//! Report rendering: turns sweep results into the paper's artefacts —
//! per-figure CSV data, gnuplot scripts, ASCII surfaces, sensitivity
//! tables — written under `results/`.

use crate::coordinator::SweepResult;
use crate::surface::{ResponseSurface, SurfaceGrid};
use crate::util::plot;
use std::path::Path;

/// Write a string to `dir/name`, creating directories as needed.
pub fn write(dir: &Path, name: &str, content: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(name), content)?;
    Ok(())
}

/// Emit one paper-style figure: CSV + gnuplot script + ASCII preview.
/// Returns the ASCII preview (also printed by the CLI).
pub fn emit_figure(
    dir: &Path,
    stem: &str,
    title: &str,
    grid: &SurfaceGrid,
    value_name: &str,
    log_xy: bool,
) -> anyhow::Result<String> {
    let csv_name = format!("{stem}.csv");
    write(dir, &csv_name, &grid.csv(value_name))?;
    write(
        dir,
        &format!("{stem}.gnuplot"),
        &plot::gnuplot_script(&csv_name, &format!("{stem}.png"), title, log_xy),
    )?;
    let ascii = grid.ascii(title, true);
    write(dir, &format!("{stem}.txt"), &ascii)?;
    Ok(ascii)
}

/// Sensitivity table for a sweep phase (the paper's §III.A conclusions).
pub fn sensitivity_table(result: &SweepResult, phase: &str) -> anyhow::Result<String> {
    let samples = result.samples(phase);
    let surf = ResponseSurface::fit(&samples)?;
    let mut out = String::new();
    out.push_str(&format!(
        "Sensitivity ({phase}), response-surface fit r²={:.4}\n",
        surf.r2
    ));
    out.push_str("parameter     local power-law exponent\n");
    for (name, e) in surf.ranking() {
        out.push_str(&format!("{name:<13} {e:+.3}\n"));
    }
    Ok(out)
}

/// Per-cell measurement CSV (full provenance of a sweep). The
/// `interpolated` column distinguishes cells the adaptive planner accepted
/// at pilot precision from fully measured ones, `failed` marks cells
/// quarantined after trial-retry exhaustion (their partial summaries are
/// provenance only — excluded from surface fits), and `trials` is the
/// count each cell actually ran (uniform in exhaustive mode, per-cell
/// under the planner).
pub fn sweep_csv(result: &SweepResult) -> String {
    let mut out = String::from(sweep_csv_header());
    for c in &result.cells {
        out.push_str(&sweep_csv_row(c));
    }
    out
}

/// The [`sweep_csv`] header line (with trailing newline). Split out so the
/// service can stream the CSV row-by-row without materialising it.
pub fn sweep_csv_header() -> &'static str {
    "n_signals,n_memvec,n_obs,violated,interpolated,failed,train_median_s,train_iqr_s,surveil_median_s,surveil_iqr_s,trials\n"
}

/// One [`sweep_csv`] data row (with trailing newline) for a single cell.
pub fn sweep_csv_row(c: &crate::coordinator::CellMeasure) -> String {
    let fmt = |s: &Option<crate::util::Summary>| match s {
        Some(s) => format!("{},{}", s.median, s.p75 - s.p25),
        None => ",".to_string(),
    };
    format!(
        "{},{},{},{},{},{},{},{},{}\n",
        c.key.n,
        c.key.m,
        c.key.obs,
        c.violated,
        c.interpolated,
        c.failed,
        fmt(&c.train),
        fmt(&c.surveil),
        c.train.as_ref().map(|s| s.n).unwrap_or(0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sweep, Backend, SweepSpec};

    fn small_result() -> SweepResult {
        run_sweep(
            &SweepSpec {
                signals: vec![4, 8],
                memvecs: vec![8, 16, 32],
                obs: vec![32, 128],
                trials: 2,
                seed: 3,
                model: "mset2".into(),
                workers: 2,
                ..SweepSpec::default()
            },
            Backend::Native,
        )
        .unwrap()
    }

    #[test]
    fn figure_emission_writes_three_files() {
        let res = small_result();
        let grid = res.panel("train", 4);
        let dir = std::env::temp_dir().join("cs_report_test");
        let ascii = emit_figure(&dir, "fig_test", "t", &grid, "cost_s", true).unwrap();
        assert!(ascii.contains("t"));
        for ext in ["csv", "gnuplot", "txt"] {
            assert!(dir.join(format!("fig_test.{ext}")).exists());
        }
    }

    #[test]
    fn sensitivity_table_ranks_memvecs_for_training() {
        let res = small_result();
        let table = sensitivity_table(&res, "train").unwrap();
        assert!(table.contains("n_memvec"));
        assert!(table.contains("r²="));
    }

    #[test]
    fn sweep_csv_has_all_cells() {
        let res = small_result();
        let csv = sweep_csv(&res);
        // header + 12 cells
        assert_eq!(csv.lines().count(), 13);
        assert!(csv.contains("true")); // gap rows flagged
    }
}
