"""Pure-jnp oracle for the L1 Pallas kernels.

These definitions are the *source of truth* for the MSET2 similarity
operator and the fused estimation step. They are mirrored in three places
that the test suites cross-check against each other:

- ``rust/src/mset/similarity.rs``  (native Rust oracle, f64)
- ``kernels/similarity.py``        (Pallas/MXU kernel, f32)
- this file                        (pure jnp, any dtype)

Constants are shared with the Rust side; change them together.
"""

import jax.numpy as jnp

#: Kernel bandwidth (dimensionless) — matches ``mset::similarity::GAMMA``.
GAMMA = 0.5

#: Ridge regularisation λ = RIDGE_REL · tr(S)/m; since diag(S) = 1 this is
#: simply RIDGE_REL. Matches ``mset::RIDGE_REL``.
RIDGE_REL = 1e-3

#: Newton–Schulz iterations for the in-graph SPD inverse (see
#: ``model.ns_inverse`` and DESIGN.md §7 — the TPU substitute for the
#: paper's cuSOLVER eigendecomposition).
NS_ITERS = 30


def bandwidth(n_real):
    """Similarity bandwidth γ·√n for the *unpadded* signal count."""
    return GAMMA * float(n_real) ** 0.5


def sim_cross(d, x, bw):
    """Similarity K[i, b] = s(D[i], X[b]) — reference implementation.

    d: (m, n) memory matrix (rows = memory vectors)
    x: (B, n) observation chunk (rows = observations)
    bw: scalar bandwidth γ·√n_real
    returns (m, B)
    """
    # ‖a−b‖² via the Gram trick, clamped against rounding.
    dn = jnp.sum(d * d, axis=1, keepdims=True)          # (m, 1)
    xn = jnp.sum(x * x, axis=1)[None, :]                # (1, B)
    cross = d @ x.T                                     # (m, B)
    d2 = jnp.maximum(dn + xn - 2.0 * cross, 0.0)
    return 1.0 / (1.0 + jnp.sqrt(d2) / bw)


def sim_matrix(d, bw):
    """Symmetric similarity matrix S = sim_cross(D, D)."""
    return sim_cross(d, d, bw)


def masked_similarity(d, mask, bw):
    """Bucket-padded similarity matrix used by training.

    Padded rows (mask == 0) are replaced by identity rows so that the
    regularised inverse is block diagonal: the padded block never mixes
    with the real block (see DESIGN.md §2.3).

    The diagonal is pinned to exactly 1: the Gram-trick distance
    ‖a‖²+‖b‖²−2aᵀb rounds to ~1e-6 instead of 0 in f32, and √ of that puts
    ~1e-3 noise on the diagonal — the same order as the ridge λ.
    """
    s_raw = sim_matrix(d, bw)
    outer = mask[:, None] * mask[None, :]
    s = s_raw * outer
    m = d.shape[0]
    return s - jnp.diag(jnp.diagonal(s)) + jnp.eye(m, dtype=s.dtype)


def estimate(g, k, d, x):
    """Fused estimation: W = G·K, X̂ = Wᵀ·D, R = X − X̂.

    g: (m, m), k: (m, B) masked similarities, d: (m, n), x: (B, n)
    returns (xhat (B, n), resid (B, n))
    """
    w = g @ k                                           # (m, B)
    xhat = w.T @ d                                      # (B, n)
    return xhat, x - xhat


def aakr_estimate(k, d, x):
    """AAKR: similarity-weighted average of memory vectors."""
    wsum = jnp.maximum(jnp.sum(k, axis=0, keepdims=True), 1e-12)
    w = k / wsum                                        # (m, B)
    xhat = w.T @ d
    return xhat, x - xhat
