//! Chaos property suite: every compiled-in failpoint, armed
//! deterministically, drives the system into exactly one of two states —
//! a result equivalent to the fault-free run, or a *classified* injected
//! failure. Never a hang, never corruption, never an unclassified error.
//! Plus the headline robustness end-to-end: kill -9 a serving process
//! mid-sweep and prove `serve --resume` replays the lost job to a
//! recommendation bit-identical to an undisturbed run over the same
//! cache.
//!
//! Failpoint decisions are pure functions of `(seed, point, tag)`, so
//! every property here is replayable: a failing seed prints in the
//! assertion message and re-running reproduces it exactly.

use containerstress::coordinator::{run_sweep, run_sweep_cached, Backend, SweepSpec};
use containerstress::metrics::Registry;
use containerstress::obs::journal::{Journal, JournalConfig};
use containerstress::service::SweepCache;
use containerstress::util::failpoint;
use containerstress::util::json::Json;
use std::io::{Read, Write};
use std::time::{Duration, Instant};

/// One measurable cell (m ≥ 2n), one trial: milliseconds per run, so the
/// 100-seed properties stay fast.
fn one_cell_spec(seed: u64) -> SweepSpec {
    SweepSpec {
        signals: vec![2],
        memvecs: vec![8],
        obs: vec![16],
        trials: 1,
        seed,
        workers: 1,
        ..SweepSpec::default()
    }
}

/// Every failpoint's injection decision is a pure function of
/// `(seed, point, tag)`: re-arming the same spec reproduces the same
/// fire-set, rate 0 never fires, rate 1 always fires — for all six
/// compiled-in points over 100 seeds each.
#[test]
fn injection_decisions_are_pure_over_100_seeds_per_point() {
    let _g = failpoint::test_guard();
    failpoint::disarm_all();
    for &point in failpoint::POINTS {
        for seed in 0..100u64 {
            let fire_set = |spec: &str| -> Vec<bool> {
                failpoint::disarm_all();
                failpoint::arm_from_str(spec).unwrap();
                (0..64).map(|tag| failpoint::hit_no_panic(point, tag).is_err()).collect()
            };
            let a = fire_set(&format!("{point}:0.5:error:{seed}"));
            let b = fire_set(&format!("{point}:0.5:error:{seed}"));
            assert_eq!(a, b, "{point} seed {seed}: decisions must replay");
            assert!(
                fire_set(&format!("{point}:0:error:{seed}")).iter().all(|f| !f),
                "{point} seed {seed}: rate 0 fired"
            );
            assert!(
                fire_set(&format!("{point}:1:error:{seed}")).iter().all(|f| *f),
                "{point} seed {seed}: rate 1 missed"
            );
        }
    }
    failpoint::disarm_all();
}

/// `executor.trial.run` under a heavy error rate, 100 seeds: every run
/// terminates as either a complete result (retries absorbed the faults),
/// a result with quarantined cells, or a classified injected job error.
/// Both terminal classes must occur across the sweep of seeds.
#[test]
fn trial_faults_complete_or_classify_over_100_seeds() {
    let _g = failpoint::test_guard();
    failpoint::disarm_all();
    let (mut ok, mut failed) = (0u32, 0u32);
    for seed in 0..100u64 {
        failpoint::disarm_all();
        failpoint::arm_from_str(&format!("executor.trial.run:0.9:error:{seed}")).unwrap();
        match run_sweep(&one_cell_spec(7), Backend::Native) {
            Ok(r) => {
                assert_eq!(r.cells.len(), 1, "seed {seed}");
                if r.failed_cells().is_empty() {
                    let train = r.cells[0].train.as_ref().expect("healthy cell has costs");
                    assert!(train.median.is_finite() && train.median >= 0.0, "seed {seed}");
                    ok += 1;
                } else {
                    // single-cell job with its only cell quarantined is a
                    // job error, not an Ok — count defensively anyway
                    failed += 1;
                }
            }
            Err(e) => {
                assert!(
                    failpoint::is_injected(&e),
                    "seed {seed}: organic failure under chaos: {e:#}"
                );
                failed += 1;
            }
        }
    }
    failpoint::disarm_all();
    assert!(ok > 0, "retries never absorbed a fault ({failed} failures)");
    assert!(failed > 0, "rate 0.9 never exhausted retries ({ok} clean)");
}

/// Spill-layer chaos, 100 seeds: write faults may degrade the cache to
/// memory-only and read faults may skip warm entries, but the sweep job
/// itself always completes with full, healthy cells.
#[test]
fn spill_faults_degrade_cache_but_never_fail_jobs_over_100_seeds() {
    let _g = failpoint::test_guard();
    failpoint::disarm_all();
    let dir = std::env::temp_dir().join(format!("cs_chaos_spill_{}", std::process::id()));
    let mut degraded = 0u32;
    for seed in 0..100u64 {
        let _ = std::fs::remove_dir_all(&dir);
        // Cold run under write faults: every spill write may fail.
        failpoint::disarm_all();
        failpoint::arm_from_str(&format!("cellstore.spill.write:0.5:error:{seed}")).unwrap();
        let cache = SweepCache::open(&dir).unwrap();
        let r = run_sweep_cached(&one_cell_spec(7), Backend::Native, Some(&cache)).unwrap();
        assert_eq!(r.cells.len(), 1, "seed {seed}");
        assert!(r.failed_cells().is_empty(), "seed {seed}: spill fault leaked into cells");
        if cache.is_degraded() {
            degraded += 1;
            let reason = cache.degrade_reason().unwrap_or_default();
            assert!(reason.contains("spill"), "seed {seed}: reason '{reason}'");
        }
        // Reopen under read faults: skipped entries are re-measured, not
        // errors.
        failpoint::disarm_all();
        failpoint::arm_from_str(&format!("cellstore.spill.read:0.5:error:{seed}")).unwrap();
        let cache2 = SweepCache::open(&dir).unwrap();
        let r2 = run_sweep_cached(&one_cell_spec(7), Backend::Native, Some(&cache2)).unwrap();
        assert_eq!(r2.cells.len(), 1, "seed {seed}");
        assert!(r2.failed_cells().is_empty(), "seed {seed}");
    }
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
    assert!(degraded > 0, "write rate 0.5 never degraded the cache");
}

/// `journal.append` chaos, 100 seeds: every append lands in exactly one
/// counter (appended or errors), the writer never panics or propagates,
/// and whatever survived on disk parses back record-for-record.
#[test]
fn journal_faults_are_counted_and_survivors_parse_over_100_seeds() {
    let _g = failpoint::test_guard();
    failpoint::disarm_all();
    let dir = std::env::temp_dir().join(format!("cs_chaos_journal_{}", std::process::id()));
    let mut injected_total = 0u64;
    for seed in 0..100u64 {
        let _ = std::fs::remove_dir_all(&dir);
        failpoint::disarm_all();
        failpoint::arm_from_str(&format!("journal.append:0.5:error:{seed}")).unwrap();
        let j = Journal::open(JournalConfig::new(&dir)).unwrap();
        for i in 0..10 {
            j.append(&Json::obj(vec![("i", Json::Num(i as f64))]));
        }
        j.flush();
        assert_eq!(j.appended() + j.errors(), 10, "seed {seed}: lost an append");
        injected_total += j.errors();
        let on_disk = containerstress::obs::journal::read_records(&dir).unwrap();
        assert_eq!(
            on_disk.len() as u64,
            j.appended(),
            "seed {seed}: disk disagrees with the appended counter"
        );
        drop(j);
    }
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
    assert!(injected_total > 0, "rate 0.5 never injected over 1000 appends");
}

/// `http.conn.accept` chaos: injected accept faults drop individual
/// connections (a client retry reconnects fine) but never wedge the
/// accept loop — 100 requests all eventually succeed at fault rate 0.5.
#[test]
fn accept_faults_drop_connections_but_never_wedge_the_server() {
    let _g = failpoint::test_guard();
    failpoint::disarm_all();
    let mut cfg = containerstress::config::Config {
        backend: "native".into(),
        ..Default::default()
    };
    cfg.service.port = 0;
    cfg.service.cache_dir = None;
    let server = containerstress::service::Server::start(&cfg, Backend::Native).unwrap();
    let addr = server.addr();
    let faults0 = Registry::global().counter("service.http.accept_faults");
    failpoint::arm_from_str("http.conn.accept:0.5:error:11").unwrap();
    for i in 0..100 {
        let mut served = false;
        for _attempt in 0..20 {
            let Ok(mut stream) = std::net::TcpStream::connect(addr) else {
                continue;
            };
            let req = b"GET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n";
            if stream.write_all(req).is_err() {
                continue; // injected drop raced the write — reconnect
            }
            let mut out = String::new();
            if stream.read_to_string(&mut out).is_ok() && out.contains("200") {
                served = true;
                break;
            }
        }
        assert!(served, "request {i} never got through at fault rate 0.5");
    }
    failpoint::disarm_all();
    assert!(
        Registry::global().counter("service.http.accept_faults") > faults0,
        "rate 0.5 over 100+ connections never injected"
    );
    server.shutdown();
}

// --- crash → restart → resume, through the real binary ------------------

#[cfg(unix)]
mod crash_resume {
    use super::*;
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};

    /// Heavy enough (12 cells × 3 trials on 4096/8192-obs cells) that a
    /// kill lands mid-sweep even on a fast machine.
    const SCOPE_BODY: &str = r#"{
      "sweep": {"signals": [2, 3], "memvecs": [8, 12, 16], "obs": [4096, 8192],
                "trials": 3, "seed": 33, "model": "mset2", "workers": 2},
      "workload": {"signals": 8, "memvecs": 16, "obs_per_sec": 0.5, "train_window": 256},
      "sla": {"headroom": 2.0, "max_train_s": 3600.0}
    }"#;

    fn spawn_serve(wal: &std::path::Path, cache: &std::path::Path, resume: bool) -> (Child, std::net::SocketAddr) {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_containerstress"));
        cmd.args([
            "serve",
            "--host",
            "127.0.0.1",
            "--port",
            "0",
            "--backend",
            "native",
            "--wal-dir",
            wal.to_str().unwrap(),
            "--cache-dir",
            cache.to_str().unwrap(),
        ]);
        if resume {
            cmd.arg("--resume");
        }
        let mut child = cmd
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn serve");
        let stdout = child.stdout.take().expect("stdout piped");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("serve exited before announcing its address")
                .expect("read serve stdout");
            if let Some(rest) = line.split("http://").nth(1) {
                break rest.trim().parse().expect("parse listen addr");
            }
        };
        // Keep draining stdout so the child never blocks on a full pipe.
        std::thread::spawn(move || for _ in lines {});
        (child, addr)
    }

    fn request(addr: std::net::SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        let body = body.unwrap_or("");
        let raw = format!(
            "{method} {path} HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(raw.as_bytes()).expect("send");
        let mut out = String::new();
        stream.read_to_string(&mut out).expect("recv");
        let status: u16 = out.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
        let payload = out.split("\r\n\r\n").nth(1).unwrap_or("");
        let json = if payload.is_empty() { Json::Null } else { Json::parse(payload).unwrap() };
        (status, json)
    }

    fn await_done(addr: std::net::SocketAddr, id: u64) {
        let deadline = Instant::now() + Duration::from_secs(300);
        loop {
            let (status, j) = request(addr, "GET", &format!("/v1/jobs/{id}"), None);
            assert_eq!(status, 200, "{j}");
            match j.get("status").and_then(Json::as_str) {
                Some("done") => return,
                Some("failed") => panic!("job {id} failed: {j}"),
                _ => {
                    assert!(Instant::now() < deadline, "job {id} timed out");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    #[test]
    fn kill9_mid_sweep_then_resume_replays_to_identical_recommendation() {
        let pid = std::process::id();
        let wal = std::env::temp_dir().join(format!("cs_crash_wal_{pid}"));
        let cache = std::env::temp_dir().join(format!("cs_crash_cache_{pid}"));
        let _ = std::fs::remove_dir_all(&wal);
        let _ = std::fs::remove_dir_all(&cache);

        // Boot, submit, let it measure for a moment, then kill -9.
        let (mut child, addr) = spawn_serve(&wal, &cache, false);
        let (status, j) = request(addr, "POST", "/v1/scope", Some(SCOPE_BODY));
        assert_eq!(status, 202, "{j}");
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            let (_, j) = request(addr, "GET", "/v1/jobs/1", None);
            let done = j
                .get("progress")
                .and_then(|p| p.get("trials_done"))
                .and_then(Json::as_usize)
                .unwrap_or(0);
            if done >= 2 {
                break;
            }
            assert!(Instant::now() < deadline, "job never got mid-flight: {j}");
            std::thread::sleep(Duration::from_millis(10));
        }
        child.kill().expect("kill -9");
        let _ = child.wait();

        // The WAL must still hold the un-retired submission.
        let pending = containerstress::coordinator::wal::JobWal::open(&wal)
            .unwrap()
            .pending()
            .unwrap();
        assert_eq!(pending.len(), 1, "crashed submit must stay pending");
        assert_eq!(pending[0].kind, "sweep");

        // Restart with --resume: the lost job replays as job 1 (partial
        // cells served from the shared cache) and runs to done.
        let (mut child2, addr2) = spawn_serve(&wal, &cache, true);
        await_done(addr2, 1);
        let (status, resumed_rec) = request(addr2, "GET", "/v1/recommendations/1", None);
        assert_eq!(status, 200, "{resumed_rec}");

        // An undisturbed submission of the same request against the now
        // fully warm cache re-measures nothing, so its recommendation is
        // bit-identical to the resumed job's.
        let (status, j) = request(addr2, "POST", "/v1/scope", Some(SCOPE_BODY));
        assert_eq!(status, 202, "{j}");
        let id2 = j.get("job_id").and_then(Json::as_usize).unwrap() as u64;
        await_done(addr2, id2);
        let (status, clean_rec) = request(addr2, "GET", &format!("/v1/recommendations/{id2}"), None);
        assert_eq!(status, 200);
        assert_eq!(
            resumed_rec.to_string(),
            clean_rec.to_string(),
            "resumed recommendation must be bit-identical to the clean one"
        );

        child2.kill().expect("kill server 2");
        let _ = child2.wait();

        // Every WAL entry is now retired: a third resume replays nothing.
        let wal_after = containerstress::coordinator::wal::JobWal::open(&wal).unwrap();
        assert!(wal_after.pending().unwrap().is_empty(), "all submits must be retired");

        let _ = std::fs::remove_dir_all(&wal);
        let _ = std::fs::remove_dir_all(&cache);
    }

    #[test]
    fn sigterm_drains_gracefully_and_exits_zero() {
        let pid = std::process::id();
        let wal = std::env::temp_dir().join(format!("cs_drain_wal_{pid}"));
        let cache = std::env::temp_dir().join(format!("cs_drain_cache_{pid}"));
        let _ = std::fs::remove_dir_all(&wal);
        let _ = std::fs::remove_dir_all(&cache);
        let (mut child, addr) = spawn_serve(&wal, &cache, false);
        let (status, _) = request(addr, "GET", "/healthz", None);
        assert_eq!(status, 200);
        let term = Command::new("kill")
            .args(["-TERM", &child.id().to_string()])
            .status()
            .expect("send SIGTERM");
        assert!(term.success());
        let deadline = Instant::now() + Duration::from_secs(30);
        let code = loop {
            if let Some(st) = child.try_wait().expect("try_wait") {
                break st;
            }
            assert!(Instant::now() < deadline, "serve ignored SIGTERM");
            std::thread::sleep(Duration::from_millis(25));
        };
        assert!(code.success(), "graceful drain must exit 0, got {code:?}");
        let _ = std::fs::remove_dir_all(&wal);
        let _ = std::fs::remove_dir_all(&cache);
    }
}
