//! Service integration: boot `serve`'s [`Server`] on an ephemeral loopback
//! port, drive it with a raw TCP client (no HTTP library exists offline):
//! submit a scope job, poll it to completion, fetch the recommendation —
//! then submit the *identical* request and prove it is served entirely
//! from the cell-level sweep cache (≥1 hit per cell, zero new trials).

use containerstress::config::Config;
use containerstress::coordinator::Backend;
use containerstress::metrics::Registry;
use containerstress::obs::journal;
use containerstress::obs::slo::{SloObjective, SloSettings};
use containerstress::service::Server;
use containerstress::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The test harness runs `#[test]`s concurrently in one process, but the
/// metrics [`Registry`] (and its `sweep.trials` counter) is global — every
/// test that executes sweeps takes this lock so counter assertions see
/// only their own trials.
static SWEEP_LOCK: Mutex<()> = Mutex::new(());

fn sweep_lock() -> std::sync::MutexGuard<'static, ()> {
    SWEEP_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let body = body.unwrap_or("");
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).expect("send");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("recv");
    let status: u16 = out
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line: {out}"));
    let payload = out.split("\r\n\r\n").nth(1).unwrap_or("");
    let json = if payload.is_empty() {
        Json::Null
    } else {
        Json::parse(payload).unwrap_or_else(|e| panic!("bad body ({e}): {payload}"))
    };
    (status, json)
}

fn test_config() -> Config {
    let mut cfg = Config {
        backend: "native".into(),
        ..Config::default()
    };
    cfg.service.port = 0; // ephemeral
    cfg.service.queue_cap = 8;
    cfg.service.cache_dir = None; // memory-only cache for the test
    cfg
}

/// 2×3×2 = 12 measurable cells (no m<2n gaps), enough for a surface fit,
/// each cell tiny enough to measure in milliseconds on the native backend.
const SCOPE_BODY: &str = r#"{
  "sweep": {"signals": [2, 3], "memvecs": [8, 12, 16], "obs": [16, 32],
            "trials": 1, "seed": 9, "model": "mset2", "workers": 2},
  "workload": {"signals": 8, "memvecs": 16, "obs_per_sec": 0.5, "train_window": 256},
  "sla": {"headroom": 2.0, "max_train_s": 3600.0}
}"#;

fn submit_and_finish(addr: SocketAddr) -> u64 {
    let (status, j) = request(addr, "POST", "/v1/scope", Some(SCOPE_BODY));
    assert_eq!(status, 202, "{j}");
    let id = j.get("job_id").unwrap().as_f64().unwrap() as u64;
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (status, j) = request(addr, "GET", &format!("/v1/jobs/{id}"), None);
        assert_eq!(status, 200, "{j}");
        match j.get("status").and_then(Json::as_str) {
            Some("done") => {
                let result = j.get("result").expect("done jobs carry a summary");
                assert_eq!(result.get("cells").unwrap().as_usize(), Some(12));
                assert_eq!(result.get("gap_cells").unwrap().as_usize(), Some(0));
                return id;
            }
            Some("failed") => panic!("job failed: {j}"),
            Some("queued" | "running") => {
                assert!(Instant::now() < deadline, "job {id} timed out");
                std::thread::sleep(Duration::from_millis(20));
            }
            other => panic!("bad status {other:?}: {j}"),
        }
    }
}

#[test]
fn scope_roundtrip_and_sweep_cache() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();

    // liveness + catalog routes answer
    let (status, j) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
    let (status, j) = request(addr, "GET", "/v1/shapes", None);
    assert_eq!(status, 200);
    assert!(j.get("shapes").unwrap().as_arr().unwrap().len() >= 10);

    // --- request 1: a full measurement -----------------------------------
    let id = submit_and_finish(addr);
    let trials_first = Registry::global().counter("sweep.trials");
    assert!(trials_first >= 12, "12 cells × 1 trial expected");
    assert_eq!(server.state().cache().hits(), 0);
    assert_eq!(server.state().cache().len(), 12);

    let (status, rec) = request(addr, "GET", &format!("/v1/recommendations/{id}"), None);
    assert_eq!(status, 200, "{rec}");
    assert!(rec.get("assessments").unwrap().as_arr().unwrap().len() >= 10);
    let rendered = rec.get("rendered").unwrap().as_str().unwrap();
    assert!(rendered.contains("shape"), "{rendered}");

    // --- request 2: identical scope → served from the sweep cache --------
    let id2 = submit_and_finish(addr);
    assert_ne!(id, id2);
    let trials_second = Registry::global().counter("sweep.trials");
    assert_eq!(
        trials_second, trials_first,
        "no new trials may execute on a warm cache"
    );
    assert!(
        server.state().cache().hits() >= 12,
        "every cell must hit the cache, got {}",
        server.state().cache().hits()
    );
    assert!(Registry::global().counter("sweep.cache.hits") >= 12);
    let (status, _) = request(addr, "GET", &format!("/v1/recommendations/{id2}"), None);
    assert_eq!(status, 200);

    // metrics route exposes the counters we just asserted on
    let (status, m) = request(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(m.get("counters").unwrap().get("sweep.cache.hits").is_some());

    server.shutdown();
}

/// 12 measurable cells × 3 trials on costly `obs` sizes: seconds of work,
/// so a poller reliably catches it mid-flight even on a fast machine.
const LARGE_SCOPE_BODY: &str = r#"{
  "sweep": {"signals": [2, 3], "memvecs": [8, 12, 16], "obs": [4096, 8192],
            "trials": 3, "seed": 33, "model": "mset2", "workers": 2}
}"#;
const LARGE_SCOPE_TRIALS: u64 = 36; // 12 cells × 3 trials

/// One-cell, one-trial request: milliseconds of work.
const SMALL_SCOPE_BODY: &str = r#"{
  "sweep": {"signals": [2], "memvecs": [8], "obs": [16],
            "trials": 1, "seed": 44, "model": "mset2", "workers": 1}
}"#;

fn submit(addr: SocketAddr, body: &str) -> u64 {
    let (status, j) = request(addr, "POST", "/v1/scope", Some(body));
    assert_eq!(status, 202, "{j}");
    j.get("job_id").unwrap().as_f64().unwrap() as u64
}

fn job_status(addr: SocketAddr, id: u64) -> (String, Json) {
    let (status, j) = request(addr, "GET", &format!("/v1/jobs/{id}"), None);
    assert_eq!(status, 200, "{j}");
    let st = j.get("status").and_then(Json::as_str).expect("status").to_string();
    (st, j)
}

fn progress_field(j: &Json, key: &str) -> usize {
    j.get("progress")
        .unwrap_or_else(|| panic!("no progress in {j}"))
        .get(key)
        .and_then(Json::as_usize)
        .unwrap_or_else(|| panic!("no progress.{key} in {j}"))
}

#[test]
fn cancel_mid_sweep_keeps_partial_cells_and_stops_dispatch() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();
    let trials_at_start = Registry::global().counter("sweep.trials");
    let id = submit(addr, LARGE_SCOPE_BODY);

    // Poll until the sweep is demonstrably mid-flight, asserting progress
    // is monotone and bounded by the plan the whole way.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut last_done = 0;
    loop {
        assert!(Instant::now() < deadline, "job {id} never reached 2 trials");
        let (status, j) = job_status(addr, id);
        assert!(
            matches!(status.as_str(), "queued" | "running" | "done"),
            "{j}"
        );
        let done = progress_field(&j, "trials_done");
        let planned = progress_field(&j, "trials_planned");
        assert!(done >= last_done, "progress went backwards: {j}");
        assert!(
            planned == 0 || done <= planned,
            "trials_done overshot trials_planned: {j}"
        );
        last_done = done;
        if done >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // Cancel; the status must settle to `cancelled` (never `failed`).
    let (status, j) = request(addr, "DELETE", &format!("/v1/jobs/{id}"), None);
    assert_eq!(status, 202, "{j}");
    assert_eq!(j.get("status").and_then(Json::as_str), Some("cancelling"));
    loop {
        assert!(Instant::now() < deadline, "job {id} never cancelled");
        let (status, _) = job_status(addr, id);
        match status.as_str() {
            "cancelled" => break,
            "running" | "queued" => std::thread::sleep(Duration::from_millis(10)),
            other => panic!("cancel produced status {other:?}"),
        }
    }
    // Queued trials were reclaimed: dispatch stops within one quantum.
    let settled = Registry::global().counter("sweep.trials");
    std::thread::sleep(Duration::from_millis(150));
    assert_eq!(
        Registry::global().counter("sweep.trials"),
        settled,
        "trials kept executing after the job was reported cancelled"
    );
    assert!(
        settled - trials_at_start < LARGE_SCOPE_TRIALS,
        "cancellation should have stopped the sweep early"
    );
    // A second DELETE is a 409 — nothing left to cancel.
    let (status, _) = request(addr, "DELETE", &format!("/v1/jobs/{id}"), None);
    assert_eq!(status, 409);

    // The trials that did finish were flushed to the cell store...
    let stored = server.state().cache().len();
    assert!(stored > 0, "partial cells must be in the cache");

    // ...so the identical scope resubmitted completes from that prefix
    // with strictly fewer fresh trials than a cold run.
    let before_resubmit = Registry::global().counter("sweep.trials");
    let id2 = submit(addr, LARGE_SCOPE_BODY);
    loop {
        assert!(Instant::now() < deadline, "resubmitted job timed out");
        let (status, j) = job_status(addr, id2);
        match status.as_str() {
            "done" => {
                let r = j.get("result").expect("summary");
                assert_eq!(r.get("cells").unwrap().as_usize(), Some(12));
                break;
            }
            "queued" | "running" => std::thread::sleep(Duration::from_millis(10)),
            other => panic!("resubmitted job status {other:?}"),
        }
    }
    let fresh = Registry::global().counter("sweep.trials") - before_resubmit;
    assert!(
        fresh < LARGE_SCOPE_TRIALS,
        "resubmission must reuse the cancelled job's cached trials ({fresh} fresh)"
    );
    assert!(
        server.state().cache().hits() > 0,
        "resubmission must hit the partial cells"
    );
    server.shutdown();
}

#[test]
fn concurrent_jobs_interleave_small_overtakes_large() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();
    let large = submit(addr, LARGE_SCOPE_BODY);
    let small = submit(addr, SMALL_SCOPE_BODY);
    assert_ne!(large, small);

    // The small job, submitted second, must finish while the large sweep
    // is still in flight — the fair-scheduling acceptance criterion.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "small job timed out");
        let (status, j) = job_status(addr, small);
        match status.as_str() {
            "done" => {
                let (large_status, lj) = job_status(addr, large);
                assert!(
                    matches!(large_status.as_str(), "queued" | "running"),
                    "small job did not overtake the large one: {lj}"
                );
                break;
            }
            "queued" | "running" => std::thread::sleep(Duration::from_millis(2)),
            other => panic!("small job status {other:?}"),
        }
    }
    // Cancel the large job rather than riding it out; it must settle.
    let (status, _) = request(addr, "DELETE", &format!("/v1/jobs/{large}"), None);
    assert_eq!(status, 202);
    loop {
        assert!(Instant::now() < deadline, "large job never settled");
        let (status, _) = job_status(addr, large);
        match status.as_str() {
            "cancelled" => break,
            "queued" | "running" => std::thread::sleep(Duration::from_millis(10)),
            other => panic!("large job status {other:?}"),
        }
    }
    server.shutdown();
}

/// Direct-mode fleet scenario: 20 tenants × 36 epochs × 3 policies —
/// milliseconds of simulation, no sweep needed.
const SCENARIO_BODY: &str = r#"{
  "scenario": {
    "name": "e2e-fleet", "seed": 4, "epochs": 36,
    "arrivals": {"initial": 12, "rate_per_epoch": 0.5, "max_tenants": 20},
    "demand": {"kind": "diurnal", "base": 0.6, "amplitude": 0.4,
               "period_epochs": 7, "growth_per_epoch": 1.01, "jitter": 0.2}
  }
}"#;

/// Workload-mode scenario whose embedded oracle sweep is seconds of work
/// (costly obs axis) — slow enough to cancel mid-flight.
const SLOW_SCENARIO_BODY: &str = r#"{
  "scenario": {
    "name": "e2e-cancel", "seed": 6, "epochs": 30,
    "arrivals": {"initial": 5, "rate_per_epoch": 0.0, "max_tenants": 5},
    "demand": {"kind": "constant", "base": 1.0,
               "growth_per_epoch": 1.0, "jitter": 0.0},
    "workload": {"signals": 2, "memvecs": 8, "obs_per_sec": 10.0,
                 "train_window": 32}
  },
  "sweep": {"signals": [2, 3], "memvecs": [8, 12, 16], "obs": [4096, 8192],
            "trials": 3, "seed": 35, "model": "mset2", "workers": 2}
}"#;

#[test]
fn scenario_roundtrip_with_live_progress() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();
    let (status, j) = request(addr, "POST", "/v1/scenarios", Some(SCENARIO_BODY));
    assert_eq!(status, 202, "{j}");
    let id = j.get("job_id").unwrap().as_f64().unwrap() as u64;
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut last_done = 0;
    loop {
        assert!(Instant::now() < deadline, "scenario {id} timed out");
        let (status, j) = request(addr, "GET", &format!("/v1/scenarios/{id}"), None);
        assert_eq!(status, 200, "{j}");
        let p = j.get("progress").expect("progress always present");
        let done = p.get("units_done").and_then(Json::as_usize).unwrap();
        let total = p.get("units_total").and_then(Json::as_usize).unwrap();
        assert!(done >= last_done, "progress went backwards: {j}");
        assert!(total == 0 || done <= total, "{j}");
        last_done = done;
        match j.get("status").and_then(Json::as_str) {
            Some("done") => {
                let r = j.get("result").expect("done scenarios carry the outcome");
                let policies = r.get("policies").unwrap().as_arr().unwrap();
                assert_eq!(policies.len(), 3, "default policy set");
                for p in policies {
                    assert!(p.get("total_usd").unwrap().as_f64().unwrap() > 0.0);
                    assert_eq!(
                        p.get("usd_per_epoch").unwrap().as_arr().unwrap().len(),
                        36
                    );
                }
                assert!(!r.get("pareto").unwrap().as_arr().unwrap().is_empty());
                assert!(r.get("recommended").unwrap().as_str().is_some());
                assert_eq!(done, total, "progress must settle at completion");
                break;
            }
            Some("failed") => panic!("scenario failed: {j}"),
            Some("queued" | "running") => std::thread::sleep(Duration::from_millis(5)),
            other => panic!("bad status {other:?}: {j}"),
        }
    }
    server.shutdown();
}

#[test]
fn scenario_cancellation_honours_delete_like_sweep_jobs() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();
    let (status, j) = request(addr, "POST", "/v1/scenarios", Some(SLOW_SCENARIO_BODY));
    assert_eq!(status, 202, "{j}");
    let id = j.get("job_id").unwrap().as_f64().unwrap() as u64;

    // Wait until the embedded oracle sweep is demonstrably mid-flight.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "scenario {id} never started");
        let (status, j) = request(addr, "GET", &format!("/v1/scenarios/{id}"), None);
        assert_eq!(status, 200, "{j}");
        let trials = j
            .get("progress")
            .and_then(|p| p.get("sweep"))
            .and_then(|s| s.get("trials_done"))
            .and_then(Json::as_usize)
            .unwrap_or(0);
        if trials >= 2 {
            break;
        }
        match j.get("status").and_then(Json::as_str) {
            Some("done") => panic!("slow scenario finished before it could be cancelled"),
            Some("failed") => panic!("scenario failed: {j}"),
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    let (status, j) = request(addr, "DELETE", &format!("/v1/scenarios/{id}"), None);
    assert_eq!(status, 202, "{j}");
    assert_eq!(j.get("status").and_then(Json::as_str), Some("cancelling"));
    loop {
        assert!(Instant::now() < deadline, "scenario {id} never cancelled");
        let (_, j) = request(addr, "GET", &format!("/v1/scenarios/{id}"), None);
        match j.get("status").and_then(Json::as_str) {
            Some("cancelled") => break,
            Some("running" | "queued") => std::thread::sleep(Duration::from_millis(10)),
            other => panic!("cancel produced status {other:?}"),
        }
    }
    // A second DELETE is a 409, and the trials that did finish were
    // flushed to the cell store for the next job to reuse.
    let (status, _) = request(addr, "DELETE", &format!("/v1/scenarios/{id}"), None);
    assert_eq!(status, 409);
    assert!(
        !server.state().cache().is_empty(),
        "partial oracle-sweep cells must be in the cache"
    );
    server.shutdown();
}

/// Raw roundtrip carrying an `x-request-id` header; returns the full
/// response text (status line + headers + body) for header assertions.
fn raw_request_with_id(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
    rid: &str,
) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let body = body.unwrap_or("");
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nx-request-id: {rid}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).expect("send");
    let mut out = String::new();
    stream.read_to_string(&mut out).expect("recv");
    out
}

#[test]
fn trace_timeline_is_ordered_and_carries_request_id() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();

    // Submit under an explicit correlation ID; the response echoes it.
    let out =
        raw_request_with_id(addr, "POST", "/v1/scope", Some(SMALL_SCOPE_BODY), "e2e-trace-42");
    assert!(out.starts_with("HTTP/1.1 202 "), "{out}");
    assert!(out.contains("x-request-id: e2e-trace-42"), "{out}");
    let payload = out.split("\r\n\r\n").nth(1).unwrap();
    let id = Json::parse(payload)
        .unwrap()
        .get("job_id")
        .unwrap()
        .as_f64()
        .unwrap() as u64;
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "job {id} timed out");
        let (st, _) = job_status(addr, id);
        match st.as_str() {
            "done" => break,
            "queued" | "running" => std::thread::sleep(Duration::from_millis(5)),
            other => panic!("job status {other:?}"),
        }
    }

    // The flight-recorder timeline: keyed by the caller's ID, non-empty,
    // ordered by start offset, with per-phase queue-wait vs run-time.
    let (status, t) = request(addr, "GET", &format!("/v1/jobs/{id}/trace"), None);
    assert_eq!(status, 200, "{t}");
    assert_eq!(t.get("trace_id").and_then(Json::as_str), Some("e2e-trace-42"));
    let spans = t.get("spans").unwrap().as_arr().unwrap();
    assert!(!spans.is_empty(), "completed job must carry spans");
    let mut prev = 0.0;
    let mut phases = Vec::new();
    for s in spans {
        let start = s.get("start_us").unwrap().as_f64().unwrap();
        let end = s.get("end_us").unwrap().as_f64().unwrap();
        assert!(start >= prev, "timeline out of order: {t}");
        assert!(end >= start, "span ends before it starts: {t}");
        assert!(s.get("queue_us").unwrap().as_f64().unwrap() >= 0.0);
        assert!(s.get("run_us").unwrap().as_f64().unwrap() >= 0.0);
        phases.push(s.get("phase").and_then(Json::as_str).unwrap().to_string());
        prev = start;
    }
    for want in ["train", "surveil", "run"] {
        assert!(phases.iter().any(|p| p == want), "missing {want}: {phases:?}");
    }

    // Scenario trace route refuses sweep jobs; Prometheus exposition and
    // the unknown-format guard answer over the wire as well.
    let (status, _) = request(addr, "GET", &format!("/v1/scenarios/{id}/trace"), None);
    assert_eq!(status, 404);
    let mut stream = TcpStream::connect(addr).expect("connect");
    let scrape = b"GET /metrics?format=prometheus HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
    stream.write_all(scrape).unwrap();
    let mut prom = String::new();
    stream.read_to_string(&mut prom).unwrap();
    assert!(prom.starts_with("HTTP/1.1 200 "), "{prom}");
    assert!(prom.contains("# TYPE"), "{prom}");
    let (status, _) = request(addr, "GET", "/metrics?format=csv", None);
    assert_eq!(status, 400);

    server.shutdown();
}

/// A persistent HTTP/1.1 client connection: framed response reading
/// (`Content-Length` and chunked transfer encoding) so many requests can
/// share one socket — the `request()` helper above closes per call.
struct Conn {
    reader: BufReader<TcpStream>,
}

impl Conn {
    fn connect(addr: SocketAddr) -> Conn {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(120)))
            .unwrap();
        Conn {
            reader: BufReader::new(stream),
        }
    }

    /// Write one request (no `Connection: close` — the connection is
    /// meant to survive). `extra` carries additional header lines, each
    /// `\r\n`-terminated.
    fn send(&mut self, method: &str, path: &str, body: Option<&str>, extra: &str) {
        let body = body.unwrap_or("");
        let raw = format!(
            "{method} {path} HTTP/1.1\r\nHost: localhost\r\n{extra}Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.reader
            .get_mut()
            .write_all(raw.as_bytes())
            .expect("send");
    }

    /// Status line + headers (names lower-cased) of the next response.
    fn read_head(&mut self) -> (u16, Vec<(String, String)>) {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("bad status line: {line:?}"));
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("header");
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        (status, headers)
    }

    /// One complete framed response; chunked bodies are drained in full.
    fn read_response(&mut self) -> (u16, Vec<(String, String)>, Vec<u8>) {
        let (status, headers) = self.read_head();
        let chunked = headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v == "chunked");
        let mut body = Vec::new();
        if chunked {
            while let Some(chunk) = self.read_chunk() {
                body.extend_from_slice(&chunk);
            }
        } else {
            let len: usize = headers
                .iter()
                .find(|(k, _)| k == "content-length")
                .and_then(|(_, v)| v.parse().ok())
                .expect("content-length header");
            body.resize(len, 0);
            self.reader.read_exact(&mut body).expect("body");
        }
        (status, headers, body)
    }

    /// Next frame of a chunked body; `None` on the terminating 0-chunk.
    fn read_chunk(&mut self) -> Option<Vec<u8>> {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("chunk size");
        let size = usize::from_str_radix(line.trim(), 16)
            .unwrap_or_else(|_| panic!("bad chunk size line {line:?}"));
        let mut crlf = [0u8; 2];
        if size == 0 {
            self.reader.read_exact(&mut crlf).expect("final crlf");
            return None;
        }
        let mut chunk = vec![0u8; size];
        self.reader.read_exact(&mut chunk).expect("chunk data");
        self.reader.read_exact(&mut crlf).expect("chunk crlf");
        Some(chunk)
    }
}

fn body_json(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).expect("utf-8 body"))
        .unwrap_or_else(|e| panic!("bad body ({e}): {:?}", String::from_utf8_lossy(body)))
}

#[test]
fn keep_alive_connection_serves_pipelined_requests() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();
    let mut conn = Conn::connect(addr);

    // Genuinely pipelined: both requests written before either response
    // is read; the server answers in order on the same socket.
    conn.send("GET", "/healthz", None, "");
    conn.send("GET", "/v1/shapes", None, "");
    let (status, _, body) = conn.read_response();
    assert_eq!(status, 200);
    assert_eq!(
        body_json(&body).get("status").and_then(Json::as_str),
        Some("ok")
    );
    let (status, _, body) = conn.read_response();
    assert_eq!(status, 200);
    assert!(body_json(&body).get("shapes").unwrap().as_arr().unwrap().len() >= 10);

    // scope → poll → cancel → poll-to-cancelled, all on the same socket
    conn.send("POST", "/v1/scope", Some(LARGE_SCOPE_BODY), "");
    let (status, _, body) = conn.read_response();
    assert_eq!(status, 202, "{:?}", String::from_utf8_lossy(&body));
    let id = body_json(&body).get("job_id").unwrap().as_f64().unwrap() as u64;

    conn.send("GET", &format!("/v1/jobs/{id}"), None, "");
    let (status, _, body) = conn.read_response();
    assert_eq!(status, 200);
    assert!(matches!(
        body_json(&body).get("status").and_then(Json::as_str),
        Some("queued" | "running")
    ));

    conn.send("DELETE", &format!("/v1/jobs/{id}"), None, "");
    let (status, _, _) = conn.read_response();
    assert_eq!(status, 202);

    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(Instant::now() < deadline, "job {id} never cancelled");
        conn.send("GET", &format!("/v1/jobs/{id}"), None, "");
        let (status, _, body) = conn.read_response();
        assert_eq!(status, 200);
        match body_json(&body).get("status").and_then(Json::as_str) {
            Some("cancelled") => break,
            Some("queued" | "running") => std::thread::sleep(Duration::from_millis(10)),
            other => panic!("cancel produced status {other:?}"),
        }
    }
    server.shutdown();
}

#[test]
fn events_stream_is_live_and_matches_final_summary() {
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();
    let id = submit(addr, LARGE_SCOPE_BODY);

    let mut conn = Conn::connect(addr);
    conn.send(
        "GET",
        &format!("/v1/jobs/{id}/events"),
        None,
        "x-request-id: e2e-stream-7\r\n",
    );
    let (status, headers) = conn.read_head();
    assert_eq!(status, 200);
    let header = |name: &str| {
        headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    };
    assert_eq!(header("transfer-encoding"), Some("chunked"));
    assert_eq!(header("content-type"), Some("application/x-ndjson"));
    assert_eq!(
        header("x-request-id"),
        Some("e2e-stream-7"),
        "stream must carry the caller's correlation ID"
    );

    // Read until the first event line arrives, then prove the job is
    // still in flight — the stream is live, not a post-hoc replay.
    let mut text = String::new();
    let first = loop {
        let chunk = conn.read_chunk().expect("stream ended before any event");
        text.push_str(std::str::from_utf8(&chunk).expect("utf-8 event"));
        if let Some(line) = text.lines().find(|l| !l.trim().is_empty()) {
            break Json::parse(line).unwrap_or_else(|e| panic!("bad event ({e}): {line}"));
        }
    };
    assert_eq!(first.get("event").and_then(Json::as_str), Some("cell"));
    let (st, _) = job_status(addr, id);
    assert!(
        matches!(st.as_str(), "queued" | "running"),
        "events must arrive before the job completes (job already {st})"
    );

    // Drain to the terminal summary (the stream ends itself).
    while let Some(chunk) = conn.read_chunk() {
        text.push_str(std::str::from_utf8(&chunk).expect("utf-8 event"));
    }
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("summary"));
    assert_eq!(last.get("status").and_then(Json::as_str), Some("done"));
    assert_eq!(last.get("job").unwrap().as_usize(), Some(id as usize));

    // The terminal event agrees with the polled job state.
    let (st, j) = job_status(addr, id);
    assert_eq!(st, "done");
    assert_eq!(
        last.get("trials_done").unwrap().as_usize(),
        Some(progress_field(&j, "trials_done"))
    );
    assert_eq!(
        last.get("cells_done").unwrap().as_usize(),
        Some(progress_field(&j, "cells_done"))
    );
    let cell_events = lines
        .iter()
        .filter(|l| Json::parse(l).unwrap().get("event").and_then(Json::as_str) == Some("cell"))
        .count();
    assert_eq!(cell_events, progress_field(&j, "cells_total"));

    // The connection survives the stream: one more request on it.
    conn.send("GET", &format!("/v1/jobs/{id}"), None, "");
    let (status, _, body) = conn.read_response();
    assert_eq!(status, 200);
    assert_eq!(
        body_json(&body).get("status").and_then(Json::as_str),
        Some("done")
    );
    server.shutdown();
}

#[test]
fn service_rejects_bad_requests() {
    // Server teardown detaches the process-wide telemetry sink, so even
    // this sweep-free test serializes with the journal/stream tests.
    let _guard = sweep_lock();
    let server = Server::start(&test_config(), Backend::Native).expect("server");
    let addr = server.addr();

    let (status, _) = request(addr, "POST", "/v1/scope", Some("{not json"));
    assert_eq!(status, 400);
    // empty sweep axes: a clean 422, not a panic (in the service path too)
    let (status, j) = request(addr, "POST", "/v1/scope", Some(r#"{"sweep": {"signals": []}}"#));
    assert_eq!(status, 422, "{j}");
    assert!(j.get("error").unwrap().as_str().unwrap().contains("non-empty"));

    let (status, _) = request(addr, "GET", "/v1/jobs/99999", None);
    assert_eq!(status, 404);
    let (status, _) = request(addr, "GET", "/v1/recommendations/not-a-number", None);
    assert_eq!(status, 400);
    let (status, _) = request(addr, "GET", "/no/such/route", None);
    assert_eq!(status, 404);
    let (status, _) = request(addr, "DELETE", "/v1/scope", None);
    assert_eq!(status, 405);

    server.shutdown();
}

/// The ops plane end to end: an impossible latency objective drives a
/// burn-rate page visible in `/v1/slo` and `/healthz`; a job submitted
/// under a client-supplied W3C `traceparent` streams its spans live over
/// `/v1/trace/stream` with the parent/child chain intact; and after the
/// server shuts down the trace is recovered from the on-disk telemetry
/// journal — the same lookup `containerstress obs grep --trace-id` runs.
#[test]
fn ops_plane_slo_breach_trace_stream_and_journal_recovery() {
    let _guard = sweep_lock();
    let jdir = std::env::temp_dir().join(format!("cs-e2e-journal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&jdir);

    let mut cfg = test_config();
    cfg.service.journal_dir = Some(jdir.clone());
    cfg.service.journal_snapshot_ms = 50;
    // A 100 ns latency threshold makes every request "slow": the page
    // burn is bad_fraction / (1 - 0.99) = 100, clearing the 14.4 bar as
    // soon as both page windows contain any traffic at all.
    cfg.service.slo = SloSettings {
        window_s: 60,
        tick_ms: 25,
        objectives: vec![SloObjective {
            route: "all".into(),
            latency_ms: 0.0001,
            latency_target: 0.99,
            error_target: 0.999,
        }],
    };

    const TRACE_ID: &str = "e2e0ddcafe5105e77a11babe00000001";
    const PARENT_SPAN: &str = "00000000000000aa";

    let server = Server::start(&cfg, Backend::Native).expect("server");
    let addr = server.addr();
    let deadline = Instant::now() + Duration::from_secs(120);

    // Subscribe to the span firehose (filtered to the caller's trace id)
    // before submitting, so arriving spans are proven live, not replay.
    let mut stream = Conn::connect(addr);
    stream.send("GET", &format!("/v1/trace/stream?trace_id={TRACE_ID}"), None, "");
    let (status, headers) = stream.read_head();
    assert_eq!(status, 200);
    assert!(headers.iter().any(|(k, v)| k == "transfer-encoding" && v == "chunked"));
    assert!(headers.iter().any(|(k, v)| k == "content-type" && v == "application/x-ndjson"));

    // Submit under a client traceparent; the 202 joins the caller's
    // trace (same trace id) with a fresh server-side span id.
    let mut sub = Conn::connect(addr);
    sub.send(
        "POST",
        "/v1/scope",
        Some(SMALL_SCOPE_BODY),
        &format!("traceparent: 00-{TRACE_ID}-{PARENT_SPAN}-01\r\n"),
    );
    let (status, headers, body) = sub.read_response();
    assert_eq!(status, 202, "{:?}", String::from_utf8_lossy(&body));
    let echoed = headers
        .iter()
        .find(|(k, _)| k == "traceparent")
        .map(|(_, v)| v.as_str())
        .expect("202 must carry a traceparent header");
    assert!(echoed.starts_with(&format!("00-{TRACE_ID}-")), "{echoed}");
    assert!(!echoed.contains(PARENT_SPAN), "span id must be fresh: {echoed}");
    let id = body_json(&body).get("job_id").unwrap().as_f64().unwrap() as u64;
    drop(sub);

    loop {
        assert!(Instant::now() < deadline, "job {id} timed out");
        match job_status(addr, id).0.as_str() {
            "done" => break,
            "queued" | "running" => std::thread::sleep(Duration::from_millis(5)),
            other => panic!("job status {other:?}"),
        }
    }

    // The job's spans arrive on the stream stitched under the caller's
    // trace: the "run" envelope parents under the client's span id, the
    // per-trial spans parent under the envelope.
    let mut text = String::new();
    let run = loop {
        assert!(Instant::now() < deadline, "run span never streamed");
        let chunk = stream.read_chunk().expect("trace stream ended");
        text.push_str(std::str::from_utf8(&chunk).expect("utf-8 span line"));
        let run = text
            .lines()
            .filter_map(|l| Json::parse(l.trim()).ok())
            .find(|j| j.get("phase").and_then(Json::as_str) == Some("run"));
        if let Some(run) = run {
            break run;
        }
    };
    assert_eq!(run.get("trace_id").and_then(Json::as_str), Some(TRACE_ID));
    assert_eq!(run.get("parent_id").and_then(Json::as_str), Some(PARENT_SPAN));
    let run_span_id = run
        .get("span_id")
        .and_then(Json::as_str)
        .expect("span_id")
        .to_string();
    let spans: Vec<Json> = text
        .lines()
        .filter_map(|l| Json::parse(l.trim()).ok())
        .filter(|j| j.get("kind").and_then(Json::as_str) == Some("span"))
        .collect();
    for s in &spans {
        assert_eq!(
            s.get("trace_id").and_then(Json::as_str),
            Some(TRACE_ID),
            "filtered stream leaked a foreign span: {s}"
        );
    }
    let has_child = spans
        .iter()
        .any(|s| s.get("parent_id").and_then(Json::as_str) == Some(run_span_id.as_str()));
    assert!(has_child, "no per-trial span parents under the run envelope");
    drop(stream);

    // Drive traffic until the engine pages. Burn is 100 from the first
    // snapshot with traffic, so this converges within about the short
    // page window (60 s / 144 ≈ 420 ms).
    let slo = loop {
        assert!(Instant::now() < deadline, "SLO engine never paged");
        let (status, _) = request(addr, "GET", "/healthz", None);
        assert_eq!(status, 200);
        let (status, slo) = request(addr, "GET", "/v1/slo", None);
        assert_eq!(status, 200, "{slo}");
        assert_eq!(slo.get("enabled").and_then(Json::as_bool), Some(true));
        if slo.get("status").and_then(Json::as_str) == Some("page") {
            break slo;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let objectives = slo.get("objectives").unwrap().as_arr().unwrap();
    assert_eq!(objectives.len(), 1, "{slo}");
    let obj = &objectives[0];
    assert_eq!(obj.get("route").and_then(Json::as_str), Some("all"));
    assert_eq!(obj.get("status").and_then(Json::as_str), Some("page"), "{slo}");
    let burn = obj
        .get("latency")
        .and_then(|l| l.get("burn"))
        .and_then(|b| b.get("page_long"))
        .and_then(Json::as_f64)
        .unwrap();
    assert!(burn >= 14.4, "paging objective must clear the page burn: {slo}");

    // /healthz carries the dashboard one-liner for the same state.
    let (status, h) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    let summary = h.get("slo").expect("healthz slo summary");
    assert_eq!(summary.get("status").and_then(Json::as_str), Some("page"), "{h}");
    let breaching = summary.get("breaching").unwrap().as_arr().unwrap();
    assert!(breaching.iter().any(|r| r.as_str() == Some("all")), "{h}");
    assert_eq!(summary.get("shedding").and_then(Json::as_bool), Some(true), "{h}");

    // Shut down (flushing the journal) and recover the trace from disk —
    // the lookup `containerstress obs grep --trace-id` performs.
    server.shutdown();
    let records = journal::read_records(&jdir).expect("read journal");
    let kinds: Vec<&str> = records
        .iter()
        .filter_map(|r| r.get("kind").and_then(Json::as_str))
        .collect();
    assert!(kinds.contains(&"metrics"), "no metrics frames journaled");
    assert!(kinds.contains(&"slo"), "no slo frames journaled");
    let trace: Vec<&Json> = records
        .iter()
        .filter(|r| r.get("trace_id").and_then(Json::as_str) == Some(TRACE_ID))
        .collect();
    assert!(!trace.is_empty(), "journal lost the client trace");
    let envelope = trace
        .iter()
        .find(|r| r.get("phase").and_then(Json::as_str) == Some("run"))
        .expect("journal must hold the run envelope");
    assert_eq!(envelope.get("parent_id").and_then(Json::as_str), Some(PARENT_SPAN));
    assert_eq!(envelope.get("span_id").and_then(Json::as_str), Some(run_span_id.as_str()));
    let _ = std::fs::remove_dir_all(&jdir);
}
