//! Property tests for the blocked `linalg::kernel` core: every `_into`
//! kernel must match its naive single-accumulator reference within 1e-12
//! across random shapes — including the zero-padded-signal case the
//! bucket router relies on. (The kernels are designed to be *bit*-stable
//! against the references — ascending-`k` accumulation, no FMA
//! contraction — so 1e-12 is slack; several properties assert exact
//! equality where the design guarantees it.)
//!
//! The exact-bit properties pin the scalar tier first (a stray
//! `CONTAINERSTRESS_KERNEL=simd` in the environment must not flip the
//! process-wide dispatch under them). The SIMD tier is covered by
//! direct-call tolerance properties at the bottom — explicit backend
//! argument, no global dispatch mutation — plus the dispatch-roundtrip
//! tests in `tests/simd_props.rs`.

use containerstress::linalg::kernel::{
    self, dist2_cross_into, matmul_into, matmul_nt_into, matmul_tn_into, syrk_into,
};
use containerstress::linalg::{simd, Mat, Workspace};
use containerstress::mset::{
    sim_cross, sim_cross_ref, sim_cross_t_into, sim_matrix, sim_matrix_ref, Scaler,
};
use containerstress::util::prop::forall_res;
use containerstress::util::rng::Rng;

fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_gauss(&mut m.data);
    m
}

/// Append `pad` zero columns (the bucket router's signal padding).
fn pad_cols(m: &Mat, pad: usize) -> Mat {
    let mut out = Mat::zeros(m.rows, m.cols + pad);
    for r in 0..m.rows {
        out.row_mut(r)[..m.cols].copy_from_slice(m.row(r));
    }
    out
}

/// Pin the scalar tier so the exact-bit assertions below hold regardless
/// of the `CONTAINERSTRESS_KERNEL` env knob.
fn pin_scalar() {
    simd::install(simd::BackendRequest::Scalar, "test").expect("scalar install cannot fail");
}

fn close(a: &Mat, b: &Mat, tol: f64, what: &str) -> Result<(), String> {
    if (a.rows, a.cols) != (b.rows, b.cols) {
        return Err(format!(
            "{what}: shape ({},{}) vs ({},{})",
            a.rows, a.cols, b.rows, b.cols
        ));
    }
    let d = a.max_abs_diff(b);
    if d > tol {
        return Err(format!("{what}: max abs diff {d} > {tol}"));
    }
    Ok(())
}

#[test]
fn prop_matmul_matches_naive_reference() {
    pin_scalar();
    forall_res(
        "blocked matmul == naive matmul",
        200,
        |rng| {
            let m = rng.range_usize(1, 18);
            let k = rng.range_usize(1, 18);
            let n = rng.range_usize(1, 18);
            let a = random_mat(rng, m, k);
            let b = random_mat(rng, k, n);
            (a, b)
        },
        |(a, b)| {
            let mut ws = Workspace::new();
            let mut out = Mat::zeros(0, 0);
            matmul_into(&mut out, a, b, &mut ws);
            close(&out, &kernel::reference::matmul(a, b), 1e-12, "matmul")?;
            // Mat::matmul routes through the same kernel
            close(&a.matmul(b), &out, 0.0, "Mat::matmul")
        },
    );
}

#[test]
fn prop_nt_tn_syrk_match_references() {
    pin_scalar();
    forall_res(
        "NT/TN/syrk variants == naive references",
        200,
        |rng| {
            let m = rng.range_usize(1, 16);
            let k = rng.range_usize(1, 16);
            let n = rng.range_usize(1, 16);
            (random_mat(rng, m, k), random_mat(rng, n, k), random_mat(rng, m, n))
        },
        |(a, b, c)| {
            let mut ws = Workspace::new();
            let mut out = Mat::zeros(0, 0);
            matmul_nt_into(&mut out, a, b, &mut ws);
            close(&out, &kernel::reference::matmul_nt(a, b), 1e-12, "NT")?;

            // TN: aᵀ·c with a: m×k ⇒ use c: m×n ⇒ k×n result
            matmul_tn_into(&mut out, a, c, &mut ws);
            close(
                &out,
                &kernel::reference::matmul(&a.transpose(), c),
                1e-12,
                "TN",
            )?;

            syrk_into(&mut out, a);
            close(&out, &kernel::reference::syrk(a), 1e-12, "syrk")?;
            for i in 0..out.rows {
                for j in 0..out.cols {
                    if out[(i, j)].to_bits() != out[(j, i)].to_bits() {
                        return Err(format!("syrk not exactly symmetric at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_kernels_match_reference_and_padding() {
    pin_scalar();
    forall_res(
        "blocked similarity == per-pair reference (padded and not)",
        150,
        |rng| {
            // n ≥ 4 keeps random gaussian rows well-separated, so the
            // Gram expansion's cancellation stays far below 1e-12.
            let m = rng.range_usize(1, 20);
            let b = rng.range_usize(1, 20);
            let n = rng.range_usize(4, 16);
            let pad = rng.range_usize(0, 6);
            (random_mat(rng, m, n), random_mat(rng, b, n), pad)
        },
        |(d, x, pad)| {
            let kr = sim_cross_ref(d, x);
            close(&sim_cross(d, x), &kr, 1e-12, "sim_cross")?;
            let sr = sim_matrix_ref(d);
            close(&sim_matrix(d), &sr, 1e-12, "sim_matrix")?;

            // zero-padded signal dimension with n_real fixed: the result
            // must be bit-identical to the unpadded blocked kernel (the
            // bucket-router invariant).
            let dp = pad_cols(d, *pad);
            let xp = pad_cols(x, *pad);
            let unpadded = sim_cross(d, x);
            let mut padded = Mat::zeros(0, 0);
            Workspace::with(|ws| {
                containerstress::mset::sim_cross_into(&mut padded, &dp, &xp, d.cols, ws)
            });
            close(&padded, &unpadded, 0.0, "padded sim_cross")?;
            close(&padded, &kr, 1e-12, "padded sim_cross vs reference")
        },
    );
}

#[test]
fn prop_sim_cross_self_equals_sim_matrix_bitwise() {
    pin_scalar();
    forall_res(
        "sim_cross(d, d) == sim_matrix(d), bit for bit",
        100,
        |rng| {
            let m = rng.range_usize(1, 24);
            let n = rng.range_usize(1, 12);
            random_mat(rng, m, n)
        },
        |d| {
            let k = sim_cross(d, d);
            let s = sim_matrix(d);
            for i in 0..d.rows {
                for j in 0..d.rows {
                    if k[(i, j)].to_bits() != s[(i, j)].to_bits() {
                        return Err(format!(
                            "mismatch at ({i},{j}): {} vs {}",
                            k[(i, j)],
                            s[(i, j)]
                        ));
                    }
                }
                if s[(i, i)] != 1.0 {
                    return Err(format!("diag ({i}) = {} != 1", s[(i, i)]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dist2_padding_bit_identical() {
    pin_scalar();
    forall_res(
        "squared distances ignore zero-padded columns exactly",
        100,
        |rng| {
            let m = rng.range_usize(1, 12);
            let b = rng.range_usize(1, 12);
            let n = rng.range_usize(1, 10);
            let pad = rng.range_usize(1, 8);
            (random_mat(rng, m, n), random_mat(rng, b, n), pad)
        },
        |(a, x, pad)| {
            let mut ws = Workspace::new();
            let mut plain = Mat::zeros(0, 0);
            let mut padded = Mat::zeros(0, 0);
            dist2_cross_into(&mut plain, a, x, &mut ws);
            dist2_cross_into(&mut padded, &pad_cols(a, *pad), &pad_cols(x, *pad), &mut ws);
            close(&padded, &plain, 0.0, "dist2 padding")
        },
    );
}

#[test]
fn prop_scaler_transform_into_matches_transform() {
    pin_scalar();
    forall_res(
        "transform_into == transform",
        100,
        |rng| {
            let rows = rng.range_usize(2, 40);
            let cols = rng.range_usize(1, 8);
            random_mat(rng, rows, cols)
        },
        |x| {
            let sc = Scaler::fit(x);
            let a = sc.transform(x);
            let mut b = Mat::zeros(3, 3); // stale shape must be overwritten
            sc.transform_into(x, &mut b);
            close(&a, &b, 0.0, "transform")
        },
    );
}

#[test]
fn prop_transposed_sim_cross_matches() {
    pin_scalar();
    forall_res(
        "sim_cross_t == sim_crossᵀ bitwise",
        100,
        |rng| {
            let m = rng.range_usize(1, 16);
            let b = rng.range_usize(1, 16);
            let n = rng.range_usize(1, 10);
            (random_mat(rng, m, n), random_mat(rng, b, n))
        },
        |(d, x)| {
            let k = sim_cross(d, x);
            let mut kt = Mat::zeros(0, 0);
            Workspace::with(|ws| sim_cross_t_into(&mut kt, x, d, d.cols, ws));
            for i in 0..d.rows {
                for j in 0..x.rows {
                    if k[(i, j)].to_bits() != kt[(j, i)].to_bits() {
                        return Err(format!("mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

// --- SIMD tier (direct-call: explicit backend, no dispatch mutation) ------

/// The SIMD tier's documented tolerance vs the naive references (the
/// scalar tier's exact-bit contract is asserted above under `pin_scalar`).
const SIMD_TOL: f64 = 1e-10;

fn max_slice_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .fold(0.0f64, |acc, (x, y)| acc.max((x - y).abs()))
}

#[test]
fn prop_simd_kernels_within_tolerance_of_references() {
    let Some(tier) = simd::detect() else {
        eprintln!("kernel_props: no SIMD tier on this host; skipping SIMD tolerance properties");
        return;
    };
    forall_res(
        "SIMD gemm_nt/syrk/row_norms within 1e-10 of naive references",
        150,
        |rng| {
            // k spans well past the 4-lane (AVX2) / 2-lane (NEON) boundary
            // so vector-body + scalar-tail remainders are exercised every
            // run; small m/n hit the 4×2-tile edge rows and odd columns.
            let m = rng.range_usize(1, 24);
            let n = rng.range_usize(1, 24);
            let k = rng.range_usize(1, 40);
            (random_mat(rng, m, k), random_mat(rng, n, k))
        },
        |(a, b)| {
            let (m, n, k) = (a.rows, b.rows, a.cols);
            let mut out = vec![0.0f64; m * n];
            simd::gemm_nt(&mut out, &a.data, &b.data, m, n, k, tier);
            let r = kernel::reference::matmul_nt(a, b);
            let d = max_slice_diff(&out, &r.data);
            if d > SIMD_TOL {
                return Err(format!("gemm_nt: max abs diff {d} > {SIMD_TOL}"));
            }

            let mut s = vec![0.0f64; m * m];
            simd::syrk_lower(&mut s, &a.data, m, k, tier);
            let sr = kernel::reference::syrk(a);
            for i in 0..m {
                for j in 0..=i {
                    let d = (s[i * m + j] - sr[(i, j)]).abs();
                    if d > SIMD_TOL {
                        return Err(format!("syrk_lower ({i},{j}): diff {d} > {SIMD_TOL}"));
                    }
                }
            }

            let mut nrm = vec![0.0f64; m];
            simd::row_norms2(&a.data, m, k, &mut nrm, tier);
            for (i, &v) in nrm.iter().enumerate() {
                // syrk's diagonal and row_norms2 run the same vector-dot
                // op sequence → bit-identical even in tolerance mode
                if v.to_bits() != s[i * m + i].to_bits() {
                    return Err(format!(
                        "row_norms2[{i}] = {v} != syrk diag {} bitwise",
                        s[i * m + i]
                    ));
                }
                let naive: f64 = a.row(i).iter().map(|&x| x * x).sum();
                if (v - naive).abs() > SIMD_TOL {
                    return Err(format!("row_norms2[{i}]: diff vs naive > {SIMD_TOL}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_zero_padded_tail_within_tolerance() {
    let Some(tier) = simd::detect() else {
        eprintln!("kernel_props: no SIMD tier on this host; skipping SIMD padding property");
        return;
    };
    forall_res(
        "SIMD gemm_nt over zero-padded k within 1e-10 of unpadded",
        100,
        |rng| {
            // Padding shifts data between the vector body and the scalar
            // tail, so unlike the scalar tier this is tolerance, not
            // bit-identity (the padding columns themselves contribute 0).
            let m = rng.range_usize(1, 16);
            let n = rng.range_usize(1, 16);
            let k = rng.range_usize(1, 12);
            let pad = rng.range_usize(1, 9);
            (random_mat(rng, m, k), random_mat(rng, n, k), pad)
        },
        |(a, b, pad)| {
            let (m, n, k) = (a.rows, b.rows, a.cols);
            let mut plain = vec![0.0f64; m * n];
            simd::gemm_nt(&mut plain, &a.data, &b.data, m, n, k, tier);
            let ap = pad_cols(a, *pad);
            let bp = pad_cols(b, *pad);
            let mut padded = vec![0.0f64; m * n];
            simd::gemm_nt(&mut padded, &ap.data, &bp.data, m, n, k + pad, tier);
            let d = max_slice_diff(&plain, &padded);
            if d > SIMD_TOL {
                return Err(format!("padded gemm_nt: max abs diff {d} > {SIMD_TOL}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_dist2_epilogue_bit_identical() {
    let Some(tier) = simd::detect() else {
        eprintln!("kernel_props: no SIMD tier on this host; skipping epilogue property");
        return;
    };
    forall_res(
        "dist2 epilogue is bit-identical across tiers",
        100,
        |rng| {
            // The epilogue is add/sub/mul/max only — no FMA — so the SIMD
            // form must agree with the scalar form bit for bit.
            let n = rng.range_usize(1, 33);
            let mut row = vec![0.0f64; n];
            rng.fill_gauss(&mut row);
            let mut nb = vec![0.0f64; n];
            rng.fill_gauss(&mut nb);
            for v in &mut nb {
                *v = v.abs();
            }
            let nai = nb[0] + 0.5;
            (row, nb, nai)
        },
        |(row, nb, nai)| {
            let mut simd_row = row.clone();
            simd::dist2_epilogue(&mut simd_row, *nai, nb, tier);
            let mut scalar_row = row.clone();
            simd::dist2_epilogue(&mut scalar_row, *nai, nb, simd::ActiveBackend::Scalar);
            for (j, (a, b)) in simd_row.iter().zip(&scalar_row).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("epilogue[{j}]: {a} vs {b} differ bitwise"));
                }
            }
            Ok(())
        },
    );
}
