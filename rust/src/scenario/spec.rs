//! Scenario specification: **scenarios are data, not code**.
//!
//! A [`ScenarioSpec`] is a small JSON document describing a fleet
//! what-if: how tenants arrive, how each tenant's demand evolves, whether
//! demand is given directly in core-equivalents or derived from an ML
//! workload through the surface oracle, and which placement/scaling
//! policies to compare. The same schema is accepted by config files
//! (`"scenario": {…}`), the `simulate` CLI verb (`--scenario file.json`),
//! and the service's `POST /v1/scenarios` body.

use crate::scenario::fleet::PredictivePolicy;
use crate::shapes::elastic::ElasticPolicy;
use crate::shapes::Workload;
use crate::util::json::Json;

/// Tenant arrival process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArrivalSpec {
    /// Tenants already present at epoch 0.
    pub initial: usize,
    /// Poisson arrival rate (new tenants per epoch) after epoch 0.
    pub rate_per_epoch: f64,
    /// Hard cap on the fleet size; arrivals beyond it are dropped.
    pub max_tenants: usize,
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec {
            initial: 20,
            rate_per_epoch: 0.5,
            max_tenants: 200,
        }
    }
}

/// Shape of one tenant's demand multiplier over its lifetime. Every kind
/// is further scaled by the common `growth_per_epoch` drift and the
/// per-tenant lognormal jitter of the enclosing [`DemandSpec`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DemandKind {
    /// Flat demand (exponential growth via `growth_per_epoch`).
    Constant,
    /// Demand doubles every `every` epochs (the paper's step growth).
    Steps {
        /// Epochs between doublings.
        every: usize,
    },
    /// `1 + amplitude · sin(2π·(t + phase)/period)` — weekly/daily load
    /// cycles; each tenant gets a deterministic random phase.
    Diurnal {
        /// Relative swing around the mean, in `[0, 1]`.
        amplitude: f64,
        /// Cycle length in epochs.
        period: usize,
    },
    /// Baseline 1×, spiking to `spike`× for `width` epochs every `every`
    /// epochs (tenant-phase-offset): launch days, reprocessing bursts.
    Flash {
        /// Multiplier during a spike (≥ 1).
        spike: f64,
        /// Epochs between spike onsets.
        every: usize,
        /// Spike duration in epochs.
        width: usize,
    },
}

/// Per-tenant demand generator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DemandSpec {
    /// Base demand: core-equivalents (direct mode) or the multiplier on
    /// the workload's `obs_per_sec` (workload mode).
    pub base: f64,
    /// Multiplicative drift applied every epoch (1.0 = none).
    pub growth_per_epoch: f64,
    /// σ of the per-tenant lognormal size jitter (0 = identical tenants).
    pub jitter: f64,
    /// Temporal shape of the demand.
    pub kind: DemandKind,
}

impl Default for DemandSpec {
    fn default() -> Self {
        DemandSpec {
            base: 0.5,
            growth_per_epoch: 1.005,
            jitter: 0.3,
            kind: DemandKind::Diurnal {
                amplitude: 0.4,
                period: 7,
            },
        }
    }
}

/// Per-epoch multiplicative drift of a tenant's ML design parameters —
/// customers widen their telemetry and grow their models over time, which
/// moves them across the `(n_signals, n_memvec, n_obs)` cost grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkloadDrift {
    /// Growth factor per epoch on `n_signals`.
    pub signals_growth: f64,
    /// Growth factor per epoch on `n_memvec`.
    pub memvecs_growth: f64,
}

impl Default for WorkloadDrift {
    fn default() -> Self {
        WorkloadDrift {
            signals_growth: 1.0,
            memvecs_growth: 1.0,
        }
    }
}

/// Workload mode: tenants are ML use cases whose demand is derived from
/// the surface oracle instead of given directly in core-equivalents.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    /// The base workload every tenant starts from.
    pub base: Workload,
    /// Per-epoch drift across the design grid.
    pub drift: WorkloadDrift,
}

/// One placement/scaling policy to evaluate.
#[derive(Clone, Copy, Debug)]
pub enum PolicySpec {
    /// Fixed shape chosen up front to cover the tenant's peak demand at
    /// the given headroom — the ContainerStress recommendation.
    PreScoped {
        /// Target peak utilisation of the chosen shape (e.g. 0.8).
        headroom: f64,
    },
    /// Reactive threshold autoscaler (scale-up lag, migration fees).
    Reactive(ElasticPolicy),
    /// Predictive oracle-driven scaler: looks ahead in the demand trace
    /// and migrates *before* demand crosses capacity.
    Predictive(PredictivePolicy),
}

impl PolicySpec {
    /// Short human-readable label used in reports, JSON, and CSV output
    /// (deliberately comma-free so CSV rows never need quoting).
    pub fn label(&self) -> String {
        match self {
            PolicySpec::PreScoped { headroom } => format!("prescoped(h={headroom:.2})"),
            PolicySpec::Reactive(p) => {
                format!("reactive(up={:.2} lag={})", p.scale_up_at, p.scale_lag_epochs)
            }
            PolicySpec::Predictive(p) => {
                format!("predictive(horizon={} lag={})", p.horizon_epochs, p.scale_lag_epochs)
            }
        }
    }
}

/// A complete fleet scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Scenario name (report/file stem).
    pub name: String,
    /// Root seed; tenant arrivals, phases and jitter all derive from it.
    pub seed: u64,
    /// Simulated epochs.
    pub epochs: usize,
    /// Wall-clock hours per epoch.
    pub hours_per_epoch: f64,
    /// Tenant arrival process.
    pub arrivals: ArrivalSpec,
    /// Per-tenant demand generator.
    pub demand: DemandSpec,
    /// `Some` switches demand to workload mode (surface-oracle derived).
    pub workload: Option<WorkloadSpec>,
    /// Policies to compare (at least one).
    pub policies: Vec<PolicySpec>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "demo-fleet".into(),
            seed: 7,
            epochs: 180,
            hours_per_epoch: 24.0,
            arrivals: ArrivalSpec::default(),
            demand: DemandSpec::default(),
            workload: None,
            policies: vec![
                PolicySpec::PreScoped { headroom: 0.8 },
                PolicySpec::Reactive(ElasticPolicy::default()),
                PolicySpec::Predictive(PredictivePolicy::default()),
            ],
        }
    }
}

impl ScenarioSpec {
    /// Reject scenarios that cannot run (zero epochs, bad rates, empty
    /// policy list, out-of-range policy thresholds, …) with a clean error
    /// before any work is scheduled.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "scenario name must be non-empty");
        anyhow::ensure!(self.epochs >= 1, "epochs must be ≥ 1");
        anyhow::ensure!(
            self.hours_per_epoch.is_finite() && self.hours_per_epoch > 0.0,
            "hours_per_epoch must be finite and > 0"
        );
        anyhow::ensure!(self.arrivals.max_tenants >= 1, "max_tenants must be ≥ 1");
        anyhow::ensure!(
            self.arrivals.initial <= self.arrivals.max_tenants,
            "initial tenants ({}) exceed max_tenants ({})",
            self.arrivals.initial,
            self.arrivals.max_tenants
        );
        anyhow::ensure!(
            self.arrivals.rate_per_epoch.is_finite() && self.arrivals.rate_per_epoch >= 0.0,
            "rate_per_epoch must be finite and ≥ 0"
        );
        let d = &self.demand;
        anyhow::ensure!(
            d.base.is_finite() && d.base >= 0.0,
            "demand.base must be finite and ≥ 0"
        );
        anyhow::ensure!(
            d.growth_per_epoch.is_finite() && d.growth_per_epoch > 0.0,
            "demand.growth_per_epoch must be finite and > 0"
        );
        anyhow::ensure!(
            d.jitter.is_finite() && d.jitter >= 0.0,
            "demand.jitter must be finite and ≥ 0"
        );
        match d.kind {
            DemandKind::Constant => {}
            DemandKind::Steps { every } => {
                anyhow::ensure!(every >= 1, "demand.step_every must be ≥ 1");
            }
            DemandKind::Diurnal { amplitude, period } => {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "demand.amplitude must be in [0, 1]"
                );
                anyhow::ensure!(period >= 1, "demand.period_epochs must be ≥ 1");
            }
            DemandKind::Flash { spike, every, width } => {
                anyhow::ensure!(
                    spike.is_finite() && spike >= 1.0,
                    "demand.spike must be finite and ≥ 1"
                );
                anyhow::ensure!(every >= 1, "demand.spike_every must be ≥ 1");
                anyhow::ensure!(
                    width >= 1 && width <= every,
                    "demand.spike_width must be in [1, spike_every]"
                );
            }
        }
        if let Some(w) = &self.workload {
            anyhow::ensure!(
                w.base.n_signals >= 1 && w.base.n_memvec >= 1,
                "workload signals/memvecs must be ≥ 1"
            );
            anyhow::ensure!(
                w.base.obs_per_sec.is_finite() && w.base.obs_per_sec >= 0.0,
                "workload.obs_per_sec must be finite and ≥ 0"
            );
            for (name, g) in [
                ("signals_growth", w.drift.signals_growth),
                ("memvecs_growth", w.drift.memvecs_growth),
            ] {
                anyhow::ensure!(
                    g.is_finite() && g > 0.0,
                    "workload.drift.{name} must be finite and > 0"
                );
            }
        }
        anyhow::ensure!(!self.policies.is_empty(), "policies must be non-empty");
        for p in &self.policies {
            match p {
                PolicySpec::PreScoped { headroom } => {
                    anyhow::ensure!(
                        headroom.is_finite() && *headroom > 0.0 && *headroom <= 1.0,
                        "prescoped headroom must be in (0, 1]"
                    );
                }
                PolicySpec::Reactive(p) => {
                    anyhow::ensure!(
                        p.scale_up_at.is_finite() && p.scale_up_at > 0.0,
                        "reactive scale_up_at must be finite and > 0"
                    );
                    anyhow::ensure!(
                        p.scale_down_at.is_finite()
                            && p.scale_down_at >= 0.0
                            && p.scale_down_at < p.scale_up_at,
                        "reactive scale_down_at must be in [0, scale_up_at)"
                    );
                    anyhow::ensure!(
                        p.migration_usd.is_finite() && p.migration_usd >= 0.0,
                        "reactive migration_usd must be finite and ≥ 0"
                    );
                }
                PolicySpec::Predictive(p) => {
                    anyhow::ensure!(p.horizon_epochs >= 1, "predictive horizon must be ≥ 1");
                    anyhow::ensure!(
                        p.headroom.is_finite() && p.headroom > 0.0 && p.headroom <= 1.0,
                        "predictive headroom must be in (0, 1]"
                    );
                    anyhow::ensure!(
                        p.scale_down_at.is_finite()
                            && p.scale_down_at >= 0.0
                            && p.scale_down_at < p.headroom,
                        "predictive scale_down_at must be in [0, headroom)"
                    );
                    anyhow::ensure!(
                        p.migration_usd.is_finite() && p.migration_usd >= 0.0,
                        "predictive migration_usd must be finite and ≥ 0"
                    );
                }
            }
        }
        Ok(())
    }

    /// Parse a scenario from its JSON form. Missing keys take defaults; a
    /// present-but-malformed key is an error, never a silent fallback
    /// (the same rule as the sweep/config parsers).
    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioSpec> {
        anyhow::ensure!(j.as_obj().is_some(), "scenario must be a JSON object");
        let mut s = ScenarioSpec::default();
        if let Some(v) = j.get("name") {
            s.name = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("scenario.name must be a string"))?
                .to_string();
        }
        if let Some(v) = j.get("seed") {
            let f = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("scenario.seed must be a number"))?;
            anyhow::ensure!(
                f >= 0.0 && f.fract() == 0.0 && f <= 9_007_199_254_740_992.0,
                "scenario.seed must be a non-negative integer ≤ 2^53"
            );
            s.seed = f as u64;
        }
        if let Some(v) = opt_usize(j, "epochs", "scenario")? {
            s.epochs = v;
        }
        if let Some(v) = opt_f64(j, "hours_per_epoch", "scenario")? {
            s.hours_per_epoch = v;
        }
        if let Some(a) = j.get("arrivals") {
            anyhow::ensure!(a.as_obj().is_some(), "scenario.arrivals must be an object");
            if let Some(v) = opt_usize(a, "initial", "arrivals")? {
                s.arrivals.initial = v;
            }
            if let Some(v) = opt_f64(a, "rate_per_epoch", "arrivals")? {
                s.arrivals.rate_per_epoch = v;
            }
            if let Some(v) = opt_usize(a, "max_tenants", "arrivals")? {
                s.arrivals.max_tenants = v;
            }
        }
        if let Some(d) = j.get("demand") {
            s.demand = demand_from_json(d)?;
        }
        match j.get("workload") {
            None | Some(Json::Null) => {}
            Some(w) => s.workload = Some(workload_from_json(w)?),
        }
        if let Some(p) = j.get("policies") {
            let arr = p
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("scenario.policies must be an array"))?;
            s.policies = arr.iter().map(policy_from_json).collect::<Result<_, _>>()?;
        }
        Ok(s)
    }

    /// Serialise to the JSON form accepted by [`ScenarioSpec::from_json`]
    /// (run provenance, config round-trips).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("hours_per_epoch", Json::Num(self.hours_per_epoch)),
            (
                "arrivals",
                Json::obj(vec![
                    ("initial", Json::Num(self.arrivals.initial as f64)),
                    ("rate_per_epoch", Json::Num(self.arrivals.rate_per_epoch)),
                    ("max_tenants", Json::Num(self.arrivals.max_tenants as f64)),
                ]),
            ),
            ("demand", demand_to_json(&self.demand)),
            (
                "policies",
                Json::Arr(self.policies.iter().map(policy_to_json).collect()),
            ),
        ];
        if let Some(w) = &self.workload {
            fields.push(("workload", workload_to_json(w)));
        }
        Json::obj(fields)
    }
}

fn opt_usize(j: &Json, key: &str, ctx: &str) -> anyhow::Result<Option<usize>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{ctx}.{key} must be a non-negative integer")),
    }
}

fn opt_f64(j: &Json, key: &str, ctx: &str) -> anyhow::Result<Option<f64>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{ctx}.{key} must be a number")),
    }
}

fn demand_from_json(d: &Json) -> anyhow::Result<DemandSpec> {
    anyhow::ensure!(d.as_obj().is_some(), "scenario.demand must be an object");
    let mut out = DemandSpec::default();
    if let Some(v) = opt_f64(d, "base", "demand")? {
        out.base = v;
    }
    if let Some(v) = opt_f64(d, "growth_per_epoch", "demand")? {
        out.growth_per_epoch = v;
    }
    if let Some(v) = opt_f64(d, "jitter", "demand")? {
        out.jitter = v;
    }
    if let Some(k) = d.get("kind") {
        let k = k
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("demand.kind must be a string"))?;
        out.kind = match k {
            "constant" => DemandKind::Constant,
            "steps" => DemandKind::Steps {
                every: opt_usize(d, "step_every", "demand")?.unwrap_or(30),
            },
            "diurnal" => DemandKind::Diurnal {
                amplitude: opt_f64(d, "amplitude", "demand")?.unwrap_or(0.4),
                period: opt_usize(d, "period_epochs", "demand")?.unwrap_or(7),
            },
            "flash" => DemandKind::Flash {
                spike: opt_f64(d, "spike", "demand")?.unwrap_or(4.0),
                every: opt_usize(d, "spike_every", "demand")?.unwrap_or(90),
                width: opt_usize(d, "spike_width", "demand")?.unwrap_or(2),
            },
            other => anyhow::bail!(
                "demand.kind must be constant|steps|diurnal|flash, got '{other}'"
            ),
        };
    }
    Ok(out)
}

fn demand_to_json(d: &DemandSpec) -> Json {
    let mut fields = vec![
        ("base", Json::Num(d.base)),
        ("growth_per_epoch", Json::Num(d.growth_per_epoch)),
        ("jitter", Json::Num(d.jitter)),
    ];
    match d.kind {
        DemandKind::Constant => fields.push(("kind", Json::Str("constant".into()))),
        DemandKind::Steps { every } => {
            fields.push(("kind", Json::Str("steps".into())));
            fields.push(("step_every", Json::Num(every as f64)));
        }
        DemandKind::Diurnal { amplitude, period } => {
            fields.push(("kind", Json::Str("diurnal".into())));
            fields.push(("amplitude", Json::Num(amplitude)));
            fields.push(("period_epochs", Json::Num(period as f64)));
        }
        DemandKind::Flash { spike, every, width } => {
            fields.push(("kind", Json::Str("flash".into())));
            fields.push(("spike", Json::Num(spike)));
            fields.push(("spike_every", Json::Num(every as f64)));
            fields.push(("spike_width", Json::Num(width as f64)));
        }
    }
    Json::obj(fields)
}

fn workload_from_json(w: &Json) -> anyhow::Result<WorkloadSpec> {
    anyhow::ensure!(w.as_obj().is_some(), "scenario.workload must be an object");
    let mut base = Workload::customer_a();
    if let Some(v) = opt_usize(w, "signals", "workload")? {
        base.n_signals = v;
    }
    if let Some(v) = opt_usize(w, "memvecs", "workload")? {
        base.n_memvec = v;
    }
    if let Some(v) = opt_f64(w, "obs_per_sec", "workload")? {
        base.obs_per_sec = v;
    }
    if let Some(v) = opt_usize(w, "train_window", "workload")? {
        base.train_window = v;
    }
    let mut drift = WorkloadDrift::default();
    if let Some(d) = w.get("drift") {
        anyhow::ensure!(d.as_obj().is_some(), "workload.drift must be an object");
        if let Some(v) = opt_f64(d, "signals_growth", "drift")? {
            drift.signals_growth = v;
        }
        if let Some(v) = opt_f64(d, "memvecs_growth", "drift")? {
            drift.memvecs_growth = v;
        }
    }
    Ok(WorkloadSpec { base, drift })
}

fn workload_to_json(w: &WorkloadSpec) -> Json {
    Json::obj(vec![
        ("signals", Json::Num(w.base.n_signals as f64)),
        ("memvecs", Json::Num(w.base.n_memvec as f64)),
        ("obs_per_sec", Json::Num(w.base.obs_per_sec)),
        ("train_window", Json::Num(w.base.train_window as f64)),
        (
            "drift",
            Json::obj(vec![
                ("signals_growth", Json::Num(w.drift.signals_growth)),
                ("memvecs_growth", Json::Num(w.drift.memvecs_growth)),
            ]),
        ),
    ])
}

fn policy_from_json(p: &Json) -> anyhow::Result<PolicySpec> {
    anyhow::ensure!(p.as_obj().is_some(), "each policy must be an object");
    let kind = p
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("policy.kind must be a string"))?;
    match kind {
        "prescoped" => Ok(PolicySpec::PreScoped {
            headroom: opt_f64(p, "headroom", "policy")?.unwrap_or(0.8),
        }),
        "reactive" => {
            let d = ElasticPolicy::default();
            Ok(PolicySpec::Reactive(ElasticPolicy {
                scale_up_at: opt_f64(p, "scale_up_at", "policy")?.unwrap_or(d.scale_up_at),
                scale_down_at: opt_f64(p, "scale_down_at", "policy")?
                    .unwrap_or(d.scale_down_at),
                scale_lag_epochs: opt_usize(p, "scale_lag_epochs", "policy")?
                    .unwrap_or(d.scale_lag_epochs),
                migration_usd: opt_f64(p, "migration_usd", "policy")?
                    .unwrap_or(d.migration_usd),
            }))
        }
        "predictive" => {
            let d = PredictivePolicy::default();
            Ok(PolicySpec::Predictive(PredictivePolicy {
                horizon_epochs: opt_usize(p, "horizon_epochs", "policy")?
                    .unwrap_or(d.horizon_epochs),
                headroom: opt_f64(p, "headroom", "policy")?.unwrap_or(d.headroom),
                scale_down_at: opt_f64(p, "scale_down_at", "policy")?
                    .unwrap_or(d.scale_down_at),
                scale_lag_epochs: opt_usize(p, "scale_lag_epochs", "policy")?
                    .unwrap_or(d.scale_lag_epochs),
                migration_usd: opt_f64(p, "migration_usd", "policy")?
                    .unwrap_or(d.migration_usd),
            }))
        }
        other => anyhow::bail!(
            "policy.kind must be prescoped|reactive|predictive, got '{other}'"
        ),
    }
}

fn policy_to_json(p: &PolicySpec) -> Json {
    match p {
        PolicySpec::PreScoped { headroom } => Json::obj(vec![
            ("kind", Json::Str("prescoped".into())),
            ("headroom", Json::Num(*headroom)),
        ]),
        PolicySpec::Reactive(p) => Json::obj(vec![
            ("kind", Json::Str("reactive".into())),
            ("scale_up_at", Json::Num(p.scale_up_at)),
            ("scale_down_at", Json::Num(p.scale_down_at)),
            ("scale_lag_epochs", Json::Num(p.scale_lag_epochs as f64)),
            ("migration_usd", Json::Num(p.migration_usd)),
        ]),
        PolicySpec::Predictive(p) => Json::obj(vec![
            ("kind", Json::Str("predictive".into())),
            ("horizon_epochs", Json::Num(p.horizon_epochs as f64)),
            ("headroom", Json::Num(p.headroom)),
            ("scale_down_at", Json::Num(p.scale_down_at)),
            ("scale_lag_epochs", Json::Num(p.scale_lag_epochs as f64)),
            ("migration_usd", Json::Num(p.migration_usd)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates_and_roundtrips() {
        let spec = ScenarioSpec::default();
        spec.validate().unwrap();
        let j = spec.to_json();
        let back = ScenarioSpec::from_json(&j).unwrap();
        back.validate().unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.epochs, spec.epochs);
        assert_eq!(back.demand, spec.demand);
        assert_eq!(back.arrivals, spec.arrivals);
        assert_eq!(back.policies.len(), spec.policies.len());
        // the round-trip is a fixed point of the JSON encoding
        assert_eq!(back.to_json(), j);
    }

    #[test]
    fn parses_every_demand_kind_and_policy() {
        let j = Json::parse(
            r#"{
              "name": "full", "seed": 3, "epochs": 50, "hours_per_epoch": 12,
              "arrivals": {"initial": 5, "rate_per_epoch": 1.5, "max_tenants": 40},
              "demand": {"kind": "flash", "base": 1.0, "spike": 6.0,
                         "spike_every": 10, "spike_width": 2, "jitter": 0.1},
              "workload": {"signals": 4, "memvecs": 16, "obs_per_sec": 2.0,
                           "train_window": 64,
                           "drift": {"signals_growth": 1.001, "memvecs_growth": 1.002}},
              "policies": [
                {"kind": "prescoped", "headroom": 0.7},
                {"kind": "reactive", "scale_up_at": 0.9, "scale_lag_epochs": 3},
                {"kind": "predictive", "horizon_epochs": 5}
              ]
            }"#,
        )
        .unwrap();
        let s = ScenarioSpec::from_json(&j).unwrap();
        s.validate().unwrap();
        assert_eq!(s.epochs, 50);
        assert!(matches!(s.demand.kind, DemandKind::Flash { width: 2, .. }));
        let w = s.workload.unwrap();
        assert_eq!(w.base.n_memvec, 16);
        assert!((w.drift.memvecs_growth - 1.002).abs() < 1e-12);
        assert_eq!(s.policies.len(), 3);
        assert!(s.policies[2].label().contains("predictive"));
        // diurnal + steps parse too
        let j = Json::parse(
            r#"{"demand": {"kind": "diurnal", "amplitude": 0.2, "period_epochs": 14}}"#,
        )
        .unwrap();
        let s = ScenarioSpec::from_json(&j).unwrap();
        assert!(matches!(
            s.demand.kind,
            DemandKind::Diurnal { period: 14, .. }
        ));
        let j = Json::parse(r#"{"demand": {"kind": "steps", "step_every": 9}}"#).unwrap();
        let s = ScenarioSpec::from_json(&j).unwrap();
        assert!(matches!(s.demand.kind, DemandKind::Steps { every: 9 }));
    }

    #[test]
    fn malformed_keys_are_errors_not_defaults() {
        for bad in [
            r#"{"epochs": "many"}"#,
            r#"{"demand": {"kind": "sawtooth"}}"#,
            r#"{"demand": {"base": "big"}}"#,
            r#"{"policies": [{"kind": "magic"}]}"#,
            r#"{"policies": "all"}"#,
            r#"{"arrivals": {"initial": -1}}"#,
            r#"{"workload": {"drift": {"signals_growth": "fast"}}}"#,
            r#"{"seed": 1.5}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ScenarioSpec::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn validation_catches_bad_specs() {
        let mut s = ScenarioSpec {
            epochs: 0,
            ..ScenarioSpec::default()
        };
        assert!(s.validate().is_err());
        s.epochs = 10;
        s.policies.clear();
        assert!(s.validate().is_err());
        s.policies = vec![PolicySpec::PreScoped { headroom: 1.5 }];
        assert!(s.validate().is_err());
        s.policies = vec![PolicySpec::PreScoped { headroom: 0.8 }];
        s.demand.kind = DemandKind::Flash {
            spike: 2.0,
            every: 4,
            width: 9,
        };
        assert!(s.validate().is_err(), "spike wider than its period");
        s.demand.kind = DemandKind::Constant;
        s.arrivals.initial = 99;
        s.arrivals.max_tenants = 10;
        assert!(s.validate().is_err());
    }
}
