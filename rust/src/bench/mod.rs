//! Micro-benchmark framework (offline substitute for `criterion`).
//!
//! Bench targets in `benches/` are built with `harness = false` and drive
//! this module. It provides warm-up, adaptive iteration-count selection,
//! robust statistics, a text table and CSV export into `results/`.

pub mod figs;

use crate::util::Summary;
use std::time::{Duration, Instant};

/// One benchmark measurement run.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label (row name in tables/CSV).
    pub name: String,
    /// Per-iteration wall time.
    pub stats: Summary,
    /// Iterations actually timed.
    pub iters: usize,
    /// Optional work units per iteration (for throughput reporting).
    pub units: Option<f64>,
}

impl Measurement {
    /// Work units per second (if `units` was set).
    pub fn throughput(&self) -> Option<f64> {
        self.units.map(|u| u / self.stats.median)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    /// Warm-up time before measuring.
    pub warmup: Duration,
    /// Target total measuring time.
    pub measure: Duration,
    /// Minimum number of timed samples.
    pub min_samples: usize,
    /// Maximum number of timed samples.
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_samples: 5,
            max_samples: 200,
        }
    }
}

impl Bencher {
    /// Fast profile for CI / tests.
    pub fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(100),
            min_samples: 3,
            max_samples: 30,
        }
    }

    /// Time `f`, one sample per call, until the time budget or sample cap
    /// is reached. The closure's result is black-boxed.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        // Warm-up.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            stats: Summary::of(&samples),
            iters: samples.len(),
            units: None,
        }
    }

    /// Like [`Bencher::run`], attaching a work-unit count for throughput
    /// reporting.
    pub fn run_with_units<R>(
        &self,
        name: &str,
        units: f64,
        f: impl FnMut() -> R,
    ) -> Measurement {
        let mut m = self.run(name, f);
        m.units = Some(units);
        m
    }
}

/// `std::hint::black_box` wrapper.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render measurements as an aligned text table.
pub fn table(measurements: &[Measurement]) -> String {
    let mut out = String::new();
    let name_w = measurements
        .iter()
        .map(|m| m.name.len())
        .max()
        .unwrap_or(4)
        .max(4);
    out.push_str(&format!(
        "{:<name_w$}  {:>12} {:>12} {:>12} {:>8} {:>14}\n",
        "name", "median", "mean", "p75", "samples", "throughput"
    ));
    for m in measurements {
        let thr = m
            .throughput()
            .map(|t| format!("{:.3e}/s", t))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<name_w$}  {:>12} {:>12} {:>12} {:>8} {:>14}\n",
            m.name,
            fmt_secs(m.stats.median),
            fmt_secs(m.stats.mean),
            fmt_secs(m.stats.p75),
            m.iters,
            thr
        ));
    }
    out
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Write a CSV of measurements under `results/`.
pub fn write_csv(path: &str, measurements: &[Measurement]) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut s = String::from("name,median_s,mean_s,std_s,min_s,max_s,samples,units\n");
    for m in measurements {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            m.name,
            m.stats.median,
            m.stats.mean,
            m.stats.std,
            m.stats.min,
            m.stats.max,
            m.iters,
            m.units.map(|u| u.to_string()).unwrap_or_default()
        ));
    }
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_min_samples() {
        let b = Bencher::quick();
        let m = b.run("noop", || 1 + 1);
        assert!(m.iters >= 3);
        assert!(m.stats.median >= 0.0);
    }

    #[test]
    fn throughput_computed() {
        let b = Bencher::quick();
        let m = b.run_with_units("spin", 1000.0, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn table_and_csv() {
        let b = Bencher::quick();
        let ms = vec![b.run("a", || ()), b.run_with_units("b", 10.0, || ())];
        let t = table(&ms);
        assert!(t.contains("a") && t.contains("b") && t.contains("median"));
        let path = std::env::temp_dir().join("cs_bench_test.csv");
        write_csv(path.to_str().unwrap(), &ms).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.lines().count() == 3);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-6).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }
}
