"""L2 correctness: the AOT-shipped graphs vs numpy oracles.

Checks the full training graph (masked similarity + Newton–Schulz inverse)
and both surveillance graphs, including the padding/masking contract the
Rust bucket router depends on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def rand(shape, seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(scale * rng.randn(*shape), jnp.float32)


def bw_of(n):
    return jnp.asarray([ref.bandwidth(n)], jnp.float32)


def full_mask(m):
    return jnp.ones((m,), jnp.float32)


# --------------------------------------------------------------- training --


def test_train_inverse_residual_small():
    m, n = 64, 8
    d = rand((m, n), 0)
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    a = np.asarray(ref.masked_similarity(d, full_mask(m), bw_of(n)), np.float64)
    a += ref.RIDGE_REL * np.eye(m)
    resid = np.abs(np.asarray(g, np.float64) @ a - np.eye(m)).max()
    # Limited by f32 similarity rounding amplified by cond(A), not by NS.
    assert resid < 5e-3, f"inverse residual {resid}"


def test_train_matches_numpy_inverse():
    """G must match numpy's direct inverse of the same f32 similarity."""
    m, n = 48, 6
    d = rand((m, n), 1)
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    a = np.asarray(
        ref.masked_similarity(d, full_mask(m), bw_of(n)), np.float64
    ) + ref.RIDGE_REL * np.eye(m)
    g_np = np.linalg.inv(a)
    rel = np.abs(np.asarray(g, np.float64) - g_np).max() / np.abs(g_np).max()
    assert rel < 1e-4, f"relative error vs numpy inverse {rel}"


def test_ns_inverse_converges_on_worst_bucket():
    """Conditioning worst case: near-duplicate memory vectors (λ_min → λ).

    The check runs against the similarity matrix the graph *actually*
    inverted (the Pallas f32 one): on near-duplicate vectors the f32
    Gram-trick perturbs S by ~1e-3, and cond(A) ≈ 1/λ amplifies any ΔS —
    an inherent f32-kernel property shared with the paper's CUDA version,
    not an NS convergence failure (see DESIGN.md §4 numerics note).
    """
    from compile.kernels.similarity import sim_pallas

    m, n = 96, 4
    base = rand((m // 2, n), 2)
    d = jnp.concatenate([base, base + 1e-4 * rand((m // 2, n), 3)], axis=0)
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    s = sim_pallas(d, d, bw_of(n))
    s = s - jnp.diag(jnp.diagonal(s)) + jnp.eye(m, dtype=s.dtype)
    a = np.asarray(s, np.float64) + ref.RIDGE_REL * np.eye(m)
    resid = np.abs(np.asarray(g, np.float64) @ a - np.eye(m)).max()
    assert resid < 1e-3, f"NS failed to converge: residual {resid}"


@given(m=st.sampled_from([16, 32, 64]), n=st.sampled_from([4, 8]), seed=st.integers(0, 10**6))
def test_train_g_symmetric(m, n, seed):
    d = rand((m, n), seed)
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    g = np.asarray(g)
    assert np.abs(g - g.T).max() < 1e-3 * np.abs(g).max()


def test_train_padding_is_block_diagonal():
    """Padded memory rows must not influence the real block of G."""
    m_real, m_pad, n = 24, 40, 6
    d_real = rand((m_real, n), 4)
    (g_small,) = model.mset2_train(d_real, full_mask(m_real), bw_of(n))
    d_pad = jnp.pad(d_real, ((0, m_pad - m_real), (0, 0)))
    mask = jnp.concatenate(
        [jnp.ones((m_real,)), jnp.zeros((m_pad - m_real,))]
    ).astype(jnp.float32)
    (g_pad,) = model.mset2_train(d_pad, mask, bw_of(n))
    np.testing.assert_allclose(
        np.asarray(g_pad)[:m_real, :m_real], np.asarray(g_small), atol=1e-4
    )
    # off-diagonal blocks are exactly zero
    off = np.abs(np.asarray(g_pad)[:m_real, m_real:]).max()
    assert off < 1e-6, f"padding leaked into G: {off}"


# ------------------------------------------------------------ surveillance --


def test_surveil_matches_ref_graph():
    m, n, b = 64, 8, 32
    d = rand((m, n), 5)
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    x = rand((b, n), 6)
    xh, r = model.mset2_surveil(d, g, full_mask(m), bw_of(n), x)
    xh_r, r_r = model.mset2_surveil_ref(d, g, full_mask(m), bw_of(n), x)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(xh_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_r), atol=1e-5)


def test_surveil_memory_vectors_reconstructed():
    """Observations that are memory vectors reconstruct near-exactly."""
    m, n = 48, 6
    d = rand((m, n), 7)
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    xh, r = model.mset2_surveil(d, g, full_mask(m), bw_of(n), d[:16])
    assert np.abs(np.asarray(r)).max() < 0.05


def test_surveil_padding_full_contract():
    """Pad n and m simultaneously: real outputs must match the unpadded
    graph — the exact contract runtime::router relies on."""
    m_r, m_p, n_r, n_p, b = 20, 32, 5, 8, 12
    d = rand((m_r, n_r), 8)
    x = rand((b, n_r), 9)
    bw = bw_of(n_r)  # bandwidth stays at n_real
    (g,) = model.mset2_train(d, full_mask(m_r), bw)
    xh_small, r_small = model.mset2_surveil(d, g, full_mask(m_r), bw, x)

    dp = jnp.pad(d, ((0, m_p - m_r), (0, n_p - n_r)))
    xp = jnp.pad(x, ((0, 0), (0, n_p - n_r)))
    mask = jnp.concatenate([jnp.ones((m_r,)), jnp.zeros((m_p - m_r,))]).astype(
        jnp.float32
    )
    (gp,) = model.mset2_train(dp, mask, bw)
    xh_pad, r_pad = model.mset2_surveil(dp, gp, mask, bw, xp)
    np.testing.assert_allclose(
        np.asarray(xh_pad)[:, :n_r], np.asarray(xh_small), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(r_pad)[:, :n_r], np.asarray(r_small), atol=1e-4
    )


def test_surveil_healthy_residual_smaller_than_shifted():
    m, n, b = 64, 8, 32
    rng = np.random.RandomState(10)
    base = rng.randn(400, n).astype(np.float32)
    d = jnp.asarray(base[:m])
    (g,) = model.mset2_train(d, full_mask(m), bw_of(n))
    healthy = jnp.asarray(base[m : m + b])
    shifted = healthy + 4.0
    _, r_h = model.mset2_surveil(d, g, full_mask(m), bw_of(n), healthy)
    _, r_s = model.mset2_surveil(d, g, full_mask(m), bw_of(n), shifted)
    assert np.abs(np.asarray(r_s)).mean() > 2.0 * np.abs(np.asarray(r_h)).mean()


# ------------------------------------------------------------------- AAKR --


def test_aakr_matches_ref():
    m, n, b = 32, 8, 16
    d = rand((m, n), 11)
    x = rand((b, n), 12)
    xh, r = model.aakr_surveil(d, full_mask(m), bw_of(n), x)
    xh_r, r_r = model.aakr_surveil_ref(d, full_mask(m), bw_of(n), x)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(xh_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_r), atol=1e-5)


def test_aakr_estimate_in_memory_hull():
    """AAKR output is a convex combination of memory vectors."""
    m, n, b = 24, 4, 8
    d = rand((m, n), 13)
    x = rand((b, n), 14)
    xh, _ = model.aakr_surveil(d, full_mask(m), bw_of(n), x)
    lo = np.asarray(d).min(axis=0) - 1e-5
    hi = np.asarray(d).max(axis=0) + 1e-5
    xh = np.asarray(xh)
    assert (xh >= lo).all() and (xh <= hi).all()


def test_aakr_padding_contract():
    m_r, m_p, n = 16, 32, 4
    d = rand((m_r, n), 15)
    x = rand((8, n), 16)
    bw = bw_of(n)
    xh_small, _ = model.aakr_surveil(d, full_mask(m_r), bw, x)
    dp = jnp.pad(d, ((0, m_p - m_r), (0, 0)))
    mask = jnp.concatenate([jnp.ones((m_r,)), jnp.zeros((m_p - m_r,))]).astype(
        jnp.float32
    )
    xh_pad, _ = model.aakr_surveil(dp, mask, bw, x)
    np.testing.assert_allclose(np.asarray(xh_pad), np.asarray(xh_small), atol=1e-5)
