//! **MSET2** — Multivariate State Estimation Technique, native Rust
//! implementation.
//!
//! This is the "pluggable ML prognostic algorithm" the paper's case study
//! scopes (§II.B). The native implementation serves three roles:
//!
//! 1. **numerical oracle** for the AOT/XLA device path (`runtime`) — the
//!    integration tests require device results to match this module;
//! 2. **data preparation** — memory-vector selection and z-scaling run once
//!    per training set and are not on the streaming hot path;
//! 3. **pure-CPU comparator** for the kernel ablation bench.
//!
//! Pipeline (see DESIGN.md §4):
//! `scale → select D → S = Dᵀ⊗D → G = (S+λI)⁻¹ → (stream) X̂ = D·G·(Dᵀ⊗x)`.
//!
//! The similarity operator ⊗ and all constants are shared with the L1/L2
//! Python definitions (`python/compile/kernels/ref.py`); changing one side
//! requires changing the other — the cross-layer tests will catch drift.

pub mod select;
pub mod similarity;

use crate::linalg::{kernel, reg_pinv_into, Mat, Workspace};

pub use select::select_memory;
pub use similarity::{
    sim, sim_cross, sim_cross_gram, sim_cross_into, sim_cross_ref, sim_cross_t_into,
    sim_matrix, sim_matrix_into, sim_matrix_ref, GAMMA,
};

/// Per-signal affine scaler (z-score using training statistics).
#[derive(Clone, Debug)]
pub struct Scaler {
    /// Per-signal mean of the training data.
    pub mean: Vec<f64>,
    /// Per-signal standard deviation (≥ tiny epsilon).
    pub std: Vec<f64>,
}

impl Scaler {
    /// Fit on training data (rows = observations).
    pub fn fit(x: &Mat) -> Scaler {
        let n = x.cols;
        let t = x.rows as f64;
        let mut mean = vec![0.0; n];
        for r in 0..x.rows {
            for (j, v) in x.row(r).iter().enumerate() {
                mean[j] += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= t;
        }
        let mut var = vec![0.0; n];
        for r in 0..x.rows {
            for (j, v) in x.row(r).iter().enumerate() {
                let d = v - mean[j];
                var[j] += d * d;
            }
        }
        let std = var
            .into_iter()
            .map(|v| (v / t).sqrt().max(1e-9))
            .collect();
        Scaler { mean, std }
    }

    /// Standardise `x` column-wise with the fitted statistics.
    pub fn transform(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.transform_into(x, &mut out);
        out
    }

    /// [`Scaler::transform`] into a caller-owned matrix — the streaming
    /// hot path standardises every probe chunk, so reusing one buffer
    /// keeps the allocator off the §II.D loop.
    pub fn transform_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.cols, self.mean.len());
        out.reshape(x.rows, x.cols);
        if x.cols == 0 {
            return;
        }
        for (orow, xrow) in out
            .data
            .chunks_exact_mut(x.cols)
            .zip(x.data.chunks_exact(x.cols))
        {
            for ((o, &v), (&m, &s)) in orow
                .iter_mut()
                .zip(xrow)
                .zip(self.mean.iter().zip(&self.std))
            {
                *o = (v - m) / s;
            }
        }
    }

    /// Undo scaling (for reporting estimates in engineering units).
    pub fn inverse(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for j in 0..row.len() {
                row[j] = row[j] * self.std[j] + self.mean[j];
            }
        }
        out
    }
}

/// A trained MSET2 model.
#[derive(Clone, Debug)]
pub struct MsetModel {
    /// Memory matrix, `m × n` (row = one memory vector, scaled units).
    pub d: Mat,
    /// `(S + λI)⁻¹`, `m × m`.
    pub g: Mat,
    /// The scaler fitted on the training data (applied to probes).
    pub scaler: Scaler,
    /// Regularisation actually applied.
    pub lambda: f64,
}

/// Ridge regularisation scale: λ = RIDGE_REL · tr(S)/m.
pub const RIDGE_REL: f64 = 1e-3;

/// Train MSET2: scale, select `m` memory vectors, build `G`.
///
/// Enforces the paper's training constraint `m ≥ 2·n_signals` (Fig. 6 note);
/// violations return an error so the sweep engine can emit surface gaps.
pub fn train(x_train: &Mat, m: usize) -> anyhow::Result<MsetModel> {
    let n = x_train.cols;
    anyhow::ensure!(
        m >= 2 * n,
        "MSET training constraint violated: m={m} < 2·n_signals={}",
        2 * n
    );
    anyhow::ensure!(
        m <= x_train.rows,
        "cannot select {m} memory vectors from {} observations",
        x_train.rows
    );
    let scaler = Scaler::fit(x_train);
    let xs = scaler.transform(x_train);
    let idx = select_memory(&xs, m);
    let mut d = Mat::zeros(m, n);
    for (r, &i) in idx.iter().enumerate() {
        d.row_mut(r).copy_from_slice(xs.row(i));
    }
    let (g, lambda) = train_from_memory(&d);
    Ok(MsetModel {
        d,
        g,
        scaler,
        lambda,
    })
}

/// Build `G = (S + λI)⁻¹` from an already-selected memory matrix (scaled).
/// Exposed separately so the device path can reuse the exact same D.
///
/// Runs entirely on the blocked kernel core with workspace-backed
/// scratch: once a worker's arena is warm, the only allocation left is
/// the returned `G` itself.
pub fn train_from_memory(d: &Mat) -> (Mat, f64) {
    Workspace::with(|ws| {
        let m = d.rows;
        let mut s = Mat {
            rows: 0,
            cols: 0,
            data: ws.take_f64(0),
        };
        sim_matrix_into(&mut s, d, ws);
        let trace: f64 = (0..m).map(|i| s[(i, i)]).sum();
        let lambda = RIDGE_REL * trace / m as f64;
        for i in 0..m {
            s[(i, i)] += lambda;
        }
        // reg_pinv applies the eigenvalue floor; λ already added on the
        // diagonal. The syrk-based reconstruction makes G exactly
        // symmetric, which `surveil_scaled` exploits.
        let mut g = Mat::zeros(0, 0);
        reg_pinv_into(&mut g, &s, 0.0, ws);
        ws.give_f64(s.data);
        (g, lambda)
    })
}

/// Surveillance result for a chunk of observations.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Estimated observations (scaled units), rows = observations.
    pub xhat: Mat,
    /// Residuals `x − x̂` (scaled units).
    pub resid: Mat,
}

impl Default for Estimate {
    /// Empty estimate — a reusable output slot for the `_into` APIs.
    fn default() -> Estimate {
        Estimate {
            xhat: Mat::zeros(0, 0),
            resid: Mat::zeros(0, 0),
        }
    }
}

impl MsetModel {
    /// Number of signals the model was trained on.
    pub fn n_signals(&self) -> usize {
        self.d.cols
    }

    /// Number of memory vectors selected at training time.
    pub fn n_memvec(&self) -> usize {
        self.d.rows
    }

    /// Estimate a chunk of raw observations (rows = observations).
    pub fn surveil(&self, x_raw: &Mat) -> Estimate {
        Workspace::with(|ws| {
            let mut xs = Mat {
                rows: 0,
                cols: 0,
                data: ws.take_f64(0),
            };
            self.scaler.transform_into(x_raw, &mut xs);
            let mut est = Estimate::default();
            self.surveil_scaled_ws(&xs, &mut est, ws);
            ws.give_f64(xs.data);
            est
        })
    }

    /// Estimate a chunk already in scaled units — the exact computation the
    /// L2 graph performs on device.
    pub fn surveil_scaled(&self, xs: &Mat) -> Estimate {
        let mut est = Estimate::default();
        self.surveil_scaled_into(xs, &mut est);
        est
    }

    /// [`MsetModel::surveil_scaled`] into a caller-owned [`Estimate`]:
    /// with a warm workspace and a reused `out`, the steady-state chunk
    /// loop performs zero heap allocations.
    pub fn surveil_scaled_into(&self, xs: &Mat, out: &mut Estimate) {
        Workspace::with(|ws| self.surveil_scaled_ws(xs, out, ws));
    }

    /// Core surveillance pipeline on the blocked kernel core. Computes
    /// `Kᵀ = sim(X, D)` (`B × m`, each observation's weights contiguous),
    /// `W = Kᵀ·Gᵀ` (`= (G·K)ᵀ`, a no-packing NT product), and
    /// `X̂ = W·D` — the same arithmetic as the classical
    /// `(G·K)ᵀ·D` formulation, element for element.
    fn surveil_scaled_ws(&self, xs: &Mat, out: &mut Estimate, ws: &mut Workspace) {
        assert_eq!(xs.cols, self.d.cols, "signal count mismatch");
        let n = self.d.cols;
        let mut kt = Mat {
            rows: 0,
            cols: 0,
            data: ws.take_f64(0),
        };
        sim_cross_t_into(&mut kt, xs, &self.d, n, ws);
        let mut w = Mat {
            rows: 0,
            cols: 0,
            data: ws.take_f64(0),
        };
        kernel::matmul_nt_into(&mut w, &kt, &self.g, ws);
        kernel::matmul_into(&mut out.xhat, &w, &self.d, ws);
        out.resid.reshape(xs.rows, n);
        for ((r, &x), &h) in out
            .resid
            .data
            .iter_mut()
            .zip(xs.data.iter())
            .zip(out.xhat.data.iter())
        {
            *r = x - h;
        }
        ws.give_f64(w.data);
        ws.give_f64(kt.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpss::{synthesize, TpssConfig};

    fn train_set(n: usize, t: usize, seed: u64) -> Mat {
        synthesize(&TpssConfig::sized(n, t), seed).data
    }

    #[test]
    fn scaler_zero_mean_unit_var() {
        let x = train_set(4, 500, 1);
        let sc = Scaler::fit(&x);
        let xs = sc.transform(&x);
        for j in 0..4 {
            let col: Vec<f64> = xs.col(j).collect();
            let m = crate::tpss::stats::moments(&col);
            assert!(m.mean.abs() < 1e-10);
            assert!((m.var - 1.0).abs() < 1e-8);
        }
        // inverse round-trips
        let back = sc.inverse(&xs);
        assert!(x.max_abs_diff(&back) < 1e-9);
    }

    #[test]
    fn training_constraint_enforced() {
        let x = train_set(8, 200, 2);
        assert!(train(&x, 15).is_err()); // m < 2n
        assert!(train(&x, 16).is_ok());
        assert!(train(&x, 300).is_err()); // m > n_obs
    }

    #[test]
    fn memory_vectors_estimate_themselves() {
        // An observation that IS a memory vector must be reconstructed
        // almost exactly (s(a,a)=1 dominates the weight vector).
        let x = train_set(4, 400, 3);
        let model = train(&x, 32).unwrap();
        let d_raw = model.scaler.inverse(&model.d);
        let est = model.surveil(&d_raw);
        let max_resid = est
            .resid
            .data
            .iter()
            .fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(max_resid < 0.05, "max residual {max_resid}");
    }

    #[test]
    fn healthy_data_small_residuals_faulted_data_large() {
        let cfg = TpssConfig::sized(6, 2000);
        let ds = synthesize(&cfg, 5);
        let model = train(&ds.data, 64).unwrap();

        let healthy = synthesize(&cfg, 6); // same distribution, new draw
        let est_h = model.surveil(&healthy.data);
        let rms_h = est_h.resid.norm() / (est_h.resid.data.len() as f64).sqrt();

        let mut faulted = synthesize(&cfg, 6);
        crate::tpss::inject(
            &mut faulted,
            2,
            crate::tpss::Fault::Step { magnitude: 6.0 },
            0.0,
            7,
        );
        let est_f = model.surveil(&faulted.data);
        let rms_f = est_f.resid.norm() / (est_f.resid.data.len() as f64).sqrt();
        assert!(
            rms_f > 2.0 * rms_h,
            "fault must inflate residuals: healthy={rms_h} faulted={rms_f}"
        );
    }

    #[test]
    fn surveil_shapes() {
        let x = train_set(5, 300, 8);
        let model = train(&x, 24).unwrap();
        let probe = train_set(5, 17, 9);
        let est = model.surveil(&probe);
        assert_eq!(est.xhat.rows, 17);
        assert_eq!(est.xhat.cols, 5);
        assert_eq!(est.resid.rows, 17);
    }

    #[test]
    fn g_is_symmetric() {
        let x = train_set(3, 200, 10);
        let model = train(&x, 12).unwrap();
        let gt = model.g.transpose();
        assert!(model.g.max_abs_diff(&gt) < 1e-8);
    }

    #[test]
    fn surveil_matches_classical_formulation() {
        // the blocked Kᵀ·Gᵀ·D pipeline must agree with the textbook
        // (G·K)ᵀ·D chain built from the reference kernels.
        let x = train_set(5, 400, 11);
        let model = train(&x, 32).unwrap();
        let probe = train_set(5, 64, 12);
        let xs = model.scaler.transform(&probe);
        let est = model.surveil_scaled(&xs);
        let k = sim_cross_ref(&model.d, &xs);
        let w = model.g.matmul(&k);
        let xhat = w.transpose().matmul(&model.d);
        assert!(
            est.xhat.max_abs_diff(&xhat) < 1e-9,
            "pipeline diverged: {}",
            est.xhat.max_abs_diff(&xhat)
        );
    }

    #[test]
    fn surveil_scaled_into_reuses_output() {
        let x = train_set(4, 300, 13);
        let model = train(&x, 24).unwrap();
        let mut est = Estimate::default();
        for rows in [50, 7, 31] {
            let probe = train_set(4, rows, 14);
            let xs = model.scaler.transform(&probe);
            model.surveil_scaled_into(&xs, &mut est);
            let fresh = model.surveil_scaled(&xs);
            assert_eq!(est.xhat, fresh.xhat);
            assert_eq!(est.resid, fresh.resid);
        }
    }
}
