//! Route dispatch for the scoping service's JSON API.
//!
//! ```text
//! POST   /v1/scope                submit a workload + SLA, get a job id
//! POST   /v1/scenarios            submit a fleet what-if scenario replay
//! GET    /v1/jobs/{id}            job status / live progress / summary
//! GET    /v1/jobs/{id}/events     live progress stream
//!                                 (?format=ndjson|sse; ndjson default)
//! GET    /v1/jobs/{id}/trace      ordered span timeline (flight recorder)
//! GET    /v1/jobs/{id}/sweep.csv  per-cell measurement CSV, streamed row-by-row
//! GET    /v1/scenarios/{id}       scenario status / replay progress / outcome
//! GET    /v1/scenarios/{id}/events live replay progress stream (NDJSON/SSE)
//! GET    /v1/scenarios/{id}/trace scenario span timeline (flight recorder)
//! DELETE /v1/jobs/{id}            cancel a queued or running job
//! DELETE /v1/scenarios/{id}       cancel a queued or running scenario
//! GET    /v1/recommendations/{id} rendered shape recommendation (job → rec)
//! GET    /v1/shapes               cloud shape catalog
//! GET    /healthz                 liveness + uptime + queue/scheduler gauges
//! GET    /metrics                 metrics registry
//!                                 (?format=json|text|prometheus; json default)
//! GET    /metrics/stream          live counter/gauge deltas on a heartbeat
//!                                 (?format=ndjson|sse; ndjson default)
//! GET    /v1/slo                  SLO objectives + multi-window burn rates
//! GET    /v1/trace/stream         retired-span firehose, replay-then-follow
//!                                 (?format=ndjson|sse, ?trace_id=… filter)
//! ```
//!
//! The `/events` endpoints stream each job's live event bus (cell
//! retirements, scenario unit completions, a terminal `summary`) as
//! NDJSON — one compact JSON object per line — or, with `?format=sse`,
//! as Server-Sent Events. Subscribing replays the bus's bounded history
//! first, so a late subscriber still sees the whole story of a small job;
//! the stream ends after the terminal event. Heartbeats (a blank NDJSON
//! line / an SSE comment) keep idle streams alive through proxies and
//! surface client disconnects.
//!
//! `POST /v1/scope` body (all keys optional; defaults fill the rest):
//!
//! ```json
//! {
//!   "sweep":     {"signals": [2,3], "memvecs": [8,16], "obs": [16,32],
//!                 "trials": 1, "seed": 9, "model": "mset2", "workers": 2,
//!                 "pilot_trials": 2, "ci_target": 0.25,
//!                 "max_trials": 8, "interpolate": true},
//!   "scheduler": {"weight": 1.0},
//!   "workload":  {"signals": 20, "memvecs": 64,
//!                 "obs_per_sec": 1.0, "train_window": 4096},
//!   "sla":       {"headroom": 2.0, "max_train_s": 3600.0}
//! }
//! ```
//!
//! `ci_target > 0` enables the adaptive sweep planner
//! ([`crate::coordinator::planner`]); omitting it keeps the exhaustive
//! fixed-`trials` sweep. `scheduler.weight` biases the job's fair share
//! of the trial executor. See `docs/API.md` for the full endpoint
//! reference.

use crate::config;
use crate::coordinator::jobs::{JobId, JobStatus, ScopingService};
use crate::coordinator::{SweepResult, SweepSpec};
use crate::metrics::{escape_label_value, Registry};
use crate::obs::slo::SloEngine;
use crate::obs::{BusEvent, FlightRecorder};
use crate::recommend::{recommend_from_sweep, Sla};
use crate::report;
use crate::scenario::ScenarioSpec;
use crate::service::cache::SweepCache;
use crate::service::http::{BodyStream, IterBody, Request, Response};
use crate::shapes::{self, Workload};
use crate::util::json::{stream::StreamEmitter, Json};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Default heartbeat cadence on idle `/events` streams (see
/// [`crate::config::ServiceConfig::stream_heartbeat_ms`]).
pub const DEFAULT_STREAM_HEARTBEAT: Duration = Duration::from_millis(1000);

/// Shared state behind every connection handler: the scoping job queue,
/// the sweep cache, and the per-job scoping context needed to turn a
/// finished sweep into a recommendation.
pub struct ServiceState {
    svc: ScopingService,
    cache: Arc<SweepCache>,
    default_spec: SweepSpec,
    jobs: Mutex<HashMap<JobId, (Workload, Sla)>>,
    /// Heartbeat cadence on idle `/events` streams.
    heartbeat: Duration,
    /// SLO burn-rate engine; `None` when no objectives are configured.
    slo: Option<Arc<SloEngine>>,
}

impl ServiceState {
    /// Assemble the shared state for a service instance.
    pub fn new(svc: ScopingService, cache: Arc<SweepCache>, default_spec: SweepSpec) -> Self {
        ServiceState {
            svc,
            cache,
            default_spec,
            jobs: Mutex::new(HashMap::new()),
            heartbeat: DEFAULT_STREAM_HEARTBEAT,
            slo: None,
        }
    }

    /// Override the heartbeat cadence on idle `/events` streams.
    pub fn with_stream_heartbeat(mut self, heartbeat: Duration) -> Self {
        self.heartbeat = heartbeat.max(Duration::from_millis(10));
        self
    }

    /// Attach the SLO burn-rate engine (serves `GET /v1/slo` and the
    /// `/healthz` summary).
    pub fn with_slo(mut self, slo: Arc<SloEngine>) -> Self {
        self.slo = Some(slo);
        self
    }

    /// The attached SLO engine, when objectives are configured.
    pub fn slo(&self) -> Option<Arc<SloEngine>> {
        self.slo.clone()
    }

    /// The shared cell-level sweep cache.
    pub fn cache(&self) -> &SweepCache {
        &self.cache
    }

    /// Worker threads in the shared trial executor.
    pub fn executor_workers(&self) -> usize {
        self.svc.executor_workers()
    }

    /// Whether fair job interleaving is enabled on the executor.
    pub fn fair_share(&self) -> bool {
        self.svc.fair_share()
    }

    /// The scoping-job service (status/progress/cancel access for
    /// embedders and tests).
    pub fn service(&self) -> &ScopingService {
        &self.svc
    }

    /// Re-attach a resumed job's recommendation context from the `extra`
    /// payload its durable submit journalled (see
    /// [`ScopingService::submit_traced_durable`]): the same
    /// `workload`/`sla` JSON shapes `POST /v1/scope` accepts, so
    /// `GET /v1/recommendations/{id}` answers for the replayed job
    /// exactly as it would have for the lost one.
    pub fn restore_context_json(&self, id: JobId, extra: &Json) -> anyhow::Result<()> {
        let workload = workload_from_json(extra.get("workload"))?;
        let sla = sla_from_json(extra.get("sla"))?;
        self.jobs.lock().unwrap().insert(id, (workload, sla));
        Ok(())
    }

    /// Top-level dispatch (the [`crate::service::http::Handler`] body).
    ///
    /// Besides the global request/error counters, each recognised route
    /// class records `service.route.{class}.seconds` /
    /// `.requests` / `.errors` (5xx only) — the per-route series the SLO
    /// engine's named objectives read.
    pub fn handle(&self, req: &Request) -> Response {
        Registry::global().inc("service.http.requests");
        let segs: Vec<&str> = req
            .path
            .split('/')
            .filter(|s| !s.is_empty())
            .collect();
        let class = route_class(&segs);
        let started = Instant::now();
        let resp = match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["healthz"]) => self.healthz(),
            ("GET", ["metrics"]) => self.metrics(req),
            ("GET", ["metrics", "stream"]) => self.metrics_stream(req),
            ("GET", ["v1", "shapes"]) => shapes_catalog(),
            ("GET", ["v1", "slo"]) => self.slo_status(),
            ("GET", ["v1", "trace", "stream"]) => self.trace_stream(req),
            ("POST", ["v1", "scope"]) => self.scope(req),
            ("POST", ["v1", "scenarios"]) => self.scenario_submit(req),
            ("GET", ["v1", "jobs", id]) => self.job_status(id),
            ("GET", ["v1", "jobs", id, "events"]) => self.job_events(id, req),
            ("GET", ["v1", "jobs", id, "trace"]) => self.job_trace(id),
            ("GET", ["v1", "jobs", id, "sweep.csv"]) => self.job_sweep_csv(id),
            ("GET", ["v1", "scenarios", id]) => self.scenario_status(id),
            ("GET", ["v1", "scenarios", id, "events"]) => self.scenario_events(id, req),
            ("GET", ["v1", "scenarios", id, "trace"]) => self.scenario_trace(id),
            ("DELETE", ["v1", "jobs", id]) | ("DELETE", ["v1", "scenarios", id]) => {
                self.cancel_job(id)
            }
            ("GET", ["v1", "recommendations", id]) => self.recommendation(id),
            (_, ["healthz"])
            | (_, ["metrics"])
            | (_, ["metrics", "stream"])
            | (_, ["v1", "shapes"])
            | (_, ["v1", "slo"])
            | (_, ["v1", "trace", "stream"])
            | (_, ["v1", "scope"])
            | (_, ["v1", "scenarios"])
            | (_, ["v1", "jobs", _])
            | (_, ["v1", "jobs", _, "events"])
            | (_, ["v1", "jobs", _, "trace"])
            | (_, ["v1", "jobs", _, "sweep.csv"])
            | (_, ["v1", "scenarios", _])
            | (_, ["v1", "scenarios", _, "events"])
            | (_, ["v1", "scenarios", _, "trace"])
            | (_, ["v1", "recommendations", _]) => {
                Response::error(405, "method not allowed on this route")
            }
            _ => {
                Registry::global().inc("service.http.not_found");
                Response::error(404, "no such route")
            }
        };
        if resp.status >= 400 {
            Registry::global().inc("service.http.errors");
        }
        if let Some(class) = class {
            let reg = Registry::global();
            reg.sample(
                &format!("service.route.{class}.seconds"),
                started.elapsed().as_secs_f64(),
            );
            reg.inc(&format!("service.route.{class}.requests"));
            if resp.status >= 500 {
                reg.inc(&format!("service.route.{class}.errors"));
            }
        }
        resp
    }

    /// `GET /healthz`: tri-state health (`ok` / `degraded` / `failing`)
    /// with a `reasons` array naming each contributor. Degraded means the
    /// service still serves correct answers with reduced guarantees
    /// (memory-only cache, lossy WAL/journal, SLO warn burn); failing
    /// means the SLO engine is paging and the HTTP front is shedding.
    /// Always 200 — the body, not the status code, carries the verdict,
    /// so liveness probes don't restart a merely degraded node.
    fn healthz(&self) -> Response {
        let kd = crate::linalg::simd::dispatch_info();
        let mut reasons: Vec<String> = Vec::new();
        let mut failing = false;
        if self.cache.is_degraded() {
            reasons.push(match self.cache.degrade_reason() {
                Some(r) => format!("cache degraded: {r}"),
                None => "cache degraded to memory-only".to_string(),
            });
        }
        if let Some(wal) = self.svc.wal() {
            let errs = wal.errors();
            if errs > 0 {
                reasons.push(format!(
                    "job WAL append errors: {errs} (recovery may miss jobs)"
                ));
            }
        }
        if let Some(journal) = crate::obs::sink().journal() {
            let errs = journal.errors();
            if errs > 0 {
                reasons.push(format!("telemetry journal append errors: {errs}"));
            }
        }
        let slo = match &self.slo {
            Some(engine) => {
                let summary = engine.summary();
                match summary.get("status").and_then(Json::as_str) {
                    Some("warn") => {
                        reasons.push("SLO error budget burning at warn rate".to_string());
                    }
                    Some("page") => {
                        failing = true;
                        reasons.push(
                            "SLO error budget burning at page rate (shedding load)"
                                .to_string(),
                        );
                    }
                    _ => {}
                }
                summary
            }
            None => Json::obj(vec![("status", Json::Str("disabled".into()))]),
        };
        let status = if failing {
            "failing"
        } else if !reasons.is_empty() {
            "degraded"
        } else {
            "ok"
        };
        Response::json(
            200,
            &Json::obj(vec![
                ("status", Json::Str(status.into())),
                (
                    "reasons",
                    Json::Arr(reasons.into_iter().map(Json::Str).collect()),
                ),
                ("slo", slo),
                ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
                ("uptime_s", Json::Num(crate::obs::uptime_s())),
                ("jobs_in_flight", Json::Num(self.svc.in_flight() as f64)),
                ("queue_cap", Json::Num(self.svc.queue_cap() as f64)),
                ("cached_cells", Json::Num(self.cache.len() as f64)),
                (
                    "executor_workers",
                    Json::Num(self.svc.executor_workers() as f64),
                ),
                ("fair_share", Json::Bool(self.svc.fair_share())),
                ("kernel_backend", Json::Str(kd.active.isa().into())),
                (
                    "kernel_dispatch",
                    Json::obj(vec![
                        ("requested", Json::Str(kd.requested.as_str().into())),
                        ("source", Json::Str(kd.source.into())),
                        ("mode", Json::Str(kd.active.mode().into())),
                        (
                            "simd_available",
                            Json::Bool(crate::linalg::simd::detect().is_some()),
                        ),
                    ]),
                ),
            ]),
        )
    }

    /// `GET /metrics`: the global registry. Gauges are computed here, at
    /// scrape time, from live service state — nothing on the trial hot
    /// path pays for them.
    fn metrics(&self, req: &Request) -> Response {
        let reg = Registry::global();
        let stats = self.svc.executor_stats();
        reg.set_gauge("executor.queue_depth", stats.queued as f64);
        reg.set_gauge("executor.busy_workers", stats.running as f64);
        reg.set_gauge("executor.busy_fraction", stats.busy_fraction());
        reg.set_gauge("executor.jobs", stats.jobs as f64);
        reg.set_gauge("executor.workers", stats.workers as f64);
        reg.set_gauge("cache.entries", self.cache.len() as f64);
        reg.set_gauge("cache.bytes", self.cache.bytes() as f64);
        let (sweeps, scenarios) = self.svc.in_flight_by_class();
        reg.set_gauge("service.jobs.in_flight.sweep", sweeps as f64);
        reg.set_gauge("service.jobs.in_flight.scenario", scenarios as f64);
        let kd = crate::linalg::simd::dispatch_info();
        reg.set_gauge(
            "kernel.simd_active",
            if kd.active.is_simd() { 1.0 } else { 0.0 },
        );
        match req.query_get("format") {
            None | Some("json") => Response::json(200, &reg.to_json()),
            Some("text") => Response::text(200, reg.render()),
            Some("prometheus") => {
                // Prometheus info-metric idiom: constant-1 gauge whose
                // labels carry the dispatch decision.
                let mut body = reg.render_prometheus();
                body.push_str("# HELP kernel_backend_info active linalg kernel tier\n");
                body.push_str("# TYPE kernel_backend_info gauge\n");
                body.push_str(&format!(
                    "kernel_backend_info{{kernel_backend=\"{}\",mode=\"{}\"}} 1\n",
                    escape_label_value(kd.active.isa()),
                    escape_label_value(kd.active.mode())
                ));
                Response::text(200, body)
            }
            Some(other) => Response::error(
                400,
                &format!("unknown format '{other}' (expected json|text|prometheus)"),
            ),
        }
    }

    /// `GET /v1/slo`: the full multi-window burn-rate evaluation, or a
    /// `{"enabled": false}` stub when no objectives are configured.
    fn slo_status(&self) -> Response {
        match &self.slo {
            Some(engine) => Response::json(200, &engine.evaluate()),
            None => Response::json(
                200,
                &Json::obj(vec![
                    ("enabled", Json::Bool(false)),
                    ("status", Json::Str("disabled".into())),
                ]),
            ),
        }
    }

    /// `GET /metrics/stream`: live metric deltas. The first frame is a
    /// full counter/gauge snapshot (`"kind":"snapshot"`); each heartbeat
    /// thereafter emits only the series that changed
    /// (`"kind":"delta"`), or a keep-alive frame when nothing did.
    fn metrics_stream(&self, req: &Request) -> Response {
        let sse = match req.query_get("format") {
            None | Some("ndjson") => false,
            Some("sse") => true,
            Some(other) => {
                return Response::error(
                    400,
                    &format!("unknown format '{other}' (expected ndjson|sse)"),
                )
            }
        };
        let body = MetricsStreamBody {
            sse,
            heartbeat: self.heartbeat,
            prev: None,
            seq: 0,
        };
        Response::streamed(
            if sse {
                "text/event-stream"
            } else {
                "application/x-ndjson"
            },
            Box::new(body),
        )
    }

    /// `GET /v1/trace/stream`: the retired-span firehose. Replays the
    /// bus's retained tail, then follows live across all jobs;
    /// `?trace_id=…` narrows the stream to a single trace.
    fn trace_stream(&self, req: &Request) -> Response {
        let sse = match req.query_get("format") {
            None | Some("ndjson") => false,
            Some("sse") => true,
            Some(other) => {
                return Response::error(
                    400,
                    &format!("unknown format '{other}' (expected ndjson|sse)"),
                )
            }
        };
        let filter = req
            .query_get("trace_id")
            .map(|id| format!("\"trace_id\":\"{id}\""));
        let (replay, live) = crate::obs::sink().span_bus().subscribe();
        let body = EventStreamBody {
            replay: replay.into(),
            rx: live,
            sse,
            heartbeat: self.heartbeat,
            recorder: None,
            filter,
            started: Instant::now(),
            delivered: 0,
            meta: format!("trace_stream rid={}", req.request_id().unwrap_or("-")),
        };
        Response::streamed(
            if sse {
                "text/event-stream"
            } else {
                "application/x-ndjson"
            },
            Box::new(body),
        )
    }

    /// `GET /v1/jobs/{id}/trace`: the job's flight-recorder timeline.
    fn job_trace(&self, id: &str) -> Response {
        let id: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        match self.svc.trace(id) {
            None => Response::error(404, &format!("unknown job {id}")),
            Some(mut t) => {
                if let Json::Obj(m) = &mut t {
                    m.insert("job_id".into(), Json::Num(id as f64));
                }
                Response::json(200, &t)
            }
        }
    }

    /// `GET /v1/jobs/{id}/events`: live progress stream. Replays the
    /// job's event history, then follows the bus live (cell retirements,
    /// unit completions, the terminal `summary`) until the job ends.
    /// NDJSON by default; `?format=sse` switches to Server-Sent Events.
    fn job_events(&self, id: &str, req: &Request) -> Response {
        let jid: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        let sse = match req.query_get("format") {
            None | Some("ndjson") => false,
            Some("sse") => true,
            Some(other) => {
                return Response::error(
                    400,
                    &format!("unknown format '{other}' (expected ndjson|sse)"),
                )
            }
        };
        let Some(bus) = self.svc.events(jid) else {
            return Response::error(404, &format!("unknown job {jid}"));
        };
        let (replay, live) = bus.subscribe();
        let body = EventStreamBody {
            replay: replay.into(),
            rx: live,
            sse,
            heartbeat: self.heartbeat,
            recorder: self.svc.recorder(jid),
            filter: None,
            started: Instant::now(),
            delivered: 0,
            meta: format!(
                "job={jid} rid={}",
                req.request_id().unwrap_or("-")
            ),
        };
        Response::streamed(
            if sse {
                "text/event-stream"
            } else {
                "application/x-ndjson"
            },
            Box::new(body),
        )
    }

    /// `GET /v1/scenarios/{id}/events`: like the jobs route, but 404s for
    /// sweep jobs (mirroring `GET /v1/scenarios/{id}`).
    fn scenario_events(&self, id: &str, req: &Request) -> Response {
        let jid: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        if self.svc.status(jid).is_some() && self.svc.scenario_progress(jid).is_none() {
            return Response::error(
                404,
                &format!("job {jid} is not a scenario job (see GET /v1/jobs/{jid}/events)"),
            );
        }
        self.job_events(id, req)
    }

    /// `GET /v1/jobs/{id}/sweep.csv`: the per-cell measurement CSV of a
    /// completed sweep, streamed one row per chunk so even a maximal grid
    /// is never materialised as a single body buffer.
    fn job_sweep_csv(&self, id: &str) -> Response {
        let jid: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        let result = match self.svc.status(jid) {
            None => return Response::error(404, &format!("unknown job {jid}")),
            Some(JobStatus::Done(r)) => r,
            Some(JobStatus::DoneScenario(_)) => {
                return Response::error(
                    409,
                    &format!("job {jid} is a scenario job; see GET /v1/scenarios/{jid}"),
                )
            }
            Some(JobStatus::Failed(e)) => {
                return Response::error(409, &format!("job {jid} failed: {e}"))
            }
            Some(_) => {
                return Response::error(409, &format!("job {jid} is not complete yet"))
            }
        };
        let n = result.cells.len();
        let rows = std::iter::once(report::sweep_csv_header().as_bytes().to_vec())
            .chain((0..n).map(move |i| report::sweep_csv_row(&result.cells[i]).into_bytes()));
        Response::streamed("text/csv; charset=utf-8", Box::new(IterBody::new(rows)))
    }

    /// `GET /v1/scenarios/{id}/trace`: like the jobs route, but 404s for
    /// sweep jobs (mirroring `GET /v1/scenarios/{id}`).
    fn scenario_trace(&self, id: &str) -> Response {
        let jid: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        if self.svc.status(jid).is_some() && self.svc.scenario_progress(jid).is_none() {
            return Response::error(
                404,
                &format!("job {jid} is not a scenario job (see GET /v1/jobs/{jid}/trace)"),
            );
        }
        self.job_trace(id)
    }

    fn scope(&self, req: &Request) -> Response {
        let body = if req.body.is_empty() && req.body_json.is_none() {
            Json::obj(vec![])
        } else {
            match req.json_body() {
                Ok(j) => j,
                Err(e) => return Response::error(400, &format!("invalid JSON body: {e}")),
            }
        };
        if body.as_obj().is_none() {
            // An array/string/number envelope would silently run the full
            // default sweep (every get() returns None) — reject it.
            return Response::error(400, "body must be a JSON object");
        }
        let spec = match body.get("sweep") {
            Some(s) => match config::sweep_spec_from_json(&self.default_spec, s) {
                Ok(spec) => spec,
                Err(e) => return Response::error(422, &format!("invalid sweep spec: {e}")),
            },
            None => self.default_spec.clone(),
        };
        if let Err(e) = spec
            .validate()
            .and_then(|_| check_service_limits(&spec, self.svc.executor_workers()))
        {
            return Response::error(422, &format!("invalid sweep spec: {e}"));
        }
        let weight = match weight_from_json(body.get("scheduler")) {
            Ok(w) => w,
            Err(e) => return Response::error(422, &format!("invalid scheduler: {e}")),
        };
        let workload = match workload_from_json(body.get("workload")) {
            Ok(w) => w,
            Err(e) => return Response::error(422, &format!("invalid workload: {e}")),
        };
        let sla = match sla_from_json(body.get("sla")) {
            Ok(s) => s,
            Err(e) => return Response::error(422, &format!("invalid sla: {e}")),
        };
        let ctx = req.trace_context();
        // Journalled alongside the spec in the WAL submit record, so a
        // resumed job's recommendation context survives the crash. Same
        // shapes `workload_from_json` / `sla_from_json` parse.
        let extra = Json::obj(vec![
            (
                "workload",
                Json::obj(vec![
                    ("signals", Json::Num(workload.n_signals as f64)),
                    ("memvecs", Json::Num(workload.n_memvec as f64)),
                    ("obs_per_sec", Json::Num(workload.obs_per_sec)),
                    ("train_window", Json::Num(workload.train_window as f64)),
                ]),
            ),
            (
                "sla",
                Json::obj(vec![
                    ("headroom", Json::Num(sla.headroom)),
                    ("max_train_s", Json::Num(sla.max_train_s)),
                ]),
            ),
        ]);
        match self.svc.submit_traced_durable(spec, weight, ctx, Some(extra)) {
            Ok(id) => {
                let mut jobs = self.jobs.lock().unwrap();
                // Drop scoping contexts for jobs the queue has evicted, so
                // this map stays bounded by the queue's retention policy.
                jobs.retain(|jid, _| self.svc.status(*jid).is_some());
                jobs.insert(id, (workload, sla));
                drop(jobs);
                Registry::global().inc("service.scope.submitted");
                Response::json(
                    202,
                    &Json::obj(vec![
                        ("job_id", Json::Num(id as f64)),
                        ("status", Json::Str("queued".into())),
                    ]),
                )
            }
            Err(e) => {
                Registry::global().inc("service.scope.rejected");
                Response::error(429, &e.to_string())
            }
        }
    }

    fn job_status(&self, id: &str) -> Response {
        let id: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        match self.svc.status(id) {
            None => Response::error(404, &format!("unknown job {id}")),
            Some(status) => {
                let mut fields = vec![("job_id", Json::Num(id as f64))];
                match status {
                    JobStatus::Queued => fields.push(("status", Json::Str("queued".into()))),
                    JobStatus::Running => {
                        fields.push(("status", Json::Str("running".into())))
                    }
                    JobStatus::Cancelled => {
                        fields.push(("status", Json::Str("cancelled".into())))
                    }
                    JobStatus::Failed(e) => {
                        fields.push(("status", Json::Str("failed".into())));
                        fields.push(("error", Json::Str(e)));
                    }
                    JobStatus::Done(r) => {
                        fields.push(("status", Json::Str("done".into())));
                        fields.push(("result", sweep_summary(&r)));
                    }
                    JobStatus::DoneScenario(o) => {
                        // full outcome lives at GET /v1/scenarios/{id}
                        fields.push(("status", Json::Str("done".into())));
                        fields.push(("scenario", Json::Str(o.name.clone())));
                    }
                }
                if let Some(p) = self.svc.progress(id) {
                    fields.push((
                        "progress",
                        Json::obj(vec![
                            ("trials_done", Json::Num(p.trials_done as f64)),
                            ("trials_planned", Json::Num(p.trials_planned as f64)),
                            ("cells_total", Json::Num(p.cells_total as f64)),
                            ("cells_done", Json::Num(p.cells_done as f64)),
                            (
                                "cells_interpolated",
                                Json::Num(p.cells_interpolated as f64),
                            ),
                        ]),
                    ));
                }
                Response::json(200, &Json::obj(fields))
            }
        }
    }

    /// `POST /v1/scenarios`: body `{"scenario": {…}, "sweep": {…},
    /// "scheduler": {…}}`. The `scenario` object is required; `sweep`
    /// overlays the server's default spec and is mandatory semantics-wise
    /// only for workload-mode scenarios (where it feeds the oracle) — the
    /// server fills it with its default spec when omitted there.
    fn scenario_submit(&self, req: &Request) -> Response {
        if req.body_json.is_none()
            && req.body_str().map(|t| t.trim().is_empty()).unwrap_or(false)
        {
            return Response::error(400, "body must carry a scenario object");
        }
        let body = match req.json_body() {
            Ok(j) => j,
            Err(e) => return Response::error(400, &format!("invalid JSON body: {e}")),
        };
        if body.as_obj().is_none() {
            return Response::error(400, "body must be a JSON object");
        }
        let Some(sj) = body.get("scenario") else {
            return Response::error(422, "missing 'scenario' object");
        };
        let scenario = match ScenarioSpec::from_json(sj) {
            Ok(s) => s,
            Err(e) => return Response::error(422, &format!("invalid scenario: {e}")),
        };
        if let Err(e) = scenario
            .validate()
            .and_then(|_| check_scenario_limits(&scenario))
        {
            return Response::error(422, &format!("invalid scenario: {e}"));
        }
        // Sweep spec: explicit overlay wins; workload mode falls back to
        // the server's default grid (the oracle needs *some* sweep).
        let sweep = match body.get("sweep") {
            Some(s) => match config::sweep_spec_from_json(&self.default_spec, s) {
                Ok(spec) => Some(spec),
                Err(e) => return Response::error(422, &format!("invalid sweep spec: {e}")),
            },
            None if scenario.workload.is_some() => Some(self.default_spec.clone()),
            None => None,
        };
        if let Some(spec) = &sweep {
            if let Err(e) = spec
                .validate()
                .and_then(|_| check_service_limits(spec, self.svc.executor_workers()))
            {
                return Response::error(422, &format!("invalid sweep spec: {e}"));
            }
        }
        let weight = match weight_from_json(body.get("scheduler")) {
            Ok(w) => w,
            Err(e) => return Response::error(422, &format!("invalid scheduler: {e}")),
        };
        let ctx = req.trace_context();
        match self.svc.submit_scenario_traced(scenario, sweep, weight, ctx) {
            Ok(id) => {
                Registry::global().inc("service.scenario.submitted");
                Response::json(
                    202,
                    &Json::obj(vec![
                        ("job_id", Json::Num(id as f64)),
                        ("status", Json::Str("queued".into())),
                    ]),
                )
            }
            Err(e) => {
                Registry::global().inc("service.scenario.rejected");
                let msg = e.to_string();
                if msg.contains("saturated") {
                    Response::error(429, &msg)
                } else {
                    Response::error(422, &msg)
                }
            }
        }
    }

    /// `GET /v1/scenarios/{id}`: status + live replay progress (plus the
    /// embedded oracle sweep's progress) and, once done, the full
    /// [`crate::scenario::ScenarioOutcome`] JSON.
    fn scenario_status(&self, id: &str) -> Response {
        let id: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        let Some(status) = self.svc.status(id) else {
            return Response::error(404, &format!("unknown job {id}"));
        };
        let Some(sp) = self.svc.scenario_progress(id) else {
            return Response::error(
                404,
                &format!("job {id} is not a scenario job (see GET /v1/jobs/{id})"),
            );
        };
        let mut fields = vec![("job_id", Json::Num(id as f64))];
        match status {
            JobStatus::Queued => fields.push(("status", Json::Str("queued".into()))),
            JobStatus::Running => fields.push(("status", Json::Str("running".into()))),
            JobStatus::Cancelled => fields.push(("status", Json::Str("cancelled".into()))),
            JobStatus::Failed(e) => {
                fields.push(("status", Json::Str("failed".into())));
                fields.push(("error", Json::Str(e)));
            }
            JobStatus::DoneScenario(o) => {
                fields.push(("status", Json::Str("done".into())));
                fields.push(("result", o.to_json()));
            }
            JobStatus::Done(_) => {
                // unreachable in practice: scenario ids never carry sweep
                // results; report it honestly rather than panicking.
                fields.push(("status", Json::Str("done".into())));
            }
        }
        let mut progress = vec![
            ("tenants", Json::Num(sp.tenants as f64)),
            ("units_total", Json::Num(sp.units_total as f64)),
            ("units_done", Json::Num(sp.units_done as f64)),
        ];
        if let Some(p) = self.svc.progress(id) {
            progress.push((
                "sweep",
                Json::obj(vec![
                    ("trials_done", Json::Num(p.trials_done as f64)),
                    ("trials_planned", Json::Num(p.trials_planned as f64)),
                    ("cells_total", Json::Num(p.cells_total as f64)),
                    ("cells_done", Json::Num(p.cells_done as f64)),
                ]),
            ));
        }
        fields.push(("progress", Json::obj(progress)));
        Response::json(200, &Json::obj(fields))
    }

    fn cancel_job(&self, id: &str) -> Response {
        let id: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        match self.svc.cancel(id) {
            None => Response::error(404, &format!("unknown job {id}")),
            Some(JobStatus::Queued | JobStatus::Running) => {
                // both DELETE routes land here; attribute the metric to
                // the job's actual kind
                if self.svc.scenario_progress(id).is_some() {
                    Registry::global().inc("service.scenario.cancelled");
                } else {
                    Registry::global().inc("service.scope.cancelled");
                }
                Response::json(
                    202,
                    &Json::obj(vec![
                        ("job_id", Json::Num(id as f64)),
                        ("status", Json::Str("cancelling".into())),
                    ]),
                )
            }
            Some(_) => Response::error(
                409,
                &format!("job {id} already completed; nothing to cancel"),
            ),
        }
    }

    fn recommendation(&self, id: &str) -> Response {
        let id: JobId = match id.parse() {
            Ok(v) => v,
            Err(_) => return Response::error(400, "job id must be an integer"),
        };
        let result = match self.svc.status(id) {
            None => return Response::error(404, &format!("unknown job {id}")),
            Some(JobStatus::Done(r)) => r,
            Some(JobStatus::Failed(e)) => {
                return Response::error(409, &format!("job {id} failed: {e}"))
            }
            Some(JobStatus::DoneScenario(_)) => {
                return Response::error(
                    409,
                    &format!("job {id} is a scenario job; see GET /v1/scenarios/{id}"),
                )
            }
            Some(_) => {
                return Response::error(409, &format!("job {id} is not complete yet"))
            }
        };
        // No silent fallback workload: a recommendation sized for the wrong
        // customer with a 200 status would be worse than an honest 409.
        let Some((workload, sla)) = self.jobs.lock().unwrap().get(&id).copied() else {
            return Response::error(
                409,
                &format!("job {id} has no scoping context (evicted or still registering)"),
            );
        };
        match recommend_from_sweep(&result, &workload, &sla) {
            Ok(rec) => {
                let mut j = rec.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("job_id".into(), Json::Num(id as f64));
                    m.insert("rendered".into(), Json::Str(rec.render()));
                }
                stream_json_object(j)
            }
            Err(e) => Response::error(500, &format!("recommendation failed: {e}")),
        }
    }
}

/// Stream a top-level JSON object one member per HTTP chunk via
/// [`StreamEmitter`], so a large rendered report is never materialised as
/// one contiguous body buffer. Non-object values fall back to a buffered
/// [`Response::json`].
fn stream_json_object(value: Json) -> Response {
    let Json::Obj(map) = value else {
        return Response::json(200, &value);
    };
    let mut em = StreamEmitter::new();
    em.begin_obj();
    let mut entries = map.into_iter();
    let mut done = false;
    let chunks = std::iter::from_fn(move || {
        if done {
            return None;
        }
        match entries.next() {
            Some((k, v)) => {
                em.key(&k);
                em.value(&v);
            }
            None => {
                em.end_obj();
                done = true;
            }
        }
        Some(em.take().into_bytes())
    });
    Response::streamed("application/json", Box::new(IterBody::new(chunks)))
}

/// The per-route metric class of a request path, or `None` for paths
/// outside the API surface (unknown routes are not worth a metric series
/// each — a scanner would mint unbounded names).
fn route_class(segs: &[&str]) -> Option<&'static str> {
    match segs {
        ["healthz"] => Some("healthz"),
        ["metrics"] | ["metrics", "stream"] => Some("metrics"),
        ["v1", "shapes"] => Some("shapes"),
        ["v1", "slo"] => Some("slo"),
        ["v1", "trace", "stream"] => Some("trace"),
        ["v1", "scope"] => Some("scope"),
        ["v1", "scenarios"] | ["v1", "scenarios", ..] => Some("scenarios"),
        ["v1", "jobs", ..] => Some("jobs"),
        ["v1", "recommendations", _] => Some("recommendations"),
        _ => None,
    }
}

/// [`BodyStream`] over a job's [`EventBus`](crate::obs::EventBus):
/// replays buffered history, then follows the live feed until the bus
/// closes (the job published its terminal `summary`). Quiet periods emit
/// keep-alive frames so proxies and clients can distinguish a slow job
/// from a dead connection.
struct EventStreamBody {
    /// History snapshot still to deliver (drained front-first).
    replay: VecDeque<BusEvent>,
    /// Live receiver; `None` once the bus has disconnected.
    rx: Option<mpsc::Receiver<BusEvent>>,
    /// Server-Sent Events framing instead of NDJSON.
    sse: bool,
    /// Idle gap after which a keep-alive frame is emitted.
    heartbeat: Duration,
    /// The job's flight recorder; the stream's lifetime is pushed as an
    /// `http/stream` span on drop so streamed responses appear in the
    /// same trace as the work they observed.
    recorder: Option<Arc<FlightRecorder>>,
    /// Substring an event line must contain to be delivered (the
    /// `?trace_id=` needle on `/v1/trace/stream`); `None` passes all.
    filter: Option<String>,
    started: Instant,
    delivered: u64,
    meta: String,
}

impl EventStreamBody {
    /// Whether `ev` passes the optional substring filter.
    fn matches(&self, ev: &BusEvent) -> bool {
        match &self.filter {
            Some(needle) => ev.line.contains(needle.as_str()),
            None => true,
        }
    }

    /// Frame one bus event for the negotiated wire format.
    fn frame(&mut self, ev: &BusEvent) -> Vec<u8> {
        self.delivered += 1;
        if self.sse {
            format!("id: {}\ndata: {}\n\n", ev.seq, ev.line).into_bytes()
        } else {
            format!("{}\n", ev.line).into_bytes()
        }
    }

    /// Keep-alive frame: an SSE comment, or a bare newline for NDJSON
    /// (blank lines are ignored by NDJSON consumers).
    fn heartbeat_frame(&self) -> Vec<u8> {
        if self.sse {
            b": keep-alive\n\n".to_vec()
        } else {
            b"\n".to_vec()
        }
    }
}

impl BodyStream for EventStreamBody {
    fn next_chunk(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        while let Some(ev) = self.replay.pop_front() {
            if self.matches(&ev) {
                return Ok(Some(self.frame(&ev)));
            }
        }
        let deadline = Instant::now() + self.heartbeat;
        loop {
            let timeout = deadline.saturating_duration_since(Instant::now());
            let recv = match &self.rx {
                None => return Ok(None),
                Some(rx) => rx.recv_timeout(timeout),
            };
            match recv {
                Ok(ev) if self.matches(&ev) => return Ok(Some(self.frame(&ev))),
                // Filtered out: keep draining until a match or the
                // heartbeat deadline — never a silent stall.
                Ok(_) if Instant::now() < deadline => continue,
                Ok(_) | Err(mpsc::RecvTimeoutError::Timeout) => {
                    return Ok(Some(self.heartbeat_frame()))
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.rx = None;
                    return Ok(None);
                }
            }
        }
    }
}

impl Drop for EventStreamBody {
    fn drop(&mut self) {
        if let Some(rec) = &self.recorder {
            rec.push(
                "http",
                "stream",
                self.started,
                Instant::now(),
                Duration::ZERO,
                format!("{} events={}", self.meta, self.delivered),
            );
        }
    }
}

/// [`BodyStream`] behind `GET /metrics/stream`: a full counter/gauge
/// snapshot first, then one delta frame per heartbeat carrying only the
/// series whose values changed since the previous frame. Runs until the
/// client disconnects (the chunk writer surfaces the broken pipe).
struct MetricsStreamBody {
    /// Server-Sent Events framing instead of NDJSON.
    sse: bool,
    /// Cadence between frames.
    heartbeat: Duration,
    /// Counter/gauge values as of the previous frame; `None` before the
    /// initial snapshot.
    prev: Option<BTreeMap<String, f64>>,
    /// Frame sequence number (the SSE `id:`).
    seq: u64,
}

/// Flatten the registry's counters and gauges into one comparable map
/// (`counter.` / `gauge.` prefixes keep the namespaces distinct).
fn metric_values() -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Json::Obj(top) = Registry::global().to_json() {
        for (section, prefix) in [("counters", "counter."), ("gauges", "gauge.")] {
            if let Some(Json::Obj(m)) = top.get(section) {
                for (name, v) in m {
                    if let Some(x) = v.as_f64() {
                        out.insert(format!("{prefix}{name}"), x);
                    }
                }
            }
        }
    }
    out
}

impl MetricsStreamBody {
    /// Frame a `snapshot` or `delta` event for the negotiated format.
    fn frame(&mut self, kind: &str, changed: Vec<(String, f64)>) -> Vec<u8> {
        self.seq += 1;
        let line = Json::obj(vec![
            ("kind", Json::Str(kind.to_string())),
            ("seq", Json::Num(self.seq as f64)),
            (
                "values",
                Json::Obj(changed.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
            ),
        ])
        .to_string();
        if self.sse {
            format!("id: {}\ndata: {line}\n\n", self.seq).into_bytes()
        } else {
            format!("{line}\n").into_bytes()
        }
    }
}

impl BodyStream for MetricsStreamBody {
    fn next_chunk(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        let Some(prev) = &self.prev else {
            let now = metric_values();
            let all: Vec<(String, f64)> = now.iter().map(|(k, v)| (k.clone(), *v)).collect();
            self.prev = Some(now);
            return Ok(Some(self.frame("snapshot", all)));
        };
        std::thread::sleep(self.heartbeat);
        let now = metric_values();
        let changed: Vec<(String, f64)> = now
            .iter()
            .filter(|(k, v)| prev.get(*k) != Some(v))
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        self.prev = Some(now);
        if changed.is_empty() {
            let hb = if self.sse {
                b": keep-alive\n\n".to_vec()
            } else {
                b"\n".to_vec()
            };
            Ok(Some(hb))
        } else {
            Ok(Some(self.frame("delta", changed)))
        }
    }
}

/// Per-request bounds on client-supplied sweep specs. The CLI is
/// operator-trusted and unbounded; the network path is not — one request
/// must not be able to exhaust the node's memory or threads.
const MAX_CELLS: usize = 512;
const MAX_TRIALS: usize = 32;
/// Bounds on the per-job fair-share weight a request may claim. The
/// executor clamps harder than this; the service rejects instead of
/// silently clamping.
const MIN_WEIGHT: f64 = 1.0 / 16.0;
const MAX_WEIGHT: f64 = 16.0;
/// Per-cell synthesis size cap: `signals × max(obs, memvecs)` elements
/// (f64), ~128 MB at the bound.
const MAX_CELL_ELEMS: usize = 1 << 24;
/// Joint cap on concurrent synthesis: `executor workers × cell elements`
/// — each in-flight trial holds a few cell-sized buffers, and the shared
/// executor (not the client-claimed `workers` knob) decides how many of a
/// job's trials run at once, so bounding that product is what actually
/// bounds transient memory.
const MAX_CONCURRENT_ELEMS: usize = 1 << 26;

/// Per-request bounds on client-supplied scenarios: fleet size × epochs
/// drives both CPU (simulation steps) and memory (per-epoch series), so
/// one request must not be able to monopolise the node.
const MAX_SCENARIO_EPOCHS: usize = 4096;
const MAX_SCENARIO_TENANTS: usize = 4096;
const MAX_SCENARIO_POLICIES: usize = 8;
/// Cap on `max_tenants × epochs` (simulation units per policy); ~2M units
/// replay in well under a second in release builds.
const MAX_SCENARIO_UNITS: usize = 1 << 21;

fn check_scenario_limits(s: &ScenarioSpec) -> anyhow::Result<()> {
    anyhow::ensure!(
        s.epochs <= MAX_SCENARIO_EPOCHS,
        "scenario too large: {} epochs (service max {MAX_SCENARIO_EPOCHS})",
        s.epochs
    );
    anyhow::ensure!(
        s.arrivals.max_tenants <= MAX_SCENARIO_TENANTS,
        "scenario too large: {} tenants (service max {MAX_SCENARIO_TENANTS})",
        s.arrivals.max_tenants
    );
    anyhow::ensure!(
        s.policies.len() <= MAX_SCENARIO_POLICIES,
        "scenario too large: {} policies (service max {MAX_SCENARIO_POLICIES})",
        s.policies.len()
    );
    let units = s.arrivals.max_tenants.saturating_mul(s.epochs);
    anyhow::ensure!(
        units <= MAX_SCENARIO_UNITS,
        "scenario too large: {units} tenant-epochs per policy \
         (service max {MAX_SCENARIO_UNITS})"
    );
    Ok(())
}

fn check_service_limits(spec: &SweepSpec, executor_workers: usize) -> anyhow::Result<()> {
    let cells = spec.signals.len() * spec.memvecs.len() * spec.obs.len();
    anyhow::ensure!(
        cells <= MAX_CELLS,
        "sweep grid too large: {cells} cells (service max {MAX_CELLS})"
    );
    // In adaptive mode the per-cell worst case is the planner's cap, not
    // the exhaustive `trials` budget.
    let per_cell = if spec.adaptive() {
        spec.effective_max_trials()
    } else {
        spec.trials
    };
    anyhow::ensure!(
        per_cell <= MAX_TRIALS,
        "trials too large: {per_cell} per cell (service max {MAX_TRIALS})"
    );
    // `spec.workers` is deliberately unchecked: in service mode the shared
    // trial executor (not the client-claimed knob) decides how many of a
    // job's trials run at once, so the field cannot amplify resource use.
    let max_n = spec.signals.iter().copied().max().unwrap_or(0);
    let max_m = spec.memvecs.iter().copied().max().unwrap_or(0);
    let max_obs = spec.obs.iter().copied().max().unwrap_or(0);
    let elems = max_n.saturating_mul(max_obs.max(max_m));
    anyhow::ensure!(
        elems <= MAX_CELL_ELEMS,
        "cell too large: {max_n} signals × {} obs/memvecs exceeds the service limit",
        max_obs.max(max_m)
    );
    let eff_workers = executor_workers.max(1);
    anyhow::ensure!(
        eff_workers.saturating_mul(elems) <= MAX_CONCURRENT_ELEMS,
        "sweep too large: {eff_workers} executor workers × {elems}-element cells exceeds \
         the service's concurrent-memory limit; reduce the cell size"
    );
    Ok(())
}

/// Per-job fair-share weight from the optional `scheduler` request object
/// (`1.0` — an equal share — when absent). Out-of-range weights are an
/// error, not a silent clamp.
fn weight_from_json(j: Option<&Json>) -> anyhow::Result<f64> {
    let Some(j) = j else { return Ok(1.0) };
    match req_f64(j, "weight")? {
        None => Ok(1.0),
        Some(w) => {
            anyhow::ensure!(
                w.is_finite() && (MIN_WEIGHT..=MAX_WEIGHT).contains(&w),
                "weight must be within [{MIN_WEIGHT}, {MAX_WEIGHT}], got {w}"
            );
            Ok(w)
        }
    }
}

fn sweep_summary(r: &SweepResult) -> Json {
    Json::obj(vec![
        ("cells", Json::Num(r.cells.len() as f64)),
        ("gap_cells", Json::Num(r.gap_cells().len() as f64)),
        ("measured_cells", Json::Num(r.measured_cells() as f64)),
        (
            "interpolated_cells",
            Json::Num(r.interpolated_cells() as f64),
        ),
        ("total_trials", Json::Num(r.total_trials() as f64)),
        ("model", Json::Str(r.spec.model.clone())),
        ("trials", Json::Num(r.spec.trials as f64)),
        ("adaptive", Json::Bool(r.spec.adaptive())),
        ("seed", Json::Num(r.spec.seed as f64)),
    ])
}

// Like `config::sweep_spec_from_json`, present-but-malformed keys are an
// error — a silently defaulted workload would size the wrong customer.

fn req_usize(j: &Json, key: &str) -> anyhow::Result<Option<usize>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{key} must be a non-negative integer")),
    }
}

fn req_f64(j: &Json, key: &str) -> anyhow::Result<Option<f64>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("{key} must be a number")),
    }
}

fn workload_from_json(j: Option<&Json>) -> anyhow::Result<Workload> {
    let mut w = Workload::customer_a();
    if let Some(j) = j {
        if let Some(v) = req_usize(j, "signals")? {
            w.n_signals = v;
        }
        if let Some(v) = req_usize(j, "memvecs")? {
            w.n_memvec = v;
        }
        if let Some(v) = req_f64(j, "obs_per_sec")? {
            w.obs_per_sec = v;
        }
        if let Some(v) = req_usize(j, "train_window")? {
            w.train_window = v;
        }
    }
    Ok(w)
}

fn sla_from_json(j: Option<&Json>) -> anyhow::Result<Sla> {
    let mut sla = Sla::default();
    if let Some(j) = j {
        if let Some(v) = req_f64(j, "headroom")? {
            sla.headroom = v;
        }
        if let Some(v) = req_f64(j, "max_train_s")? {
            sla.max_train_s = v;
        }
    }
    Ok(sla)
}

fn shapes_catalog() -> Response {
    let shapes: Vec<Json> = shapes::catalog()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.to_string())),
                ("cores", Json::Num(s.cpu.cores as f64)),
                ("mem_gb", Json::Num(s.mem_gb)),
                ("gpus", Json::Num(s.gpus as f64)),
                ("usd_per_hour", Json::Num(s.usd_per_hour)),
                ("cpu_eff_gflops", Json::Num(s.cpu_eff_flops() / 1e9)),
            ])
        })
        .collect();
    Response::json(200, &Json::obj(vec![("shapes", Json::Arr(shapes))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;

    fn state() -> ServiceState {
        ServiceState::new(
            ScopingService::start(Backend::Native, 4),
            Arc::new(SweepCache::in_memory()),
            SweepSpec::default(),
        )
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".into(),
            path: path.to_string(),
            query: vec![],
            headers: vec![],
            body: vec![],
            body_json: None,
            http11: true,
        }
    }

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".into(),
            path: path.to_string(),
            query: vec![],
            headers: vec![],
            body: body.as_bytes().to_vec(),
            body_json: None,
            http11: true,
        }
    }

    /// Collect a response body to completion: the buffered bytes, or the
    /// streamed chunks drained and concatenated.
    fn drain(r: Response) -> Vec<u8> {
        let mut out = r.body;
        if let Some(mut s) = r.stream {
            while let Some(chunk) = s.next_chunk().unwrap() {
                out.extend_from_slice(&chunk);
            }
        }
        out
    }

    #[test]
    fn health_shapes_and_404() {
        let st = state();
        assert_eq!(st.handle(&get("/healthz")).status, 200);
        let r = st.handle(&get("/v1/shapes"));
        assert_eq!(r.status, 200);
        assert!(String::from_utf8(r.body).unwrap().contains("VM.Standard2.1"));
        assert_eq!(st.handle(&get("/nope")).status, 404);
        assert_eq!(st.handle(&post("/healthz", "")).status, 405);
    }

    #[test]
    fn scope_input_validation() {
        let st = state();
        assert_eq!(st.handle(&post("/v1/scope", "{oops")).status, 400);
        // valid JSON, wrong envelope type
        assert_eq!(st.handle(&post("/v1/scope", "[1, 2]")).status, 400);
        assert_eq!(st.handle(&post("/v1/scope", "\"scope me\"")).status, 400);
        let r = st.handle(&post("/v1/scope", r#"{"sweep": {"signals": []}}"#));
        assert_eq!(r.status, 422);
        let r = st.handle(&post("/v1/scope", r#"{"sweep": {"model": "gpt"}}"#));
        assert_eq!(r.status, 422);
        // malformed axis entries are an error, not silently dropped
        let r = st.handle(&post("/v1/scope", r#"{"sweep": {"signals": [16.5, 32]}}"#));
        assert_eq!(r.status, 422);
        assert!(String::from_utf8(r.body).unwrap().contains("signals"));
        assert_eq!(st.handle(&get("/v1/jobs/zzz")).status, 400);
        assert_eq!(st.handle(&get("/v1/jobs/12345")).status, 404);
        assert_eq!(st.handle(&get("/v1/recommendations/12345")).status, 404);
    }

    #[test]
    fn scope_resource_limits() {
        let st = state();
        // one cell of ~8 GB synthesis: rejected before any work is queued
        let r = st.handle(&post(
            "/v1/scope",
            r#"{"sweep": {"signals": [4], "memvecs": [8], "obs": [1000000000]}}"#,
        ));
        assert_eq!(r.status, 422);
        assert!(String::from_utf8(r.body).unwrap().contains("too large"));
        let r = st.handle(&post("/v1/scope", r#"{"sweep": {"trials": 1000}}"#));
        assert_eq!(r.status, 422);
        // the adaptive planner's per-cell cap is bounded like `trials`
        let r = st.handle(&post(
            "/v1/scope",
            r#"{"sweep": {"ci_target": 0.2, "max_trials": 1000}}"#,
        ));
        assert_eq!(r.status, 422);
        assert!(String::from_utf8(r.body).unwrap().contains("too large"));
    }

    #[test]
    fn planner_knobs_validated() {
        let st = state();
        let r = st.handle(&post("/v1/scope", r#"{"sweep": {"interpolate": "yes"}}"#));
        assert_eq!(r.status, 422);
        let r = st.handle(&post(
            "/v1/scope",
            r#"{"sweep": {"ci_target": 0.3, "pilot_trials": 1}}"#,
        ));
        assert_eq!(r.status, 422);
        assert!(String::from_utf8(r.body).unwrap().contains("pilot_trials"));
    }

    fn delete(path: &str) -> Request {
        Request {
            method: "DELETE".into(),
            path: path.to_string(),
            query: vec![],
            headers: vec![],
            body: vec![],
            body_json: None,
            http11: true,
        }
    }

    #[test]
    fn scheduler_weight_validated() {
        let st = state();
        let r = st.handle(&post("/v1/scope", r#"{"scheduler": {"weight": "fast"}}"#));
        assert_eq!(r.status, 422);
        let r = st.handle(&post("/v1/scope", r#"{"scheduler": {"weight": 1000}}"#));
        assert_eq!(r.status, 422);
        assert!(String::from_utf8(r.body).unwrap().contains("weight"));
        let r = st.handle(&post("/v1/scope", r#"{"scheduler": {"weight": 2.0}}"#));
        assert_eq!(r.status, 202, "in-range weights are accepted");
    }

    #[test]
    fn cancel_route_contract() {
        let st = state();
        assert_eq!(st.handle(&delete("/v1/jobs/zzz")).status, 400);
        assert_eq!(st.handle(&delete("/v1/jobs/12345")).status, 404);
        // a completed job is 409, not a second cancellation
        let r = st.handle(&post("/v1/scope", "{}"));
        assert_eq!(r.status, 202);
        let id = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap();
        st.svc.wait(id as u64).unwrap();
        assert_eq!(st.handle(&delete(&format!("/v1/jobs/{id}"))).status, 409);
    }

    #[test]
    fn job_status_carries_progress() {
        let st = state();
        let r = st.handle(&post("/v1/scope", "{}"));
        assert_eq!(r.status, 202);
        let id = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap();
        st.svc.wait(id as u64).unwrap();
        let r = st.handle(&get(&format!("/v1/jobs/{id}")));
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let p = j.get("progress").expect("status carries progress");
        assert_eq!(
            p.get("cells_done").unwrap().as_usize(),
            p.get("cells_total").unwrap().as_usize()
        );
        assert_eq!(
            p.get("trials_done").unwrap().as_usize(),
            p.get("trials_planned").unwrap().as_usize()
        );
    }

    #[test]
    fn scenario_submit_validation() {
        let st = state();
        // no body / missing scenario object
        assert_eq!(st.handle(&post("/v1/scenarios", "")).status, 400);
        assert_eq!(st.handle(&post("/v1/scenarios", "[1]")).status, 400);
        assert_eq!(st.handle(&post("/v1/scenarios", "{}")).status, 422);
        // malformed scenario fields
        let r = st.handle(&post(
            "/v1/scenarios",
            r#"{"scenario": {"demand": {"kind": "sawtooth"}}}"#,
        ));
        assert_eq!(r.status, 422);
        // resource limits: fleet × epochs bounded
        let r = st.handle(&post(
            "/v1/scenarios",
            r#"{"scenario": {"epochs": 4000,
                 "arrivals": {"initial": 1, "max_tenants": 4000}}}"#,
        ));
        assert_eq!(r.status, 422);
        assert!(String::from_utf8(r.body).unwrap().contains("too large"));
        // bad embedded sweep is rejected up front
        let r = st.handle(&post(
            "/v1/scenarios",
            r#"{"scenario": {"epochs": 10}, "sweep": {"signals": []}}"#,
        ));
        assert_eq!(r.status, 422);
        // method guard
        assert_eq!(st.handle(&get("/v1/scenarios")).status, 405);
    }

    #[test]
    fn scenario_roundtrip_and_status_routes() {
        let st = state();
        let body = r#"{"scenario": {
            "name": "route-test", "epochs": 20,
            "arrivals": {"initial": 3, "rate_per_epoch": 0.0, "max_tenants": 3},
            "demand": {"kind": "constant", "base": 0.5,
                       "growth_per_epoch": 1.01, "jitter": 0.0}
        }}"#;
        let r = st.handle(&post("/v1/scenarios", body));
        assert_eq!(r.status, 202, "{:?}", String::from_utf8(r.body));
        let id = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap();
        st.svc.wait_scenario(id as u64).unwrap();
        let r = st.handle(&get(&format!("/v1/scenarios/{id}")));
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("done"));
        let result = j.get("result").expect("done scenarios carry the outcome");
        assert_eq!(
            result.get("policies").unwrap().as_arr().unwrap().len(),
            3,
            "default policy set"
        );
        assert!(result.get("recommended").unwrap().as_str().is_some());
        let p = j.get("progress").expect("progress present");
        assert_eq!(
            p.get("units_done").unwrap().as_usize(),
            p.get("units_total").unwrap().as_usize()
        );
        // the generic jobs route sees it too, pointing at the scenario
        let r = st.handle(&get(&format!("/v1/jobs/{id}")));
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.get("scenario").and_then(Json::as_str), Some("route-test"));
        // a finished scenario cannot be cancelled
        assert_eq!(st.handle(&delete(&format!("/v1/scenarios/{id}"))).status, 409);
        // unknown / non-scenario ids
        assert_eq!(st.handle(&get("/v1/scenarios/99999")).status, 404);
        let r = st.handle(&post("/v1/scope", "{}"));
        assert_eq!(r.status, 202);
        let sweep_id = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap();
        st.svc.wait(sweep_id as u64).unwrap();
        assert_eq!(
            st.handle(&get(&format!("/v1/scenarios/{sweep_id}"))).status,
            404,
            "sweep jobs are not scenarios"
        );
        // recommendations route redirects scenario jobs
        assert_eq!(
            st.handle(&get(&format!("/v1/recommendations/{id}"))).status,
            409
        );
    }

    #[test]
    fn healthz_reports_scheduler() {
        let st = state();
        let r = st.handle(&get("/healthz"));
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert!(j.get("executor_workers").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(j.get("fair_share").unwrap().as_bool(), Some(true));
        // kernel dispatch reporting is self-consistent with the live
        // decision (the active tier depends on host + env, not the test)
        let kd = crate::linalg::simd::dispatch_info();
        assert_eq!(
            j.get("kernel_backend").and_then(Json::as_str),
            Some(kd.active.isa())
        );
        let disp = j.get("kernel_dispatch").expect("kernel_dispatch object");
        assert_eq!(disp.get("mode").and_then(Json::as_str), Some(kd.active.mode()));
        assert_eq!(
            disp.get("requested").and_then(Json::as_str),
            Some(kd.requested.as_str())
        );
        assert_eq!(disp.get("source").and_then(Json::as_str), Some(kd.source));
        assert_eq!(
            disp.get("simd_available").and_then(Json::as_bool),
            Some(crate::linalg::simd::detect().is_some())
        );
    }

    #[test]
    fn metrics_renders_all_formats_and_rejects_unknown() {
        let st = state();
        let r = st.handle(&get("/metrics"));
        assert_eq!(r.status, 200);
        assert!(Json::parse(std::str::from_utf8(&r.body).unwrap()).is_ok());
        let with_format = |f: &str| {
            let mut req = get("/metrics");
            req.query.push(("format".into(), f.into()));
            st.handle(&req)
        };
        let r = with_format("text");
        assert_eq!(r.content_type, "text/plain; charset=utf-8");
        assert!(String::from_utf8(r.body).unwrap().contains("metrics"));
        let r = with_format("json");
        assert_eq!(r.status, 200);
        let r = with_format("prometheus");
        assert_eq!(r.status, 200);
        let text = String::from_utf8(r.body).unwrap();
        assert!(text.contains("# TYPE"), "{text}");
        assert!(text.contains("executor_queue_depth"), "{text}");
        let kd = crate::linalg::simd::dispatch_info();
        let info_line = format!(
            "kernel_backend_info{{kernel_backend=\"{}\",mode=\"{}\"}} 1",
            kd.active.isa(),
            kd.active.mode()
        );
        assert!(text.contains(&info_line), "{text}");
        let r = with_format("xml");
        assert_eq!(r.status, 400);
        assert!(String::from_utf8(r.body).unwrap().contains("xml"));
    }

    #[test]
    fn metrics_scrape_sets_live_gauges() {
        let st = state();
        let r = st.handle(&get("/metrics"));
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let gauges = j.get("gauges").expect("gauges object present");
        for key in [
            "executor.queue_depth",
            "executor.workers",
            "cache.entries",
            "cache.bytes",
            "service.jobs.in_flight.sweep",
            "service.jobs.in_flight.scenario",
            "kernel.simd_active",
        ] {
            assert!(gauges.get(key).is_some(), "missing gauge {key}");
        }
        assert!(gauges.get("executor.workers").unwrap().as_f64().unwrap() >= 1.0);
        let simd_active = gauges.get("kernel.simd_active").unwrap().as_f64().unwrap();
        let expect = if crate::linalg::simd::dispatch_info().active.is_simd() {
            1.0
        } else {
            0.0
        };
        assert_eq!(simd_active, expect);
    }

    #[test]
    fn healthz_reports_uptime_and_version() {
        let st = state();
        let r = st.handle(&get("/healthz"));
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            j.get("version").and_then(Json::as_str),
            Some(env!("CARGO_PKG_VERSION"))
        );
    }

    #[test]
    fn trace_routes_serve_timelines_and_guard_kinds() {
        let st = state();
        assert_eq!(st.handle(&get("/v1/jobs/zzz/trace")).status, 400);
        assert_eq!(st.handle(&get("/v1/jobs/12345/trace")).status, 404);
        assert_eq!(st.handle(&post("/v1/jobs/1/trace", "")).status, 405);
        let r = st.handle(&post("/v1/scope", "{}"));
        assert_eq!(r.status, 202);
        let id = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap();
        st.svc.wait(id as u64).unwrap();
        let r = st.handle(&get(&format!("/v1/jobs/{id}/trace")));
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert!(j.get("trace_id").and_then(Json::as_str).is_some());
        assert!(!j.get("spans").unwrap().as_arr().unwrap().is_empty());
        // a sweep job is not served by the scenario trace route
        assert_eq!(st.handle(&get(&format!("/v1/scenarios/{id}/trace"))).status, 404);
    }

    fn submit_job(st: &ServiceState, body: &str) -> usize {
        let r = st.handle(&post("/v1/scope", body));
        assert_eq!(r.status, 202, "{:?}", String::from_utf8(r.body));
        Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap()
    }

    #[test]
    fn events_route_streams_ndjson_until_summary() {
        let st = state();
        let id = submit_job(&st, "{}");
        // subscribe while the job may still be running: drain() follows the
        // live feed and returns only once the bus closes after the summary
        let r = st.handle(&get(&format!("/v1/jobs/{id}/events")));
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "application/x-ndjson");
        assert!(r.stream.is_some(), "events are streamed, not buffered");
        let text = String::from_utf8(drain(r)).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
        for l in &lines {
            Json::parse(l).expect("every event line is a standalone JSON doc");
        }
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(last.get("event").and_then(Json::as_str), Some("summary"));
        assert_eq!(last.get("status").and_then(Json::as_str), Some("done"));
        let cells_total = last.get("cells_total").unwrap().as_usize().unwrap();
        let cells = lines
            .iter()
            .filter(|l| {
                Json::parse(l).unwrap().get("event").and_then(Json::as_str) == Some("cell")
            })
            .count();
        assert_eq!(cells, cells_total, "one cell event per grid cell");
    }

    #[test]
    fn events_route_sse_format_and_validation() {
        let st = state();
        let id = submit_job(&st, "{}");
        st.svc.wait(id as u64).unwrap();
        let mut req = get(&format!("/v1/jobs/{id}/events"));
        req.query.push(("format".into(), "sse".into()));
        let r = st.handle(&req);
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "text/event-stream");
        let text = String::from_utf8(drain(r)).unwrap();
        assert!(text.contains("data: {"), "{text}");
        assert!(text.lines().any(|l| l.starts_with("id: ")));
        let mut req = get(&format!("/v1/jobs/{id}/events"));
        req.query.push(("format".into(), "xml".into()));
        assert_eq!(st.handle(&req).status, 400);
        assert_eq!(st.handle(&get("/v1/jobs/zzz/events")).status, 400);
        assert_eq!(st.handle(&get("/v1/jobs/99999/events")).status, 404);
        let r = st.handle(&post(&format!("/v1/jobs/{id}/events"), ""));
        assert_eq!(r.status, 405);
    }

    #[test]
    fn sweep_csv_route_streams_rows() {
        let st = state();
        let id = submit_job(&st, "{}");
        st.svc.wait(id as u64).unwrap();
        let r = st.handle(&get(&format!("/v1/jobs/{id}/sweep.csv")));
        assert_eq!(r.status, 200);
        assert!(r.stream.is_some(), "CSV is streamed row-by-row");
        let text = String::from_utf8(drain(r)).unwrap();
        let Some(JobStatus::Done(result)) = st.svc.status(id as u64) else {
            panic!("job should be done");
        };
        assert_eq!(text, report::sweep_csv(&result));
        assert_eq!(st.handle(&get("/v1/jobs/99999/sweep.csv")).status, 404);
        assert_eq!(st.handle(&get("/v1/jobs/zzz/sweep.csv")).status, 400);
    }

    #[test]
    fn scenario_events_route_guards_and_streams() {
        let st = state();
        // sweep jobs are not served by the scenario events route
        let id = submit_job(&st, "{}");
        st.svc.wait(id as u64).unwrap();
        assert_eq!(
            st.handle(&get(&format!("/v1/scenarios/{id}/events"))).status,
            404
        );
        let body = r#"{"scenario": {
            "name": "ev-test", "epochs": 10,
            "arrivals": {"initial": 2, "rate_per_epoch": 0.0, "max_tenants": 2},
            "demand": {"kind": "constant", "base": 0.5,
                       "growth_per_epoch": 1.01, "jitter": 0.0}
        }}"#;
        let r = st.handle(&post("/v1/scenarios", body));
        assert_eq!(r.status, 202, "{:?}", String::from_utf8(r.body));
        let sid = Json::parse(std::str::from_utf8(&r.body).unwrap())
            .unwrap()
            .get("job_id")
            .unwrap()
            .as_usize()
            .unwrap();
        let r = st.handle(&get(&format!("/v1/scenarios/{sid}/events")));
        assert_eq!(r.status, 200);
        let text = String::from_utf8(drain(r)).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
        assert!(
            lines.iter().any(|l| l.contains("\"event\":\"unit\"")),
            "scenario streams unit completions: {text}"
        );
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(last.get("event").and_then(Json::as_str), Some("summary"));
        assert!(
            last.get("units_done").is_some(),
            "scenario summaries carry unit progress"
        );
        // the sweep CSV route refuses scenario jobs
        st.svc.wait_scenario(sid as u64).unwrap();
        assert_eq!(
            st.handle(&get(&format!("/v1/jobs/{sid}/sweep.csv"))).status,
            409
        );
    }

    #[test]
    fn recommendation_streams_valid_json() {
        let st = state();
        let id = submit_job(&st, "{}");
        st.svc.wait(id as u64).unwrap();
        let r = st.handle(&get(&format!("/v1/recommendations/{id}")));
        assert_eq!(r.status, 200);
        assert!(r.stream.is_some(), "recommendation body is streamed");
        let text = String::from_utf8(drain(r)).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("job_id").unwrap().as_usize(), Some(id));
        assert!(j.get("rendered").and_then(Json::as_str).is_some());
        // streamed emission is byte-identical to batch serialisation
        assert_eq!(text, j.to_string());
    }

    #[test]
    fn slo_route_and_healthz_summary() {
        use crate::obs::slo::{SloObjective, SloSettings};
        // No engine attached: the route answers with a disabled stub.
        let st = state();
        let r = st.handle(&get("/v1/slo"));
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(false));
        let r = st.handle(&get("/healthz"));
        let h = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(
            h.get("slo").unwrap().get("status").and_then(Json::as_str),
            Some("disabled")
        );
        // With objectives: the full evaluation, summarised in /healthz.
        let settings = SloSettings {
            window_s: 3600,
            tick_ms: 1000,
            objectives: vec![SloObjective::parse_flag("all:500:0.99:0.999").unwrap()],
        };
        let engine = Arc::new(SloEngine::new(settings));
        engine.tick();
        let st = state().with_slo(Arc::clone(&engine));
        let r = st.handle(&get("/v1/slo"));
        assert_eq!(r.status, 200);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(true));
        let objs = j.get("objectives").unwrap().as_arr().unwrap();
        assert_eq!(objs.len(), 1);
        assert_eq!(objs[0].get("route").and_then(Json::as_str), Some("all"));
        let r = st.handle(&get("/healthz"));
        let h = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let slo = h.get("slo").expect("healthz summarises the SLO engine");
        assert!(slo.get("status").and_then(Json::as_str).is_some());
        assert!(slo.get("breaching").is_some());
        assert_eq!(st.handle(&post("/v1/slo", "")).status, 405);
    }

    #[test]
    fn metrics_stream_snapshot_then_delta() {
        let st = state().with_stream_heartbeat(Duration::from_millis(20));
        let r = st.handle(&get("/metrics/stream"));
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "application/x-ndjson");
        let mut s = r.stream.expect("metric deltas are streamed");
        let first = String::from_utf8(s.next_chunk().unwrap().unwrap()).unwrap();
        let j = Json::parse(first.trim()).unwrap();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("snapshot"));
        assert!(j.get("values").unwrap().as_obj().is_some());
        // Change one counter: a following frame is a delta carrying it.
        Registry::global().inc("test.routes.metrics_stream.ticks");
        let mut saw = false;
        for _ in 0..50 {
            let chunk = String::from_utf8(s.next_chunk().unwrap().unwrap()).unwrap();
            if chunk.contains("counter.test.routes.metrics_stream.ticks") {
                assert!(chunk.contains("\"kind\":\"delta\""), "{chunk}");
                saw = true;
                break;
            }
        }
        assert!(saw, "delta frame carries the changed counter");
        // format negotiation mirrors the other stream routes
        let mut req = get("/metrics/stream");
        req.query.push(("format".into(), "sse".into()));
        let r = st.handle(&req);
        assert_eq!(r.content_type, "text/event-stream");
        let mut s = r.stream.unwrap();
        let first = String::from_utf8(s.next_chunk().unwrap().unwrap()).unwrap();
        assert!(first.starts_with("id: "), "{first}");
        let mut req = get("/metrics/stream");
        req.query.push(("format".into(), "xml".into()));
        assert_eq!(st.handle(&req).status, 400);
        assert_eq!(st.handle(&post("/metrics/stream", "")).status, 405);
    }

    #[test]
    fn trace_stream_replays_and_filters() {
        // Publish straight to the global span bus rather than toggling the
        // sink's stream flag (other tests share the sink; only the obs
        // sink unit test flips that switch).
        let st = state().with_stream_heartbeat(Duration::from_millis(20));
        let bus = crate::obs::sink().span_bus();
        bus.publish_json(&Json::obj(vec![
            ("kind", Json::Str("span".into())),
            ("name", Json::Str("routes-test".into())),
            ("trace_id", Json::Str("tr-routes-filter".into())),
        ]));
        bus.publish_json(&Json::obj(vec![
            ("kind", Json::Str("span".into())),
            ("name", Json::Str("routes-test".into())),
            ("trace_id", Json::Str("tr-routes-other".into())),
        ]));
        let mut req = get("/v1/trace/stream");
        req.query.push(("trace_id".into(), "tr-routes-filter".into()));
        let r = st.handle(&req);
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "application/x-ndjson");
        let mut s = r.stream.expect("span firehose is streamed");
        let first = String::from_utf8(s.next_chunk().unwrap().unwrap()).unwrap();
        assert!(first.contains("tr-routes-filter"), "{first}");
        // The non-matching span is filtered out: the next frame is a
        // keep-alive (or another match), never `tr-routes-other`.
        let next = String::from_utf8(s.next_chunk().unwrap().unwrap()).unwrap();
        assert!(!next.contains("tr-routes-other"), "{next}");
        drop(s);
        // Unfiltered: the replay carries both spans.
        let r = st.handle(&get("/v1/trace/stream"));
        let mut s = r.stream.unwrap();
        let mut seen = String::new();
        for _ in 0..200 {
            seen.push_str(&String::from_utf8(s.next_chunk().unwrap().unwrap()).unwrap());
            if seen.contains("tr-routes-filter") && seen.contains("tr-routes-other") {
                break;
            }
        }
        assert!(seen.contains("tr-routes-filter"), "{seen}");
        assert!(seen.contains("tr-routes-other"), "{seen}");
        let mut req = get("/v1/trace/stream");
        req.query.push(("format".into(), "xml".into()));
        assert_eq!(st.handle(&req).status, 400);
        assert_eq!(st.handle(&post("/v1/trace/stream", "")).status, 405);
    }

    #[test]
    fn job_trace_after_cancel_serves_flushed_prefix() {
        let st = state();
        let id = submit_job(&st, "{}");
        st.handle(&delete(&format!("/v1/jobs/{id}")));
        // Cancelled or already done — either way the route must answer
        // with whatever prefix of the timeline was flushed, never a 5xx.
        let _ = st.svc.wait(id as u64);
        let r = st.handle(&get(&format!("/v1/jobs/{id}/trace")));
        assert_eq!(r.status, 200, "trace after DELETE must not fail");
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert!(j.get("trace_id").and_then(Json::as_str).is_some());
        assert!(j.get("spans").unwrap().as_arr().is_some());
    }

    #[test]
    fn per_route_metrics_recorded() {
        let st = state();
        let before = Registry::global().counter("service.route.healthz.requests");
        st.handle(&get("/healthz"));
        let after = Registry::global().counter("service.route.healthz.requests");
        assert!(after > before, "route counter increments");
        assert!(
            Registry::global()
                .summary("service.route.healthz.seconds")
                .is_some(),
            "route latency histogram recorded"
        );
        // unknown paths do not mint per-route series (scanner safety)
        assert_eq!(route_class(&["totally", "unknown"]), None);
        // the error counter is 5xx-only: a 404 on a known class stays flat
        let before = Registry::global().counter("service.route.jobs.errors");
        st.handle(&get("/v1/jobs/99999"));
        let after = Registry::global().counter("service.route.jobs.errors");
        assert_eq!(after, before);
    }
}
