//! Durable job recovery: a write-ahead log of submitted job specs.
//!
//! Every accepted scope/scenario submission is journalled here **before**
//! its driver starts, under [`crate::obs::journal`]'s size-rotated NDJSON
//! machinery with `fsync=always` — a `submit` record survives any crash
//! that happens after the client's 202. When the job reaches a terminal
//! state (done / failed / cancelled) a matching `terminal` record is
//! appended. On a `serve --resume` start, [`JobWal::pending`] returns the
//! submits with no terminal record — the jobs a crashed process accepted
//! but never finished — and the service resubmits them. Replay is
//! bit-identical for sweep jobs: the payload round-trips the full
//! [`crate::coordinator::SweepSpec`] (see
//! [`crate::config::sweep_spec_to_json`]) and trials are seed-determined,
//! so a resumed job recomputes exactly the cells the lost one would have
//! (a warm cell cache serves the already-measured prefix without
//! re-running a single trial).
//!
//! The WAL shares a directory format with the telemetry journal but uses
//! its own `wal.` file prefix, so both can even share one directory
//! without clashing sequence files. Append failures follow journal
//! semantics — counted and logged, never propagated — so a dying disk
//! degrades durability without taking submissions down;
//! [`JobWal::errors`] feeds the service's `/healthz` degradation report.

use crate::obs::journal::{self, FsyncPolicy, Journal, JournalConfig};
use crate::util::json::Json;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// WAL journal file prefix (`wal.<seq>.ndjson`), distinct from the
/// telemetry journal's `telemetry.` so the two never clash.
pub const WAL_FILE_PREFIX: &str = "wal.";

/// A `submit` record with no matching `terminal` record: a job an earlier
/// process accepted but never finished.
#[derive(Clone, Debug)]
pub struct PendingJob {
    /// WAL identity of the original submission (not the job id — job ids
    /// restart at 1 on every boot; WAL ids are monotonic across restarts).
    pub wal_id: u64,
    /// Job kind: `"sweep"` or `"scenario"`.
    pub kind: String,
    /// The submission payload (spec JSON + weight + optional context).
    pub payload: Json,
}

/// The job write-ahead log. One instance per server; cheap to share via
/// `Arc` (appends serialize on the journal's internal writer lock).
pub struct JobWal {
    journal: Journal,
    next_id: AtomicU64,
}

impl JobWal {
    /// Open (or create) the WAL under `dir`. Scans existing records to
    /// continue the monotonic `wal_id` sequence across restarts.
    pub fn open(dir: &Path) -> anyhow::Result<JobWal> {
        let max_id = journal::read_records_with_prefix(dir, WAL_FILE_PREFIX)?
            .iter()
            .filter_map(|r| r.get("wal_id").and_then(Json::as_usize))
            .max()
            .unwrap_or(0) as u64;
        let cfg = JournalConfig {
            fsync: FsyncPolicy::Always,
            file_prefix: WAL_FILE_PREFIX.to_string(),
            ..JournalConfig::new(dir)
        };
        Ok(JobWal {
            journal: Journal::open(cfg)?,
            next_id: AtomicU64::new(max_id + 1),
        })
    }

    /// Journal a job submission; returns its WAL id. `kind` is `"sweep"`
    /// or `"scenario"`; `payload` must round-trip everything resubmission
    /// needs. Append failures are counted, not propagated (see module
    /// docs) — the id is minted either way so terminal records stay
    /// pairable.
    pub fn log_submit(&self, kind: &str, payload: Json) -> u64 {
        let wal_id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.journal.append(&Json::obj(vec![
            ("kind", Json::Str("submit".to_string())),
            ("wal_id", Json::Num(wal_id as f64)),
            ("job", Json::Str(kind.to_string())),
            ("ts_ms", Json::Num(now_ms() as f64)),
            ("payload", payload),
        ]));
        wal_id
    }

    /// Journal a job's terminal state (`done` / `failed` / `cancelled`,
    /// plus `resumed` for entries handed off to a replacement submission
    /// at resume time). After this the submission is no longer pending.
    pub fn log_terminal(&self, wal_id: u64, state: &str) {
        self.journal.append(&Json::obj(vec![
            ("kind", Json::Str("terminal".to_string())),
            ("wal_id", Json::Num(wal_id as f64)),
            ("state", Json::Str(state.to_string())),
            ("ts_ms", Json::Num(now_ms() as f64)),
        ]));
    }

    /// The submissions with no terminal record, in WAL-id order —
    /// everything a crashed process accepted but never finished. Reads
    /// the files on disk (tolerating a torn tail), so it reflects what
    /// actually survived, not what this process believes it wrote.
    pub fn pending(&self) -> anyhow::Result<Vec<PendingJob>> {
        let records =
            journal::read_records_with_prefix(self.journal.dir(), WAL_FILE_PREFIX)?;
        let mut submits: std::collections::BTreeMap<u64, PendingJob> = Default::default();
        for r in &records {
            let Some(wal_id) = r.get("wal_id").and_then(Json::as_usize).map(|n| n as u64)
            else {
                continue;
            };
            match r.get("kind").and_then(Json::as_str) {
                Some("submit") => {
                    submits.insert(
                        wal_id,
                        PendingJob {
                            wal_id,
                            kind: r
                                .get("job")
                                .and_then(Json::as_str)
                                .unwrap_or("sweep")
                                .to_string(),
                            payload: r.get("payload").cloned().unwrap_or(Json::Null),
                        },
                    );
                }
                Some("terminal") => {
                    submits.remove(&wal_id);
                }
                _ => {}
            }
        }
        Ok(submits.into_values().collect())
    }

    /// Flush buffered bytes to stable storage (drain path; appends are
    /// already fsynced individually under `FsyncPolicy::Always`).
    pub fn flush(&self) {
        self.journal.flush();
    }

    /// Records successfully appended since open.
    pub fn appended(&self) -> u64 {
        self.journal.appended()
    }

    /// Append errors since open (each is logged; feeds `/healthz`).
    pub fn errors(&self) -> u64 {
        self.journal.errors()
    }
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_millis() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wal_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("cs_wal_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn submit_terminal_pending_roundtrip() {
        let dir = wal_dir("roundtrip");
        let wal = JobWal::open(&dir).unwrap();
        assert!(wal.pending().unwrap().is_empty());
        let payload = |n: f64| Json::obj(vec![("weight", Json::Num(n))]);
        let a = wal.log_submit("sweep", payload(1.0));
        let b = wal.log_submit("scenario", payload(2.0));
        assert_ne!(a, b);
        wal.log_terminal(a, "done");
        let pending = wal.pending().unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].wal_id, b);
        assert_eq!(pending[0].kind, "scenario");
        assert_eq!(
            pending[0].payload.get("weight").and_then(Json::as_f64),
            Some(2.0)
        );
        assert_eq!(wal.appended(), 3);
        assert_eq!(wal.errors(), 0);
    }

    #[test]
    fn wal_ids_stay_monotonic_across_reopen() {
        let dir = wal_dir("reopen");
        let first = {
            let wal = JobWal::open(&dir).unwrap();
            wal.log_submit("sweep", Json::Null)
        };
        // Reopen (as a restarted process would): the pending submit is
        // visible and new ids continue past every recorded one.
        let wal = JobWal::open(&dir).unwrap();
        let pending = wal.pending().unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].wal_id, first);
        let second = wal.log_submit("sweep", Json::Null);
        assert!(second > first, "{second} vs {first}");
        wal.log_terminal(first, "resumed");
        wal.log_terminal(second, "done");
        assert!(wal.pending().unwrap().is_empty());
    }

    #[test]
    fn torn_tail_keeps_whole_records_pending() {
        let dir = wal_dir("torn");
        {
            let wal = JobWal::open(&dir).unwrap();
            wal.log_submit("sweep", Json::obj(vec![("weight", Json::Num(1.0))]));
        }
        // Simulate a crash mid-append: a half-written record at the tail.
        let (_, path) = journal::list_files_with_prefix(&dir, WAL_FILE_PREFIX)
            .unwrap()
            .pop()
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"kind\":\"submit\",\"wal_id\":99");
        std::fs::write(&path, bytes).unwrap();
        let wal = JobWal::open(&dir).unwrap();
        let pending = wal.pending().unwrap();
        assert_eq!(pending.len(), 1, "torn record ignored, whole one kept");
        // The torn id never entered the sequence; new ids continue from
        // the last *whole* record.
        assert_eq!(wal.log_submit("sweep", Json::Null), pending[0].wal_id + 1);
    }
}
