//! **BENCH-1**: shared trial executor vs the sequential-leader baseline.
//!
//! The service front used to drain jobs strictly one at a time through a
//! single leader thread, so one customer's giant sweep head-of-line-blocked
//! every other tenant's small request. This benchmark reproduces that
//! multi-tenant mix — `N` small scoping jobs submitted alongside one large
//! sweep — under both disciplines:
//!
//! 1. **sequential-leader baseline** — jobs run one at a time in
//!    submission order (large first), exactly the old FIFO;
//! 2. **fair executor** — all jobs submitted to a [`ScopingService`],
//!    whose shared [`TrialExecutor`] interleaves `(cell, trial)` tasks
//!    across jobs with weighted fair queueing.
//!
//! Asserts the small jobs' **p95 completion latency improves ≥ 3×** under
//! fair scheduling. Distinct per-job seeds keep every measurement fresh
//! (no cache involved on either side).
//!
//! Output: `results/BENCH_scheduler.json` (the first entry of the bench
//! trajectory) + `results/throughput_scheduler.csv`. `--quick` (or
//! `CS_BENCH_QUICK=1`) shrinks the workload.
//!
//! [`TrialExecutor`]: containerstress::util::threadpool::TrialExecutor
//! [`ScopingService`]: containerstress::coordinator::jobs::ScopingService

use containerstress::bench::figs;
use containerstress::coordinator::jobs::ScopingService;
use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::report;
use containerstress::util::json::Json;
use std::time::Instant;

/// Number of concurrent small (interactive-tenant) jobs.
const SMALL_JOBS: usize = 8;

/// A 10-cell-scale interactive request: milliseconds of work.
fn small_spec(i: usize) -> SweepSpec {
    SweepSpec {
        signals: vec![2],
        memvecs: vec![8],
        obs: vec![16],
        trials: 1,
        seed: 1000 + i as u64,
        model: "mset2".into(),
        workers: 1,
        ..SweepSpec::default()
    }
}

/// The bulk tenant: a grid heavy enough to dominate the leader queue.
fn large_spec(quick: bool) -> SweepSpec {
    SweepSpec {
        signals: vec![2, 3],
        memvecs: vec![8, 12],
        obs: if quick { vec![1024] } else { vec![2048] },
        trials: if quick { 3 } else { 6 },
        seed: 77,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    }
}

fn p95(lat: &[f64]) -> f64 {
    let mut xs = lat.to_vec();
    xs.sort_by(f64::total_cmp);
    let idx = ((xs.len() as f64 * 0.95).ceil() as usize).max(1) - 1;
    xs[idx.min(xs.len() - 1)]
}

fn mean(lat: &[f64]) -> f64 {
    lat.iter().sum::<f64>() / lat.len() as f64
}

fn main() {
    containerstress::util::logger::init();
    let quick = figs::quick();
    let large = large_spec(quick);
    println!(
        "throughput_scheduler: 1 large job ({} cells × {} trials) + {SMALL_JOBS} small jobs",
        large.signals.len() * large.memvecs.len() * large.obs.len(),
        large.trials
    );

    // --- baseline: the old single-leader FIFO, large job first -----------
    let t0 = Instant::now();
    let mut seq_lat = Vec::with_capacity(SMALL_JOBS);
    run_sweep(&large, Backend::Native).expect("large sweep (sequential)");
    for i in 0..SMALL_JOBS {
        run_sweep(&small_spec(i), Backend::Native).expect("small sweep (sequential)");
        seq_lat.push(t0.elapsed().as_secs_f64());
    }
    let seq_total = t0.elapsed().as_secs_f64();

    // --- fair executor: all jobs concurrent, trials interleaved ----------
    let svc = ScopingService::start(Backend::Native, SMALL_JOBS + 2);
    let t0 = Instant::now();
    let large_id = svc.submit(large_spec(quick)).expect("submit large");
    let ids: Vec<_> = (0..SMALL_JOBS)
        .map(|i| svc.submit(small_spec(i)).expect("submit small"))
        .collect();
    let mut fair_lat = vec![0.0f64; SMALL_JOBS];
    std::thread::scope(|scope| {
        let svc = &svc;
        let handles: Vec<_> = ids
            .iter()
            .map(|&id| {
                scope.spawn(move || {
                    svc.wait(id).expect("small job");
                    t0.elapsed().as_secs_f64()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            fair_lat[i] = h.join().expect("join waiter");
        }
    });
    svc.wait(large_id).expect("large job");
    let fair_total = t0.elapsed().as_secs_f64();
    svc.shutdown();

    let (p95_seq, p95_fair) = (p95(&seq_lat), p95(&fair_lat));
    let speedup = p95_seq / p95_fair.max(1e-9);
    println!(
        "{:<18} {:>14} {:>14} {:>14}",
        "discipline", "small_p95_s", "small_mean_s", "makespan_s"
    );
    println!(
        "{:<18} {:>14.4} {:>14.4} {:>14.4}",
        "sequential-leader",
        p95_seq,
        mean(&seq_lat),
        seq_total
    );
    println!(
        "{:<18} {:>14.4} {:>14.4} {:>14.4}",
        "fair-executor",
        p95_fair,
        mean(&fair_lat),
        fair_total
    );
    println!("small-job p95 latency speedup: {speedup:.1}x");
    assert!(
        speedup >= 3.0,
        "fair scheduling must improve small-job p95 latency ≥3x over the \
         sequential leader (got {speedup:.2}x: {p95_seq:.4}s vs {p95_fair:.4}s)"
    );

    let dir = std::path::Path::new("results");
    let mut csv = String::from("discipline,small_p95_s,small_mean_s,makespan_s\n");
    csv.push_str(&format!(
        "sequential-leader,{p95_seq},{},{seq_total}\n",
        mean(&seq_lat)
    ));
    csv.push_str(&format!(
        "fair-executor,{p95_fair},{},{fair_total}\n",
        mean(&fair_lat)
    ));
    report::write(dir, "throughput_scheduler.csv", &csv).unwrap();
    let json = Json::obj(vec![
        ("bench", Json::Str("throughput_scheduler".into())),
        ("small_jobs", Json::Num(SMALL_JOBS as f64)),
        ("quick", Json::Bool(quick)),
        (
            "sequential",
            Json::obj(vec![
                ("small_p95_s", Json::Num(p95_seq)),
                ("small_mean_s", Json::Num(mean(&seq_lat))),
                ("makespan_s", Json::Num(seq_total)),
            ]),
        ),
        (
            "fair",
            Json::obj(vec![
                ("small_p95_s", Json::Num(p95_fair)),
                ("small_mean_s", Json::Num(mean(&fair_lat))),
                ("makespan_s", Json::Num(fair_total)),
            ]),
        ),
        ("p95_speedup", Json::Num(speedup)),
    ]);
    report::write(dir, "BENCH_scheduler.json", &json.to_pretty()).unwrap();
    println!(
        "throughput_scheduler done → results/BENCH_scheduler.json, \
         results/throughput_scheduler.csv"
    );
}
