//! Small dense linear algebra substrate (no external BLAS available
//! offline). Backs TPSS cross-correlation shaping, response-surface fitting
//! and the native MSET2 oracle.
//!
//! Layered in three pieces:
//!
//! - [`mat`] — the row-major `Mat` container and its convenience ops;
//! - [`kernel`] — the cache-blocked, register-tiled compute core
//!   (`gemm_nt` / packed-panel `matmul` / `syrk` / fused squared-distance
//!   kernels) plus naive [`kernel::reference`] oracles;
//! - [`simd`] — the runtime-dispatched explicit-SIMD tier (AVX2+FMA /
//!   NEON) the kernel core routes to when opted in via `--kernel-backend`
//!   or `CONTAINERSTRESS_KERNEL`; documented tolerance mode, scalar stays
//!   the bit-identical default;
//! - [`workspace`] — the per-thread scratch arena that makes the kernel
//!   `_into` entry points allocation-free in steady state.
//!
//! See `docs/ARCHITECTURE.md` §"Kernel core" for the blocking scheme and
//! the bit-stability contract, and `benches/kernel_hotpath.rs` for the
//! gated speedups (`BENCH_kernel.json`).

pub mod decomp;
pub mod kernel;
pub mod mat;
pub mod simd;
pub mod workspace;

pub use decomp::{cholesky, eigh, eigh_into, lstsq, reg_pinv, reg_pinv_into, solve_spd};
pub use mat::Mat;
pub use workspace::Workspace;
