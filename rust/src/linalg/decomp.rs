//! Matrix decompositions: Cholesky, QR least squares, symmetric Jacobi
//! eigendecomposition, and the regularised pseudo-inverse MSET training uses.

use super::mat::Mat;

/// Cholesky factor `L` with `L Lᵀ = A` for symmetric positive-definite `A`.
/// Returns `None` if a pivot drops below `eps` (not SPD).
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky: square required");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 1e-14 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    let n = a.rows;
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    // back: Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    Some(x)
}

/// Least squares `min ‖A x − b‖₂` via normal equations with ridge fallback:
/// used by the response-surface fitter where `A` is tall and well-scaled.
pub fn lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    let at = a.transpose();
    let mut ata = at.matmul(a);
    let atb = at.matvec(b);
    // Tikhonov jitter escalates until the system factors.
    let trace: f64 = (0..ata.rows).map(|i| ata[(i, i)]).sum();
    let mut jitter = 1e-12 * trace.max(1.0) / ata.rows as f64;
    for _ in 0..12 {
        if let Some(x) = solve_spd(&ata, &atb) {
            return x;
        }
        for i in 0..ata.rows {
            ata[(i, i)] += jitter;
        }
        jitter *= 10.0;
    }
    panic!("lstsq: normal equations failed to factor");
}

/// Symmetric eigendecomposition by cyclic Jacobi rotations.
/// Returns `(eigenvalues, V)` with `A = V diag(w) Vᵀ`, eigenvalues ascending.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "eigh: square required");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // sort ascending, permute V columns to match
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| w[i].partial_cmp(&w[j]).unwrap());
    let wv: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let mut vs = Mat::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            vs[(r, new_c)] = v[(r, old_c)];
        }
    }
    w = wv;
    (w, vs)
}

/// Regularised symmetric pseudo-inverse: `(A + λI)⁻¹` computed through the
/// eigendecomposition with an eigenvalue floor — the same construction the
/// paper applies to the MSET similarity matrix via cuSOLVER.
pub fn reg_pinv(a: &Mat, lambda: f64) -> Mat {
    let (w, v) = eigh(a);
    let n = a.rows;
    let floor = 1e-12 * w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
    let mut out = Mat::zeros(n, n);
    // out = V diag(1/(w+λ)) Vᵀ
    for k in 0..n {
        let d = 1.0 / (w[k] + lambda).max(floor);
        for i in 0..n {
            let vik = v[(i, k)] * d;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += vik * v[(j, k)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let mut b = Mat::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.gauss();
        }
        let bt = b.transpose();
        let mut a = bt.matmul(&b);
        for i in 0..n {
            a[(i, i)] += n as f64; // well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9, "diff={}", a.max_abs_diff(&rec));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eig −1, 3
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_roundtrip() {
        let mut rng = Rng::new(2);
        let a = random_spd(10, &mut rng);
        let x_true: Vec<f64> = (0..10).map(|i| i as f64 - 4.5).collect();
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn lstsq_recovers_line() {
        // y = 2 + 3x, overdetermined
        let xs: Vec<f64> = (0..20).map(|i| i as f64 / 5.0).collect();
        let a = Mat::from_rows(xs.iter().map(|&x| vec![1.0, x]).collect());
        let b: Vec<f64> = xs.iter().map(|&x| 2.0 + 3.0 * x).collect();
        let c = lstsq(&a, &b);
        assert!((c[0] - 2.0).abs() < 1e-9 && (c[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn eigh_reconstructs_and_orthogonal() {
        let mut rng = Rng::new(3);
        let a = random_spd(12, &mut rng);
        let (w, v) = eigh(&a);
        // ascending
        for k in 1..w.len() {
            assert!(w[k] >= w[k - 1]);
        }
        // V diag(w) Vᵀ == A
        let mut d = Mat::zeros(12, 12);
        for i in 0..12 {
            d[(i, i)] = w[i];
        }
        let rec = v.matmul(&d).matmul(&v.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-8, "diff={}", a.max_abs_diff(&rec));
        // VᵀV == I
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.max_abs_diff(&Mat::eye(12)) < 1e-9);
    }

    #[test]
    fn eigh_known_2x2() {
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (w, _) = eigh(&a);
        assert!((w[0] - 1.0).abs() < 1e-10 && (w[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn reg_pinv_inverts_well_conditioned() {
        let mut rng = Rng::new(4);
        let a = random_spd(6, &mut rng);
        let inv = reg_pinv(&a, 0.0);
        let eye = a.matmul(&inv);
        assert!(eye.max_abs_diff(&Mat::eye(6)) < 1e-7);
    }

    #[test]
    fn reg_pinv_handles_singular() {
        // rank-1 matrix; with λ>0 result stays finite
        let a = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let p = reg_pinv(&a, 0.1);
        assert!(p.data.iter().all(|x| x.is_finite()));
    }
}
