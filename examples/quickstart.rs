//! Quickstart: the whole ContainerStress stack in ~60 lines.
//!
//! 1. synthesize realistic telemetry (TPSS substrate),
//! 2. train MSET2 **on device** (AOT/PJRT artifacts),
//! 3. stream surveillance and detect an injected fault with SPRT,
//! 4. print the measured compute costs — the quantity the paper scopes.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use containerstress::detect::{Sprt, SprtConfig};
use containerstress::mset;
use containerstress::runtime::{mset::DeviceMset, DeviceServer};
use containerstress::tpss::{inject, synthesize, Fault, TpssConfig};

fn main() -> anyhow::Result<()> {
    containerstress::util::logger::init();

    // --- 1. telemetry ------------------------------------------------------
    let n_signals = 8;
    let cfg = TpssConfig::sized(n_signals, 2048);
    let train_ds = synthesize(&cfg, 1);
    println!(
        "synthesized {} observations × {} signals of telemetry",
        train_ds.data.rows, train_ds.data.cols
    );

    // --- 2. train on device -------------------------------------------------
    let server = DeviceServer::start(containerstress::runtime::default_artifact_dir())?;
    let model = mset::train(&train_ds.data, 64)?; // scaling + memory selection (L3)
    let mut sess = DeviceMset::new(server.handle(), &model.d)?;
    let (_g, train_cost) = sess.train()?;
    println!(
        "trained MSET2 (m=64) on device in {:.3} ms (bucket n={}, m={})",
        train_cost.exec.as_secs_f64() * 1e3,
        sess.bucket.n,
        sess.bucket.m
    );

    // --- 3. surveil + detect ------------------------------------------------
    let healthy = synthesize(&cfg, 2);
    let (_, resid_h, _) = sess.surveil(&model.scaler.transform(&healthy.data))?;
    let mut detector = Sprt::from_healthy(
        &resid_h,
        SprtConfig {
            alpha: 1e-6,
            beta: 1e-4,
            shift: 4.5,
            var_ratio: 6.0,
        },
    );

    let mut stream = synthesize(&cfg, 3);
    let onset = inject(&mut stream, 5, Fault::Drift { magnitude: 6.0 }, 0.5, 4);
    let (_, resid, surveil_cost) = sess.surveil(&model.scaler.transform(&stream.data))?;
    let alarms = detector.run(&resid);
    let first = alarms
        .iter()
        .find(|a| a.signal == 5 && a.at >= onset)
        .expect("drift must be detected");
    println!(
        "injected 6σ drift on signal 5 at t={onset}; detected at t={} (latency {})",
        first.at,
        first.at - onset
    );

    // --- 4. the scoped quantity ---------------------------------------------
    println!(
        "surveillance compute cost: {:.3} ms for {} observations ({:.1} µs/obs, {} device calls)",
        surveil_cost.exec.as_secs_f64() * 1e3,
        stream.data.rows,
        surveil_cost.exec.as_secs_f64() * 1e6 / stream.data.rows as f64,
        surveil_cost.calls
    );
    Ok(())
}
