//! Bucket routing and padding.
//!
//! XLA executables are shape-specialised; the AOT pipeline ships a grid of
//! (n_signals, n_memvec) buckets. The router picks the smallest bucket that
//! fits a workload and zero-pads tensors up to it. Correctness of padding
//! relies on the masking contract of the L2 graphs (`model.py`):
//! similarity bandwidth is passed separately (γ·√n_real) and padded memory
//! rows are masked out of S and K.

use crate::linalg::Mat;

/// A (signals, memvecs) bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// Bucket signal count.
    pub n: usize,
    /// Bucket memory-vector count.
    pub m: usize,
}

/// Pick the smallest bucket (by padded area `n·m`, ties toward smaller n)
/// that fits `(n_real, m_real)`. `buckets` need not be sorted.
pub fn pick_bucket(buckets: &[(usize, usize)], n_real: usize, m_real: usize) -> Option<Bucket> {
    buckets
        .iter()
        .filter(|&&(n, m)| n >= n_real && m >= m_real)
        .min_by_key(|&&(n, m)| (n * m, n, m))
        .map(|&(n, m)| Bucket { n, m })
}

/// Zero-pad a matrix (rows × cols) to (rows_to × cols_to), row-major f32.
pub fn pad_mat_f32(x: &Mat, rows_to: usize, cols_to: usize) -> Vec<f32> {
    let mut out = Vec::new();
    pad_mat_f32_into(x, rows_to, cols_to, &mut out);
    out
}

/// [`pad_mat_f32`] into a caller-owned buffer, so streaming loops reuse
/// one staging allocation per session instead of one per chunk.
pub fn pad_mat_f32_into(x: &Mat, rows_to: usize, cols_to: usize, out: &mut Vec<f32>) {
    assert!(x.rows <= rows_to && x.cols <= cols_to, "pad smaller than data");
    out.clear();
    out.resize(rows_to * cols_to, 0.0);
    for r in 0..x.rows {
        let src = x.row(r);
        let dst = &mut out[r * cols_to..r * cols_to + x.cols];
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d = s as f32;
        }
    }
}

/// Extract the top-left (rows × cols) block from a padded row-major buffer.
pub fn unpad_mat_f32(data: &[f32], padded_cols: usize, rows: usize, cols: usize) -> Mat {
    let mut out = Mat::zeros(rows, cols);
    unpad_rows_f32_into(data, padded_cols, rows, cols, &mut out, 0);
    out
}

/// Copy the top-left (rows × cols) block of a padded row-major buffer
/// into `out` starting at row `row0` — lets the streaming surveillance
/// loop land device chunks directly in the result matrix instead of
/// materialising an intermediate per chunk.
pub fn unpad_rows_f32_into(
    data: &[f32],
    padded_cols: usize,
    rows: usize,
    cols: usize,
    out: &mut Mat,
    row0: usize,
) {
    assert!(data.len() >= rows * padded_cols);
    assert!(cols <= padded_cols && cols <= out.cols && row0 + rows <= out.rows);
    for r in 0..rows {
        let src = &data[r * padded_cols..r * padded_cols + cols];
        for (d, &s) in out.row_mut(row0 + r)[..cols].iter_mut().zip(src.iter()) {
            *d = s as f64;
        }
    }
}

/// Memory-vector mask: 1.0 for the first `m_real` slots, 0.0 for padding.
pub fn mask_f32(m_real: usize, m_bucket: usize) -> Vec<f32> {
    assert!(m_real <= m_bucket);
    let mut v = vec![0.0f32; m_bucket];
    for s in v.iter_mut().take(m_real) {
        *s = 1.0;
    }
    v
}

/// Similarity bandwidth for the *unpadded* signal count.
pub fn bandwidth(gamma: f64, n_real: usize) -> f32 {
    (gamma * (n_real as f64).sqrt()) as f32
}

/// Number of `chunk`-row device calls needed for `rows` observations.
pub fn n_chunks(rows: usize, chunk: usize) -> usize {
    rows.div_ceil(chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_res;
    use crate::util::rng::Rng;

    const GRID: &[(usize, usize)] = &[
        (8, 32),
        (8, 64),
        (16, 32),
        (16, 64),
        (32, 64),
        (32, 128),
        (64, 128),
        (64, 256),
        (128, 256),
        (128, 512),
    ];

    #[test]
    fn picks_exact_bucket_when_available() {
        assert_eq!(
            pick_bucket(GRID, 16, 64),
            Some(Bucket { n: 16, m: 64 })
        );
    }

    #[test]
    fn picks_smallest_feasible() {
        // 9 signals, 40 memvecs → (16, 64) has area 1024; (16,32) can't fit m.
        assert_eq!(pick_bucket(GRID, 9, 40), Some(Bucket { n: 16, m: 64 }));
        // 1 signal, 1 memvec → (8, 32)
        assert_eq!(pick_bucket(GRID, 1, 1), Some(Bucket { n: 8, m: 32 }));
    }

    #[test]
    fn none_when_too_large() {
        assert_eq!(pick_bucket(GRID, 200, 32), None);
        assert_eq!(pick_bucket(GRID, 8, 1024), None);
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let mut rng = Rng::new(1);
        let mut x = Mat::zeros(5, 3);
        rng.fill_gauss(&mut x.data);
        let padded = pad_mat_f32(&x, 8, 4);
        assert_eq!(padded.len(), 32);
        // padding area is zero
        assert_eq!(padded[3], 0.0); // row 0, col 3
        assert_eq!(padded[8 * 4 - 1], 0.0);
        let back = unpad_mat_f32(&padded, 4, 5, 3);
        assert!(x.max_abs_diff(&back) < 1e-6);
    }

    #[test]
    fn into_variants_match_allocating() {
        let mut rng = Rng::new(2);
        let mut x = Mat::zeros(4, 3);
        rng.fill_gauss(&mut x.data);
        let mut buf = vec![7.0f32; 3]; // stale contents must be cleared
        pad_mat_f32_into(&x, 6, 5, &mut buf);
        assert_eq!(buf, pad_mat_f32(&x, 6, 5));
        // unpad into an offset row window
        let mut out = Mat::zeros(10, 3);
        unpad_rows_f32_into(&buf, 5, 4, 3, &mut out, 2);
        let whole = unpad_mat_f32(&buf, 5, 4, 3);
        for r in 0..4 {
            assert_eq!(out.row(r + 2), whole.row(r));
        }
        assert!(out.row(0).iter().all(|&v| v == 0.0));
        assert!(out.row(9).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mask_layout() {
        let m = mask_f32(3, 6);
        assert_eq!(m, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn chunk_count() {
        assert_eq!(n_chunks(0, 32), 0);
        assert_eq!(n_chunks(1, 32), 1);
        assert_eq!(n_chunks(32, 32), 1);
        assert_eq!(n_chunks(33, 32), 2);
    }

    #[test]
    fn prop_router_minimal_and_feasible() {
        forall_res(
            "router picks the smallest feasible bucket",
            300,
            |rng| {
                let n = rng.range_usize(1, 140);
                let m = rng.range_usize(1, 600);
                (n, m)
            },
            |&(n, m)| {
                match pick_bucket(GRID, n, m) {
                    None => {
                        // no feasible bucket may exist in the grid
                        if GRID.iter().any(|&(bn, bm)| bn >= n && bm >= m) {
                            return Err("router returned None but a bucket fits".into());
                        }
                    }
                    Some(b) => {
                        if b.n < n || b.m < m {
                            return Err(format!("bucket {b:?} does not fit ({n},{m})"));
                        }
                        // minimality: no feasible bucket with smaller area
                        if GRID
                            .iter()
                            .any(|&(bn, bm)| bn >= n && bm >= m && bn * bm < b.n * b.m)
                        {
                            return Err(format!("bucket {b:?} not minimal for ({n},{m})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_pad_preserves_content_and_zeroes_rest() {
        forall_res(
            "padding preserves content",
            100,
            |rng| {
                let r = rng.range_usize(1, 10);
                let c = rng.range_usize(1, 10);
                let rt = r + rng.range_usize(0, 6);
                let ct = c + rng.range_usize(0, 6);
                let mut x = Mat::zeros(r, c);
                rng.fill_gauss(&mut x.data);
                (x, rt, ct)
            },
            |(x, rt, ct)| {
                let p = pad_mat_f32(x, *rt, *ct);
                for r in 0..*rt {
                    for c in 0..*ct {
                        let v = p[r * ct + c] as f64;
                        let expect = if r < x.rows && c < x.cols { x[(r, c)] } else { 0.0 };
                        if (v - expect).abs() > 1e-6 {
                            return Err(format!("mismatch at ({r},{c})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
