//! **Fig. 6**: GPU training speedup factor vs (signals × memory vectors),
//! log–log axes, with the `m ≥ 2n` constraint producing the paper's
//! "missing parts of the training surface". Paper range: 200× → 1500×.
//!
//! Two surfaces are emitted:
//! - `modelled`: the paper-anchored analytic model over the paper's own
//!   parameter range (n ∈ 2⁵..2¹⁰, m ∈ 2⁷..2¹³);
//! - `anchored`: the same GPU model against a CPU term **calibrated from
//!   device-path training costs measured on this testbed** over the scaled
//!   bucket grid — demonstrating the calibration workflow end-to-end.
//!
//! Output: `results/fig6_training_speedup/`.

use containerstress::accel::{self, CpuRef, GpuSpec};
use containerstress::bench::figs;
use containerstress::report;
use containerstress::surface::SurfaceGrid;
use std::path::Path;

fn main() {
    containerstress::util::logger::init();
    let gpu = GpuSpec::v100();
    let cpu = CpuRef::xeon_platinum();
    let out = Path::new("results/fig6_training_speedup");

    // --- paper-range modelled surface --------------------------------------
    let signals: Vec<usize> = (5..=10).map(|k| 1usize << k).collect(); // 32..1024
    let memvecs: Vec<usize> = (7..=13).map(|k| 1usize << k).collect(); // 128..8192
    let mut grid = SurfaceGrid::new(
        "n_memvec",
        "n_signals",
        memvecs.iter().map(|&v| v as f64).collect(),
        signals.iter().map(|&v| v as f64).collect(),
    );
    let mut lo = f64::INFINITY;
    let mut hi = 0.0f64;
    for (r, &m) in memvecs.iter().enumerate() {
        for (c, &n) in signals.iter().enumerate() {
            if m < 2 * n {
                continue; // the paper's missing surface cells
            }
            let s = accel::speedup_train(n, m, &gpu, &cpu);
            lo = lo.min(s);
            hi = hi.max(s);
            grid.set(r, c, s);
        }
    }
    let ascii = report::emit_figure(
        out,
        "fig6_modelled",
        "Fig6: GPU training speedup (modelled, log-log)",
        &grid,
        "speedup",
        true,
    )
    .expect("emit");
    println!("{ascii}");
    println!(
        "modelled speedup range {:.0}× → {:.0}×  (paper: 200× → 1500×); coverage {:.0}% (gaps = m<2n)",
        lo,
        hi,
        grid.coverage() * 100.0
    );
    assert!(hi / lo > 2.0, "speedup must grow across the grid");
    assert!((50.0..5000.0).contains(&lo) && (500.0..6000.0).contains(&hi));

    // --- locally-anchored surface over the measured bucket grid -------------
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (sig_b, mem_b) = figs::available_axes(&handle);
    let trials = if figs::quick() { 1 } else { 2 };
    let mut measured = Vec::new();
    let mut grid_local = SurfaceGrid::new(
        "n_memvec",
        "n_signals",
        mem_b.iter().map(|&v| v as f64).collect(),
        sig_b.iter().map(|&v| v as f64).collect(),
    );
    for (r, &m) in mem_b.iter().enumerate() {
        for (c, &n) in sig_b.iter().enumerate() {
            if m < 2 * n {
                continue;
            }
            let t = figs::median(&figs::measure_train(&handle, n, m, 2 * m, trials));
            let flops = accel::total_flops(&accel::train_routines(n, m));
            measured.push((flops, t));
            // local-CPU-anchored speedup for this cell
            let t_gpu = gpu.time(&accel::train_routines(n, m), accel::TRAIN_LAUNCHES, n);
            grid_local.set(r, c, t / t_gpu);
        }
    }
    let local_eff = accel::calibrate_cpu_eff(&measured)
        .expect("at least one measured (flops, seconds) training cell");
    println!(
        "local testbed effective training throughput: {:.2} GFLOP/s (XLA CPU, multithreaded)",
        local_eff / 1e9
    );
    let ascii = report::emit_figure(
        out,
        "fig6_anchored",
        "Fig6: speedup anchored to measured local training cost",
        &grid_local,
        "speedup",
        true,
    )
    .expect("emit");
    println!("{ascii}");
    println!("fig6 done → {}", out.display());
}
