//! Small dense linear algebra substrate (no external BLAS available
//! offline). Backs TPSS cross-correlation shaping, response-surface fitting
//! and the native MSET2 oracle. The production hot path runs inside XLA.

pub mod decomp;
pub mod mat;

pub use decomp::{cholesky, eigh, lstsq, reg_pinv, solve_spd};
pub use mat::Mat;
