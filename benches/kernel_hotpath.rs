//! **BENCH-kernel**: reference vs blocked kernel core on the native MSET
//! trial hot path (§II.D).
//!
//! Three gates, enforced with asserts so CI catches regressions:
//!
//! 1. **Accuracy** — the blocked `sim_cross`/`sim_matrix` kernels agree
//!    with the per-pair reference implementations to ≤ 1e-10 at every
//!    grid size (they are designed to be far closer; see
//!    `linalg::kernel`'s bit-stability contract).
//! 2. **Kernel speedup** — blocked `sim_cross` + Gram (`sim_matrix`)
//!    combined are ≥ 3× the reference formulations at n = 1024.
//! 3. **End-to-end** — a full native MSET2 trial (synthesize → scale →
//!    select → train → surveil) on the production kernel stack is
//!    ≥ 1.5× a twin trial built from the naive reference kernels.
//!
//! Output: `results/BENCH_kernel.json` + `results/kernel_hotpath.csv`
//! (the README perf table is sourced from the JSON). `CS_BENCH_QUICK=1`
//! shortens the measuring windows but keeps every asserted point.

use containerstress::bench::{black_box, figs, table, write_csv, Bencher, Measurement};
use containerstress::linalg::{eigh, kernel, Mat};
use containerstress::models::{MsetPlugin, PrognosticModel};
use containerstress::mset::{
    select_memory, sim_cross_ref, sim_matrix_ref, Scaler, RIDGE_REL,
};
use containerstress::report;
use containerstress::tpss::{synthesize, TpssConfig};
use containerstress::util::json::Json;
use containerstress::util::rng::Rng;

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut m = Mat::zeros(rows, cols);
    rng.fill_gauss(&mut m.data);
    m
}

/// The pre-blocked `reg_pinv`: eigendecomposition plus the naive
/// `V·diag(1/(w+λ))·Vᵀ` triple-loop reconstruction.
fn reg_pinv_ref(a: &Mat, lambda: f64) -> Mat {
    let (w, v) = eigh(a);
    let n = a.rows;
    let floor = 1e-12 * w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
    let mut out = Mat::zeros(n, n);
    for k in 0..n {
        let d = 1.0 / (w[k] + lambda).max(floor);
        for i in 0..n {
            let vik = v[(i, k)] * d;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += vik * v[(j, k)];
            }
        }
    }
    out
}

/// One native MSET2 trial on the naive reference kernels: the exact
/// pre-blocked pipeline, sharing synthesis/scaling/selection with the
/// production twin so only the kernel stack differs.
fn reference_trial(n: usize, m: usize, obs: usize, seed: u64) -> Mat {
    let train_ds = synthesize(&TpssConfig::sized(n, obs.max(m)), seed);
    let probe_ds = synthesize(&TpssConfig::sized(n, obs), seed ^ 0x5EED);
    let scaler = Scaler::fit(&train_ds.data);
    let xs = scaler.transform(&train_ds.data);
    let idx = select_memory(&xs, m);
    let mut d = Mat::zeros(m, n);
    for (r, &i) in idx.iter().enumerate() {
        d.row_mut(r).copy_from_slice(xs.row(i));
    }
    // train: S = sim(D, D), G = (S + λI)⁻¹
    let mut s = sim_matrix_ref(&d);
    let trace: f64 = (0..m).map(|i| s[(i, i)]).sum();
    let lambda = RIDGE_REL * trace / m as f64;
    for i in 0..m {
        s[(i, i)] += lambda;
    }
    let g = reg_pinv_ref(&s, 0.0);
    // surveil: X̂ = (G·K)ᵀ·D over the naive kernels
    let probe = scaler.transform(&probe_ds.data);
    let k = sim_cross_ref(&d, &probe);
    let w = kernel::reference::matmul(&g, &k);
    kernel::reference::matmul(&w.transpose(), &d)
}

/// The production twin: the same trial through `models::MsetPlugin`
/// (blocked kernels + workspace arena), returning X̂ for the accuracy
/// cross-check.
fn production_trial(n: usize, m: usize, obs: usize, seed: u64) -> Mat {
    let train_ds = synthesize(&TpssConfig::sized(n, obs.max(m)), seed);
    let probe_ds = synthesize(&TpssConfig::sized(n, obs), seed ^ 0x5EED);
    let mut plugin = MsetPlugin::default();
    plugin.fit(&train_ds.data, m).expect("fit");
    plugin.estimate(&probe_ds.data).xhat
}

fn main() {
    containerstress::util::logger::init();
    let quick = figs::quick();
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    const MAX_KERNEL_DIFF: f64 = 1e-10;
    const MIN_KERNEL_SPEEDUP: f64 = 3.0; // sim_cross + Gram at n = 1024
    const MIN_E2E_SPEEDUP: f64 = 1.5; // full native trial

    let sizes: &[usize] = if quick {
        &[64, 256, 1024]
    } else {
        &[64, 128, 256, 512, 1024]
    };

    let mut ms: Vec<Measurement> = Vec::new();
    let mut size_rows: Vec<Json> = Vec::new();
    let mut speedup_at_1024 = 0.0;
    for &n in sizes {
        // memory-vector and chunk axes capped like the paper's grid
        let m = n.min(256);
        let bsz = n.min(256);
        let d = random_mat(m, n, 1);
        let x = random_mat(bsz, n, 2);

        // accuracy gates first (one evaluation each)
        let cross_diff = containerstress::mset::sim_cross(&d, &x).max_abs_diff(&sim_cross_ref(&d, &x));
        let gram_diff = containerstress::mset::sim_matrix(&d).max_abs_diff(&sim_matrix_ref(&d));
        assert!(
            cross_diff <= MAX_KERNEL_DIFF,
            "n={n}: blocked sim_cross diverged from reference by {cross_diff}"
        );
        assert!(
            gram_diff <= MAX_KERNEL_DIFF,
            "n={n}: blocked sim_matrix diverged from reference by {gram_diff}"
        );

        let units = (m * bsz) as f64;
        let rc = b.run_with_units(&format!("ref_sim_cross_n{n}"), units, || {
            sim_cross_ref(&d, &x)
        });
        let bc = b.run_with_units(&format!("blk_sim_cross_n{n}"), units, || {
            containerstress::mset::sim_cross(&d, &x)
        });
        let gunits = (m * m) as f64 / 2.0;
        let rg = b.run_with_units(&format!("ref_gram_n{n}"), gunits, || sim_matrix_ref(&d));
        let bg = b.run_with_units(&format!("blk_gram_n{n}"), gunits, || {
            containerstress::mset::sim_matrix(&d)
        });

        let cross_speedup = rc.stats.median / bc.stats.median;
        let gram_speedup = rg.stats.median / bg.stats.median;
        let combined =
            (rc.stats.median + rg.stats.median) / (bc.stats.median + bg.stats.median);
        println!(
            "n={n} (m={m}, B={bsz}): sim_cross {cross_speedup:.2}×, gram {gram_speedup:.2}×, \
             combined {combined:.2}× (diffs {cross_diff:.2e}/{gram_diff:.2e})"
        );
        if n == 1024 {
            speedup_at_1024 = combined;
        }
        size_rows.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("m", Json::Num(m as f64)),
            ("b", Json::Num(bsz as f64)),
            ("ref_sim_cross_s", Json::Num(rc.stats.median)),
            ("blk_sim_cross_s", Json::Num(bc.stats.median)),
            ("ref_gram_s", Json::Num(rg.stats.median)),
            ("blk_gram_s", Json::Num(bg.stats.median)),
            ("speedup_sim_cross", Json::Num(cross_speedup)),
            ("speedup_gram", Json::Num(gram_speedup)),
            ("speedup_combined", Json::Num(combined)),
            ("max_diff_sim_cross", Json::Num(cross_diff)),
            ("max_diff_gram", Json::Num(gram_diff)),
        ]));
        ms.extend([rc, bc, rg, bg]);
    }
    assert!(
        speedup_at_1024 >= MIN_KERNEL_SPEEDUP,
        "blocked sim_cross+Gram at n=1024 is only {speedup_at_1024:.2}× the reference \
         (floor {MIN_KERNEL_SPEEDUP}×)"
    );

    // --- end-to-end native trial -----------------------------------------
    // A surveillance-heavy cell, mirroring the native run_trial body.
    let (tn, tm, tobs) = (32usize, 64usize, 4096usize);
    let xhat_ref = reference_trial(tn, tm, tobs, 7);
    let xhat_new = production_trial(tn, tm, tobs, 7);
    let e2e_diff = xhat_ref.max_abs_diff(&xhat_new);
    assert!(
        e2e_diff < 1e-7,
        "production trial estimate diverged from the reference pipeline: {e2e_diff}"
    );
    let rt = b.run(&format!("ref_trial_n{tn}_m{tm}_obs{tobs}"), || {
        black_box(reference_trial(tn, tm, tobs, 7))
    });
    let pt = b.run(&format!("blk_trial_n{tn}_m{tm}_obs{tobs}"), || {
        black_box(production_trial(tn, tm, tobs, 7))
    });
    let e2e_speedup = rt.stats.median / pt.stats.median;
    println!(
        "end-to-end native trial (n={tn}, m={tm}, obs={tobs}): {:.3}s → {:.3}s = {e2e_speedup:.2}× \
         (estimate diff {e2e_diff:.2e})",
        rt.stats.median, pt.stats.median
    );
    assert!(
        e2e_speedup >= MIN_E2E_SPEEDUP,
        "end-to-end native trial is only {e2e_speedup:.2}× the reference pipeline \
         (floor {MIN_E2E_SPEEDUP}×)"
    );
    ms.push(rt);
    ms.push(pt);

    // --- emit artifacts ---------------------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::Str("kernel_hotpath".into())),
        ("quick", Json::Bool(quick)),
        ("sizes", Json::Arr(size_rows)),
        (
            "e2e",
            Json::obj(vec![
                ("n", Json::Num(tn as f64)),
                ("m", Json::Num(tm as f64)),
                ("obs", Json::Num(tobs as f64)),
                (
                    "ref_trial_s",
                    Json::Num(ms[ms.len() - 2].stats.median),
                ),
                ("blk_trial_s", Json::Num(ms[ms.len() - 1].stats.median)),
                ("speedup", Json::Num(e2e_speedup)),
                ("estimate_diff", Json::Num(e2e_diff)),
            ]),
        ),
        (
            "asserted",
            Json::obj(vec![
                ("max_kernel_diff", Json::Num(MAX_KERNEL_DIFF)),
                ("min_kernel_speedup_n1024", Json::Num(MIN_KERNEL_SPEEDUP)),
                ("min_e2e_speedup", Json::Num(MIN_E2E_SPEEDUP)),
                ("kernel_speedup_n1024", Json::Num(speedup_at_1024)),
            ]),
        ),
    ]);
    let dir = std::path::Path::new("results");
    report::write(dir, "BENCH_kernel.json", &json.to_pretty()).unwrap();
    println!("{}", table(&ms));
    write_csv("results/kernel_hotpath.csv", &ms).unwrap();
    println!("kernel_hotpath done → results/BENCH_kernel.json, results/kernel_hotpath.csv");
}
