//! **BENCH-chaos**: fault-injection overhead when no failpoint is armed.
//!
//! Failpoints sit on hot paths (every trial, every cache spill, every
//! journal append), so the disarmed probe must be effectively free — one
//! relaxed atomic load and a branch. Gates, enforced with asserts so CI
//! catches regressions:
//!
//! 1. **Disarmed probe budget** — the measured cost of a disarmed
//!    `hit()`, multiplied by a deliberately generous per-trial call
//!    envelope (far more probes than any trial actually executes), must
//!    stay under 1% of the median native trial. This bounds what the
//!    chaos layer *can* add to an un-chaosed run, without asserting two
//!    noisy end-to-end medians against each other.
//! 2. **Armed-but-silent sanity** — a sweep with `executor.trial.run`
//!    armed at rate 0 (the armed lookup runs on every trial, nothing ever
//!    fires) stays within 5% of the disarmed twin: arming one point must
//!    not change the economics of a clean run.
//! 3. **Non-vacuity** — the same spec with rate 1 really injects (the
//!    run fails classified), so gates 1–2 measure live machinery.
//!
//! Output: `results/BENCH_chaos.json` + `results/chaos_overhead.csv`.
//! `CS_BENCH_QUICK=1` shortens the measuring windows but keeps every
//! asserted point.

use containerstress::bench::{black_box, figs, table, write_csv, Bencher, Measurement};
use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::report;
use containerstress::util::failpoint;
use containerstress::util::json::Json;

/// One surveillance-heavy cell, a few trials — the same hot-path shape the
/// obs-overhead bench uses, so the two budgets are directly comparable.
fn hotpath_spec(quick: bool) -> SweepSpec {
    SweepSpec {
        signals: vec![8],
        memvecs: vec![32],
        obs: vec![if quick { 1024 } else { 4096 }],
        trials: 2,
        seed: 11,
        workers: 2,
        ..SweepSpec::default()
    }
}

/// Probes charged against one trial in the budget math. A real trial
/// executes a handful (the trial hook, a couple of cache spills, a
/// journal append); 64 is a ~10× envelope so the gate survives new
/// failpoints without retuning.
const PROBES_PER_TRIAL: f64 = 64.0;

fn main() {
    containerstress::util::logger::init();
    let quick = figs::quick();
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    const MAX_DISARMED_FRACTION: f64 = 0.01; // of one trial, for PROBES_PER_TRIAL probes
    const MAX_ARMED_SILENT_RATIO: f64 = 1.05; // armed-at-rate-0 / disarmed medians

    let spec = hotpath_spec(quick);
    failpoint::disarm_all();

    // Non-vacuity: the machinery being costed really injects when told to.
    failpoint::arm_from_str("executor.trial.run:1:error:3").expect("arm");
    let err = run_sweep(&spec, Backend::Native).expect_err("rate-1 chaos must fail the run");
    assert!(
        failpoint::is_injected(&err),
        "rate-1 failure must classify as injected: {err:#}"
    );
    failpoint::disarm_all();

    // --- micro: the disarmed probe ---------------------------------------
    let probe = b.run_with_units("hit_disarmed", 1.0, || {
        black_box(failpoint::hit("executor.trial.run", black_box(1)).is_ok())
    });

    // --- end-to-end twins -------------------------------------------------
    let disarmed = b.run("sweep_chaos_disarmed", || {
        black_box(run_sweep(&spec, Backend::Native).expect("sweep"))
    });
    failpoint::arm_from_str("executor.trial.run:0:error:3").expect("arm rate 0");
    let armed_silent = b.run("sweep_chaos_armed_rate0", || {
        black_box(run_sweep(&spec, Backend::Native).expect("sweep"))
    });
    failpoint::disarm_all();

    let trials = (spec.signals.len() * spec.memvecs.len() * spec.obs.len() * spec.trials) as f64;
    let trial_s = disarmed.stats.median / trials;
    let disarmed_fraction = probe.stats.median * PROBES_PER_TRIAL / trial_s;
    let armed_ratio = armed_silent.stats.median / disarmed.stats.median;
    println!(
        "disarmed probe {:.1}ns; {PROBES_PER_TRIAL} probes = {:.5}% of a {:.4}s trial \
         (budget {:.0}%)",
        probe.stats.median * 1e9,
        disarmed_fraction * 100.0,
        trial_s,
        MAX_DISARMED_FRACTION * 100.0
    );
    println!(
        "armed-at-rate-0 sweep: {:.4}s vs disarmed {:.4}s → ratio {armed_ratio:.4} \
         (ceiling {MAX_ARMED_SILENT_RATIO})",
        armed_silent.stats.median, disarmed.stats.median
    );
    assert!(
        disarmed_fraction <= MAX_DISARMED_FRACTION,
        "disarmed failpoint probes cost {:.3}% of a trial (budget 1%)",
        disarmed_fraction * 100.0
    );
    assert!(
        armed_ratio <= MAX_ARMED_SILENT_RATIO,
        "an armed-but-silent failpoint costs {:.1}% on the trial hot path (budget 5%)",
        (armed_ratio - 1.0) * 100.0
    );

    // --- emit artifacts ---------------------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::Str("chaos_overhead".into())),
        ("quick", Json::Bool(quick)),
        (
            "sweep",
            Json::obj(vec![
                ("n", Json::Num(spec.signals[0] as f64)),
                ("m", Json::Num(spec.memvecs[0] as f64)),
                ("obs", Json::Num(spec.obs[0] as f64)),
                ("trials", Json::Num(spec.trials as f64)),
                ("disarmed_s", Json::Num(disarmed.stats.median)),
                ("armed_rate0_s", Json::Num(armed_silent.stats.median)),
            ]),
        ),
        (
            "micro",
            Json::obj(vec![
                ("hit_disarmed_s", Json::Num(probe.stats.median)),
                ("probes_per_trial", Json::Num(PROBES_PER_TRIAL)),
                ("trial_s", Json::Num(trial_s)),
            ]),
        ),
        (
            "asserted",
            Json::obj(vec![
                ("max_disarmed_fraction", Json::Num(MAX_DISARMED_FRACTION)),
                ("disarmed_fraction", Json::Num(disarmed_fraction)),
                ("max_armed_silent_ratio", Json::Num(MAX_ARMED_SILENT_RATIO)),
                ("armed_silent_ratio", Json::Num(armed_ratio)),
            ]),
        ),
    ]);
    let ms: Vec<Measurement> = vec![probe, disarmed, armed_silent];
    let dir = std::path::Path::new("results");
    report::write(dir, "BENCH_chaos.json", &json.to_pretty()).unwrap();
    println!("{}", table(&ms));
    write_csv("results/chaos_overhead.csv", &ms).unwrap();
    println!("chaos_overhead done → results/BENCH_chaos.json, results/chaos_overhead.csv");
}
