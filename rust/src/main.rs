//! `containerstress` — launcher CLI for the ContainerStress framework.
//!
//! ```text
//! containerstress sweep     run a Monte Carlo cost sweep, emit surfaces
//! containerstress scope     sweep + fit surfaces + recommend cloud shapes
//! containerstress simulate  fleet what-if scenario replay over surface oracles
//! containerstress serve     multi-tenant scoping service (HTTP JSON API)
//! containerstress speedup   emit the GPU speedup surfaces (Figs. 6–8)
//! containerstress synth     synthesize TPSS telemetry to CSV
//! containerstress detect    run MSET2+SPRT anomaly detection demo
//! containerstress shapes    print the cloud shape catalog
//! containerstress obs       summarize a serve telemetry journal offline
//! ```
//!
//! Flags: `--config file.json` plus per-key overrides (see `config`),
//! `--backend device|native`, `--kernel-backend scalar|simd|auto` (linalg
//! kernel tier), `--metrics` to dump the metrics registry.
//! `--ci-target F` (with `--pilot-trials`, `--max-trials`,
//! `--interpolate`) switches `sweep`/`scope`/`serve` from the exhaustive
//! fixed-trials loop to the adaptive sweep planner. `--chaos` arms
//! deterministic failpoints (fault injection); `serve` adds `--wal-dir` /
//! `--resume` / `--drain-deadline-ms` for durable job recovery.
//!
//! See `docs/ARCHITECTURE.md` for the module map and `docs/API.md` for the
//! `serve` endpoint reference.

use containerstress::accel::{self, CpuRef, GpuSpec};
use containerstress::config::Config;
use containerstress::linalg::simd;
use containerstress::coordinator::{run_sweep, Backend};
use containerstress::detect::{Sprt, SprtConfig};
use containerstress::metrics::Registry;
use containerstress::recommend::{recommend_from_sweep, Sla};
use containerstress::report;
use containerstress::runtime::DeviceServer;
use containerstress::service;
use containerstress::shapes::{self, Workload};
use containerstress::surface::SurfaceGrid;
use containerstress::tpss::{synthesize, Fault, TpssConfig};
use containerstress::util::cli::Args;
use containerstress::util::logger;

fn main() {
    logger::init();
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    if args.flag("metrics") {
        eprint!("{}", Registry::global().render());
    }
    std::process::exit(code);
}

/// Pin the linalg kernel tier before any trial work runs. An explicit
/// `kernel_backend` config key / `--kernel-backend` flag wins over the
/// `CONTAINERSTRESS_KERNEL` env knob; requesting `simd` on a host without
/// a vector tier is a hard error here (the config asked for it by name),
/// whereas the env knob degrades to scalar with a warning.
fn install_kernel_backend(cfg: &Config) -> anyhow::Result<()> {
    let info = match &cfg.kernel_backend {
        Some(s) => {
            // Spelling was validated by `Config::validate`; availability
            // is checked here, at install time on the actual host.
            let req = simd::BackendRequest::parse(s)
                .ok_or_else(|| anyhow::anyhow!("invalid kernel_backend '{s}'"))?;
            simd::install(req, "config")?
        }
        None => simd::dispatch_info(),
    };
    log::info!(
        "kernel backend: {} ({} mode; requested '{}' via {})",
        info.active.isa(),
        info.active.mode(),
        info.requested.as_str(),
        info.source
    );
    Ok(())
}

fn make_backend(cfg: &Config) -> anyhow::Result<(Backend, Option<DeviceServer>)> {
    install_kernel_backend(cfg)?;
    // Deterministic fault injection: arm any `--chaos` / config / env
    // specs before the first trial runs. make_backend is the common
    // gateway for every work-running command (sweep/scope/simulate/serve).
    containerstress::util::failpoint::arm_from_config(cfg.chaos.as_deref())?;
    match cfg.backend.as_str() {
        "native" => Ok((Backend::Native, None)),
        _ => {
            let server = DeviceServer::start(&cfg.artifact_dir)?;
            let handle = server.handle();
            Ok((Backend::Device(handle), Some(server)))
        }
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_deref() {
        Some("sweep") => cmd_sweep(args),
        Some("scope") => cmd_scope(args),
        Some("simulate") => cmd_simulate(args),
        Some("serve") => cmd_serve(args),
        Some("speedup") => cmd_speedup(args),
        Some("synth") => cmd_synth(args),
        Some("detect") => cmd_detect(args),
        Some("shapes") => cmd_shapes(),
        Some("elastic") => cmd_elastic(args),
        Some("obs") => cmd_obs(args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}' (see --help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "containerstress — autonomous cloud-node scoping for big-data ML use cases\n\
         \n\
         subcommands:\n\
           sweep     Monte Carlo compute-cost sweep over (signals × memvecs × obs)\n\
           scope     sweep + response surfaces + cloud-shape recommendation\n\
           simulate  fleet what-if scenario replay (policies × tenants × epochs)\n\
           serve     multi-tenant scoping service: HTTP JSON API + sweep cache\n\
           speedup   GPU speedup-factor surfaces (paper Figs. 6-8)\n\
           synth     synthesize TPSS telemetry to CSV\n\
           detect    MSET2 + SPRT anomaly-detection demo\n\
           shapes    print the cloud shape catalog\n\
           elastic   pre-scoped vs autoscaled cost/violation simulation\n\
           obs       offline journal summaries: obs top|slo|grep --trace-id ID\n\
                     --journal DIR  (a serve --journal-dir)\n\
         \n\
         common flags: --config FILE --backend device|native --signals a,b,c\n\
           --memvecs a,b,c --obs a,b,c --trials N --model mset2|aakr|ridge\n\
           --out DIR --metrics\n\
           --kernel-backend scalar|simd|auto   linalg kernel tier (default\n\
             scalar = bit-exact; simd = AVX2/NEON tolerance mode, errors if\n\
             unavailable; auto = simd when detected; env CONTAINERSTRESS_KERNEL)\n\
         simulate flags: --scenario FILE.json  (scenario spec; omit for the\n\
           built-in demo)  --epochs N  --tenants N  --scenario-seed N\n\
           (workload-mode scenarios run the configured sweep first to fit\n\
            the surface oracle; the serve cache-dir is reused when set)\n\
         planner flags (adaptive sweep; sweep/scope/serve):\n\
           --ci-target F     relative 95%-CI target per cell (0 = exhaustive)\n\
           --pilot-trials N  cheap pilot trials per cell (default 2)\n\
           --max-trials N    per-cell trial cap (0 = max(trials, pilot))\n\
           --interpolate B   surface-model cell pruning on|off (default on)\n\
         serve flags:  --host H --port P --queue-cap N --cache-dir DIR|none\n\
           --executor-workers N  shared trial-executor threads (0 = auto)\n\
           --fair-share B        fair job interleaving on|off (default on)\n\
           --access-log B        per-request HTTP access log (default off)\n\
         serve ops-plane flags:\n\
           --slo R:MS:LT:ET,...  latency/error objectives per route class\n\
             (route 'all', latency ms, latency target, error target;\n\
              empty string clears)  --slo-window-s S  --slo-tick-ms MS\n\
           --journal-dir DIR|none     durable telemetry journal (NDJSON)\n\
           --journal-max-file-bytes N --journal-max-total-bytes N\n\
           --journal-fsync never|rotate|always  --journal-snapshot-ms MS\n\
         serve fault-tolerance flags:\n\
           --wal-dir DIR|none    durable job WAL: submissions are journalled\n\
             (fsync always) before they run, so a crash loses no accepted job\n\
           --resume              replay unfinished WAL jobs on boot\n\
             (requires --wal-dir; share the --cache-dir for bit-identical,\n\
              nearly-free replay)\n\
           --drain-deadline-ms N graceful SIGTERM/SIGINT drain deadline\n\
             (default 5000; jobs still running stay pending for --resume)\n\
         chaos flags (sweep/scope/simulate/serve):\n\
           --chaos point:rate:kind[:seed],...  deterministic fault injection\n\
             at named failpoints (kind error|panic|delay; rate in [0,1];\n\
             env CONTAINERSTRESS_CHAOS overrides; empty string clears).\n\
             points: cellstore.spill.write cellstore.spill.read\n\
             executor.trial.run journal.append http.conn.accept\n\
             scenario.unit.run\n\
         \n\
         serve API:    POST /v1/scope  GET /v1/jobs/ID  DELETE /v1/jobs/ID\n\
                       GET /v1/jobs/ID/trace  GET /v1/scenarios/ID/trace\n\
                       GET /v1/recommendations/ID  GET /v1/shapes  GET /healthz\n\
                       GET /metrics[?format=json|text|prometheus]\n\
                       GET /v1/slo  GET /metrics/stream  GET /v1/trace/stream"
    );
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::resolve(args)?;
    let (backend, _server) = make_backend(&cfg)?;
    let result = run_sweep(&cfg.sweep, backend)?;
    if cfg.sweep.adaptive() {
        println!(
            "adaptive planner: {} measured + {} interpolated cells, {} total trials",
            result.measured_cells(),
            result.interpolated_cells(),
            result.total_trials()
        );
    }
    report::write(&cfg.output_dir, "sweep.csv", &report::sweep_csv(&result))?;
    report::write(
        &cfg.output_dir,
        "sweep_config.json",
        &cfg.to_json().to_pretty(),
    )?;
    for phase in ["train", "surveil"] {
        for &n in &cfg.sweep.signals {
            let grid = result.panel(phase, n);
            let ascii = report::emit_figure(
                &cfg.output_dir,
                &format!("{phase}_n{n}"),
                &format!("MSET2 {phase} compute cost, {n} signals"),
                &grid,
                "cost_s",
                false,
            )?;
            println!("{ascii}");
        }
        println!("{}", report::sensitivity_table(&result, phase)?);
    }
    println!("wrote results to {}", cfg.output_dir.display());
    Ok(())
}

fn cmd_scope(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::resolve(args)?;
    let (backend, _server) = make_backend(&cfg)?;
    let result = run_sweep(&cfg.sweep, backend)?;
    let workload = Workload {
        n_signals: args.get_usize("wl-signals", 20)?,
        n_memvec: args.get_usize("wl-memvecs", 64)?,
        obs_per_sec: args.get_f64("wl-rate", 1.0)?,
        train_window: args.get_usize("wl-window", 4096)?,
    };
    let sla = Sla {
        headroom: args.get_f64("sla-headroom", 2.0)?,
        max_train_s: args.get_f64("sla-train", 3600.0)?,
    };
    // Surface fit + calibration + assessment; errors cleanly on degenerate
    // sweep grids instead of panicking (empty axes, too few cells).
    let rec = recommend_from_sweep(&result, &workload, &sla)?;
    println!("{}", rec.render());
    report::write(&cfg.output_dir, "recommendation.txt", &rec.render())?;
    report::write(
        &cfg.output_dir,
        "recommendation.json",
        &rec.to_json().to_pretty(),
    )?;
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    use containerstress::coordinator::{run_sweep_cached, CellStore};
    use containerstress::scenario::{run_scenario, Backstop, SurfaceOracle};
    use containerstress::service::SweepCache;
    let cfg = Config::resolve(args)?;
    let spec = cfg.scenario.clone().unwrap_or_default();
    spec.validate()?;
    let outcome = if spec.workload.is_some() {
        // Workload mode: run the configured sweep (served from the shared
        // cell cache when warm), fit the surface oracle, then replay. The
        // sweep spec doubles as the backstop template for out-of-domain
        // cells the drifting fleet wanders into.
        let (backend, _server) = make_backend(&cfg)?;
        let cache = match &cfg.service.cache_dir {
            Some(dir) => Some(SweepCache::open(dir)?),
            None => None,
        };
        let cache_ref: Option<&dyn CellStore> = cache.as_ref().map(|c| c as &dyn CellStore);
        let result = run_sweep_cached(&cfg.sweep, backend.clone(), cache_ref)?;
        let oracle = SurfaceOracle::from_sweep(&result)?;
        let backstop = Backstop {
            spec: &cfg.sweep,
            backend: &backend,
            cache: cache_ref,
        };
        run_scenario(&spec, Some(&oracle), Some(&backstop))?
    } else {
        run_scenario(&spec, None, None)?
    };
    println!("{}", outcome.render());
    // File stem from the scenario name, sanitized: the name is the first
    // user-controlled filename component, and "../x" must not escape
    // --out.
    let stem: String = spec
        .name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '-'
            }
        })
        .collect();
    report::write(
        &cfg.output_dir,
        &format!("scenario_{stem}.json"),
        &outcome.to_json().to_pretty(),
    )?;
    report::write(
        &cfg.output_dir,
        &format!("scenario_{stem}_spec.json"),
        &spec.to_json().to_pretty(),
    )?;
    let mut csv = String::from("policy,epoch,usd,violating_tenants\n");
    for p in &outcome.policies {
        for (t, (usd, viol)) in p.usd_per_epoch.iter().zip(&p.violations_per_epoch).enumerate()
        {
            csv.push_str(&format!("{},{t},{usd},{viol}\n", p.label));
        }
    }
    report::write(&cfg.output_dir, &format!("scenario_{stem}.csv"), &csv)?;
    println!("wrote scenario results to {}", cfg.output_dir.display());
    Ok(())
}

/// Set by the SIGTERM/SIGINT handler; the serve loop polls it and turns
/// a kill into a graceful drain (finish in-flight jobs up to the
/// deadline, flush the WAL, leave the rest pending for `--resume`).
static TERM_REQUESTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
fn install_term_handler() {
    // No libc crate offline: reach signal(2) through its raw C symbol.
    // The handler only flips an atomic, which is async-signal-safe.
    extern "C" fn on_term(_sig: i32) {
        TERM_REQUESTED.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term as usize);
        signal(SIGINT, on_term as usize);
    }
}

#[cfg(not(unix))]
fn install_term_handler() {}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::resolve(args)?;
    let (backend, _device) = make_backend(&cfg)?;
    let server = service::Server::start(&cfg, backend)?;
    println!("containerstress service listening on http://{}", server.addr());
    println!("  POST   /v1/scope              submit a scoping job");
    println!("  POST   /v1/scenarios          submit a fleet what-if scenario");
    println!("  GET    /v1/jobs/ID            job status + live progress");
    println!("  GET    /v1/jobs/ID/trace      span timeline (flight recorder)");
    println!("  GET    /v1/scenarios/ID       scenario status + replay progress");
    println!("  GET    /v1/scenarios/ID/trace scenario span timeline");
    println!("  DELETE /v1/jobs/ID | /v1/scenarios/ID   cancel a job");
    println!("  GET    /v1/recommendations/ID shape recommendation");
    println!("  GET    /v1/slo                SLO burn-rate status");
    println!("  GET    /metrics/stream        live metric deltas (NDJSON/SSE)");
    println!("  GET    /v1/trace/stream       retired-span firehose (NDJSON/SSE)");
    println!("  GET    /v1/shapes | /healthz | /metrics[?format=json|text|prometheus]");
    println!(
        "scheduler: {} executor workers, fair_share={}, access_log={}",
        server.state().executor_workers(),
        server.state().fair_share(),
        cfg.service.access_log
    );
    let kd = simd::dispatch_info();
    println!(
        "kernel backend: {} ({} mode; requested '{}' via {})",
        kd.active.isa(),
        kd.active.mode(),
        kd.requested.as_str(),
        kd.source
    );
    match &cfg.service.cache_dir {
        Some(d) => println!(
            "sweep cache: {} ({} cells warm)",
            d.display(),
            server.state().cache().len()
        ),
        None => println!("sweep cache: in-memory only"),
    }
    match &cfg.service.journal_dir {
        Some(d) => println!(
            "telemetry journal: {} (fsync={}, snapshot every {}ms)",
            d.display(),
            cfg.service.journal_fsync.as_str(),
            cfg.service.journal_snapshot_ms
        ),
        None => println!("telemetry journal: disabled"),
    }
    if cfg.service.slo.enabled() {
        println!(
            "slo engine: {} objectives over {}s windows (tick {}ms)",
            cfg.service.slo.objectives.len(),
            cfg.service.slo.window_s,
            cfg.service.slo.tick_ms
        );
    }
    match &cfg.service.wal_dir {
        Some(d) => println!(
            "job WAL: {} (resume={}, drain deadline {}ms)",
            d.display(),
            cfg.service.resume,
            cfg.service.drain_deadline_ms
        ),
        None => println!("job WAL: disabled (submissions are not crash-durable)"),
    }
    install_term_handler();
    while !TERM_REQUESTED.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!(
        "shutdown signal received; draining in-flight jobs (deadline {}ms)",
        cfg.service.drain_deadline_ms
    );
    let remaining =
        server.drain(std::time::Duration::from_millis(cfg.service.drain_deadline_ms));
    if remaining > 0 {
        println!(
            "{remaining} job(s) still running at the drain deadline; \
             restart with --resume to replay them"
        );
    }
    Ok(())
}

/// `containerstress obs` — offline summaries over a `serve --journal-dir`
/// telemetry journal (no server needed; reads the NDJSON files directly,
/// tolerating a torn tail from a crashed process):
///
/// ```text
/// obs top  --journal DIR            span tallies + latest metric snapshot
/// obs slo  --journal DIR            latest journalled SLO evaluation
/// obs grep --journal DIR --trace-id ID   one trace's spans, as NDJSON
/// ```
fn cmd_obs(args: &Args) -> anyhow::Result<()> {
    use containerstress::obs::journal;
    use containerstress::util::json::Json;
    let verb = args.positional.first().map(String::as_str).unwrap_or("top");
    let dir = std::path::PathBuf::from(args.get_or("journal", "results/journal"));
    let records = journal::read_records(&dir)?;
    anyhow::ensure!(
        !records.is_empty(),
        "no journal records under {} (expected files from serve --journal-dir)",
        dir.display()
    );
    match verb {
        "top" => {
            let mut spans = 0usize;
            let mut metric_frames = 0usize;
            let mut slo_frames = 0usize;
            let mut by_kind: std::collections::BTreeMap<String, usize> = Default::default();
            let mut last_metrics = None;
            for r in &records {
                match r.get("kind").and_then(Json::as_str) {
                    Some("span") => {
                        spans += 1;
                        let key = format!(
                            "{}/{}",
                            r.get("name").and_then(Json::as_str).unwrap_or("?"),
                            r.get("phase").and_then(Json::as_str).unwrap_or("?")
                        );
                        *by_kind.entry(key).or_insert(0) += 1;
                    }
                    Some("metrics") => {
                        metric_frames += 1;
                        last_metrics = Some(r);
                    }
                    Some("slo") => slo_frames += 1,
                    _ => {}
                }
            }
            println!(
                "journal {}: {} records ({spans} spans, {metric_frames} metric frames, \
                 {slo_frames} slo frames)",
                dir.display(),
                records.len()
            );
            let mut kinds: Vec<(String, usize)> = by_kind.into_iter().collect();
            kinds.sort_by(|a, b| b.1.cmp(&a.1));
            println!("top span kinds:");
            for (name, n) in kinds.iter().take(10) {
                println!("  {n:>8}  {name}");
            }
            if let Some(counters) = last_metrics
                .and_then(|r| r.get("metrics"))
                .and_then(|m| m.get("counters"))
                .and_then(Json::as_obj)
            {
                let mut top: Vec<(&String, f64)> = counters
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k, x)))
                    .collect();
                top.sort_by(|a, b| b.1.total_cmp(&a.1));
                println!("top counters (latest snapshot):");
                for (k, v) in top.iter().take(15) {
                    println!("  {v:>12.0}  {k}");
                }
            }
        }
        "slo" => {
            let last = records
                .iter()
                .rev()
                .find(|r| r.get("kind").and_then(Json::as_str) == Some("slo"))
                .ok_or_else(|| {
                    anyhow::anyhow!("journal has no slo frames (serve ran without --slo?)")
                })?;
            println!("{}", last.get("slo").unwrap_or(last).to_pretty());
        }
        "grep" => {
            let id = args
                .get("trace-id")
                .ok_or_else(|| anyhow::anyhow!("obs grep requires --trace-id ID"))?;
            let mut n = 0usize;
            for r in &records {
                if r.get("kind").and_then(Json::as_str) == Some("span")
                    && r.get("trace_id").and_then(Json::as_str) == Some(id)
                {
                    println!("{r}");
                    n += 1;
                }
            }
            anyhow::ensure!(n > 0, "no spans for trace '{id}' in {}", dir.display());
            eprintln!("{n} spans for trace {id}");
        }
        other => anyhow::bail!("unknown obs verb '{other}' (expected top|slo|grep)"),
    }
    Ok(())
}

fn cmd_speedup(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::resolve(args)?;
    let gpu = GpuSpec::v100();
    let cpu = CpuRef::xeon_platinum();
    // Fig. 6: training speedup over (signals × memvecs), log–log, m ≥ 2n.
    let signals: Vec<usize> = args.get_usize_list("signals", &[32, 64, 128, 256, 512, 1024])?;
    let memvecs: Vec<usize> =
        args.get_usize_list("memvecs", &[128, 256, 512, 1024, 2048, 4096, 8192])?;
    let mut grid = SurfaceGrid::new(
        "n_memvec",
        "n_signals",
        memvecs.iter().map(|&v| v as f64).collect(),
        signals.iter().map(|&v| v as f64).collect(),
    );
    for (r, &m) in memvecs.iter().enumerate() {
        for (c, &n) in signals.iter().enumerate() {
            if m >= 2 * n {
                grid.set(r, c, accel::speedup_train(n, m, &gpu, &cpu));
            }
        }
    }
    let ascii = report::emit_figure(
        &cfg.output_dir,
        "fig6_training_speedup",
        "GPU training speedup factor (Fig. 6)",
        &grid,
        "speedup",
        true,
    )?;
    println!("{ascii}");
    Ok(())
}

fn cmd_synth(args: &Args) -> anyhow::Result<()> {
    let cfg = TpssConfig {
        n_signals: args.get_usize("signals", 8)?,
        n_obs: args.get_usize("obs", 1024)?,
        cross_corr: args.get_f64("rho", 0.4)?,
        ar_coeff: args.get_f64("ar", 0.7)?,
        skewness: args.get_f64("skew", 0.0)?,
        kurtosis: args.get_f64("kurt", 3.0)?,
        ..TpssConfig::default()
    };
    let ds = synthesize(&cfg, args.get_u64("seed", 1)?);
    let mut out = String::new();
    for r in 0..ds.data.rows {
        let row: Vec<String> = ds.data.row(r).iter().map(|v| format!("{v:.6}")).collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    let path = args.get_or("out", "results/telemetry.csv");
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, out)?;
    println!(
        "wrote {} × {} telemetry to {path}",
        ds.data.rows, ds.data.cols
    );
    Ok(())
}

fn cmd_detect(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("signals", 8)?;
    let cfg = TpssConfig::sized(n, 4096);
    let train = synthesize(&cfg, 11);
    let model = containerstress::mset::train(&train.data, args.get_usize("memvecs", 64)?)?;
    // healthy window calibrates the detector
    let healthy = synthesize(&cfg, 12);
    let est_h = model.surveil(&healthy.data);
    let mut det = Sprt::from_healthy(&est_h.resid, SprtConfig::default());
    // faulted stream
    let mut probe = synthesize(&cfg, 13);
    let onset = containerstress::tpss::inject(
        &mut probe,
        2,
        Fault::Drift { magnitude: 6.0 },
        0.5,
        14,
    );
    let est = model.surveil(&probe.data);
    let alarms = det.run(&est.resid);
    let first = alarms.iter().find(|a| a.signal == 2 && a.at >= onset);
    println!(
        "injected 6σ drift on signal 2 at t={onset}; {} alarms; first on-target at {:?}",
        alarms.len(),
        first.map(|a| a.at)
    );
    anyhow::ensure!(first.is_some(), "drift not detected");
    println!(
        "detection latency: {} observations",
        first.unwrap().at - onset
    );
    Ok(())
}

fn cmd_elastic(args: &Args) -> anyhow::Result<()> {
    use containerstress::shapes::elastic::{compare, ElasticPolicy, GrowthTrace};
    let epochs = args.get_usize("epochs", 120)?;
    let d0 = args.get_f64("demand0", 0.5)?;
    let growth = args.get_f64("growth", 1.03)?;
    let trace = GrowthTrace::exponential(d0, growth, epochs, 24.0)?;
    let policy = ElasticPolicy {
        scale_lag_epochs: args.get_usize("lag", 2)?,
        migration_usd: args.get_f64("migration-usd", 5.0)?,
        ..Default::default()
    };
    let (fixed, elastic) = compare(&trace, &policy);
    println!(
        "growth trace: {epochs} epochs × 24h, demand {d0:.2} → {:.2} core-eq ({growth}×/epoch)",
        trace.demand().last().unwrap()
    );
    println!(
        "pre-scoped ({}):   ${:>9.2}  violations {:>3}  migrations {}",
        fixed.shape_trace[0], fixed.total_usd, fixed.violation_epochs, fixed.migrations
    );
    println!(
        "elastic autoscale: ${:>9.2}  violations {:>3}  migrations {} (final shape {})",
        elastic.total_usd,
        elastic.violation_epochs,
        elastic.migrations,
        elastic.shape_trace.last().unwrap()
    );
    println!(
        "→ {}",
        if elastic.violation_epochs > 0 {
            "elasticity is cheaper but 'not as smooth as cloud marketing teams might wish' (paper §I): SLA violations during scale-up lag"
        } else {
            "both strategies meet SLA; elastic is cheaper for slow growth"
        }
    );
    Ok(())
}

fn cmd_shapes() -> anyhow::Result<()> {
    println!(
        "{:<18} {:>6} {:>8} {:>6} {:>10} {:>14}",
        "shape", "cores", "mem_gb", "gpus", "$/hr", "eff GFLOP/s"
    );
    for s in shapes::catalog() {
        println!(
            "{:<18} {:>6} {:>8.0} {:>6} {:>10.4} {:>14.1}",
            s.name,
            s.cpu.cores,
            s.mem_gb,
            s.gpus,
            s.usd_per_hour,
            s.cpu_eff_flops() / 1e9
        );
    }
    Ok(())
}
