//! **End-to-end driver** (DESIGN.md §6, experiment E2E): exercises every
//! layer of the system on a real small workload and reports the paper's
//! headline artefacts. This is the run recorded in EXPERIMENTS.md.
//!
//! Pipeline:
//!   TPSS synthesis → scoping-job queue → Monte Carlo device sweep over
//!   (signals × memvecs × obs) → compute-cost response surfaces (paper
//!   Figs. 4/5 panels, ASCII + CSV under results/e2e/) → sensitivity
//!   conclusions (§III.A) → GPU speedup surfaces (Figs. 6–8 shape) →
//!   cloud-shape recommendations for both customer extremes → SPRT
//!   detection sanity on the device path.
//!
//! Run: `make artifacts && cargo run --release --example e2e_scoping`

use containerstress::accel::{self, CpuRef, GpuSpec};
use containerstress::coordinator::jobs::ScopingService;
use containerstress::coordinator::{Backend, SweepSpec};
use containerstress::detect::{measure, Sprt, SprtConfig};
use containerstress::metrics::Registry;
use containerstress::recommend::{recommend, LocalCalibration, Sla};
use containerstress::report;
use containerstress::runtime::DeviceServer;
use containerstress::shapes::Workload;
use containerstress::surface::ResponseSurface;
use containerstress::tpss::{inject, synthesize, Fault, TpssConfig};
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    containerstress::util::logger::init();
    let t0 = Instant::now();
    let out = Path::new("results/e2e");
    let server = DeviceServer::start(containerstress::runtime::default_artifact_dir())?;

    // ---- 1. scoping job through the service front -------------------------
    let spec = SweepSpec {
        signals: vec![4, 8, 12, 16],
        memvecs: vec![32, 48, 64],
        obs: vec![64, 128, 256, 512],
        trials: 3,
        seed: 7,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    };
    let n_cells = spec.signals.len() * spec.memvecs.len() * spec.obs.len();
    println!("[1/5] scoping sweep: {n_cells} cells × {} trials (device)", spec.trials);
    let svc = ScopingService::start(Backend::Device(server.handle()), 8);
    let job = svc.submit(spec.clone())?;
    let result = svc.wait(job)?;
    report::write(out, "sweep.csv", &report::sweep_csv(&result))?;

    // ---- 2. response surfaces + paper-panel figures ------------------------
    println!("[2/5] fitting response surfaces, emitting Fig. 4/5-style panels");
    let train_surf = ResponseSurface::fit(&result.samples("train"))?;
    let surveil_surf = ResponseSurface::fit(&result.samples("surveil"))?;
    for (phase, surf) in [("train", &train_surf), ("surveil", &surveil_surf)] {
        for &n in &spec.signals {
            let grid = result.panel(phase, n);
            report::emit_figure(
                out,
                &format!("{phase}_n{n}"),
                &format!("{phase} cost, {n} signals"),
                &grid,
                "cost_s",
                false,
            )?;
        }
        println!(
            "  {phase}: r²={:.3}, exponents(n,m,obs) = {:?}",
            surf.r2,
            surf.exponents().map(|e| (e * 100.0).round() / 100.0)
        );
        let table = report::sensitivity_table(&result, phase)?;
        report::write(out, &format!("sensitivity_{phase}.txt"), &table)?;
    }
    // Paper §III.A conclusions, asserted:
    let et = train_surf.exponents();
    let es = surveil_surf.exponents();
    anyhow::ensure!(
        es[2] > et[2],
        "surveillance must be more obs-sensitive than training"
    );
    println!(
        "  conclusion check: training driven by (m, n) [m-exp {:.2}], surveillance by (obs, n) [obs-exp {:.2}] ✓",
        et[1], es[2]
    );

    // ---- 3. GPU speedup surfaces (Figs. 6–8) -------------------------------
    println!("[3/5] GPU speedup surfaces (analytic V100 model)");
    let gpu = GpuSpec::v100();
    let cpu = CpuRef::xeon_platinum();
    let su_small = accel::speedup_train(32, 128, &gpu, &cpu);
    let su_big = accel::speedup_train(1024, 8192, &gpu, &cpu);
    let su_s64 = accel::speedup_surveil(64, 8192, 1 << 20, &gpu, &cpu);
    let su_s1024 = accel::speedup_surveil(1024, 8192, 1 << 20, &gpu, &cpu);
    println!(
        "  training {su_small:.0}×→{su_big:.0}× (paper: 200×→1500×); surveillance 64-sig {su_s64:.0}× (paper >5000×), 1024-sig {su_s1024:.0}× (paper >9000×)"
    );

    // ---- 4. recommendations for the paper's two customer extremes ----------
    println!("[4/5] shape recommendations");
    // Power-law fits for recommendation: customer B extrapolates far
    // outside the sweep grid.
    let train_pl = ResponseSurface::fit_power_law(&result.samples("train"))?;
    let surveil_pl = ResponseSurface::fit_power_law(&result.samples("surveil"))?;
    let cal = LocalCalibration::from_surface(&surveil_pl, 16, 64, 512);
    for (name, wl) in [
        ("customer A (datacenter)", Workload::customer_a()),
        ("customer B (A320 partition)", Workload::customer_b_partition()),
    ] {
        let rec = recommend(&wl, &train_pl, &surveil_pl, cal, &Sla::default());
        report::write(
            out,
            &format!("recommendation_{}.txt", name.chars().next().map(|c| if c=='c' {"a"} else {"b"}).unwrap_or("x")),
            &rec.render(),
        )?;
        match rec.chosen_shape() {
            Some(c) => println!("  {name}: {} (${:.4}/hr)", c.shape.name, c.usd_per_hour),
            None => println!("  {name}: no feasible single shape (shard further)"),
        }
    }

    // ---- 5. detection sanity on the device path ----------------------------
    println!("[5/5] SPRT detection through the device path");
    let cfg = TpssConfig::sized(8, 2048);
    let model = containerstress::mset::train(&synthesize(&cfg, 100).data, 64)?;
    let mut sess =
        containerstress::runtime::mset::DeviceMset::new(server.handle(), &model.d)?;
    sess.train()?;
    let healthy = synthesize(&cfg, 101);
    let (_, resid_h, _) = sess.surveil(&model.scaler.transform(&healthy.data))?;
    let mut det = Sprt::from_healthy(
        &resid_h,
        SprtConfig {
            alpha: 1e-6,
            beta: 1e-4,
            shift: 4.5,
            var_ratio: 6.0,
        },
    );
    let mut faulted = synthesize(&cfg, 102);
    let onset = inject(&mut faulted, 3, Fault::Step { magnitude: 5.0 }, 0.5, 103);
    let (_, resid_f, _) = sess.surveil(&model.scaler.transform(&faulted.data))?;
    let (far, missed, latency) = measure(&mut det, &resid_f, Some(3), onset);
    println!(
        "  FAR={far:.2e}, missed={:?}, latency={:?} obs",
        missed, latency
    );
    anyhow::ensure!(missed == Some(0.0), "fault missed");

    println!(
        "\nE2E complete in {:.1}s — results under {}\n",
        t0.elapsed().as_secs_f64(),
        out.display()
    );
    print!("{}", Registry::global().render());
    Ok(())
}
