//! Deterministic fault injection: a registry of named **failpoints**.
//!
//! A failpoint is a named hook compiled into a failure-prone code path
//! (spill I/O, trial execution, journal appends, socket accepts). In
//! normal operation every hook is disarmed and costs a single relaxed
//! atomic load. Chaos runs arm one or more points with a
//! `point:rate:kind[:seed]` spec — via `--chaos`, the `chaos` config
//! key, or the `CONTAINERSTRESS_CHAOS` environment variable — and the
//! armed hooks then inject errors, panics, or delays.
//!
//! # Determinism
//!
//! Whether a given hit injects is **not** drawn from a shared RNG
//! stream: under a threaded executor the interleaving of trials would
//! decide which trial consumes which random draw, and chaos runs would
//! stop being reproducible. Instead every call site passes a `tag`
//! that identifies the unit of work (the trial seed and attempt
//! number, a spill file-stem hash, a journal sequence number), and the
//! decision is a pure function of `(spec seed, point name, tag)`. Two
//! runs with the same spec and the same workload therefore inject
//! faults into exactly the same units of work regardless of thread
//! scheduling — the foundation of the `chaos_props` bit-identity
//! suite.
//!
//! # Panic safety
//!
//! [`hit`] may panic when the armed kind is `panic`; it is only placed
//! inside `catch_unwind` scopes (trial tasks, scenario units).
//! [`hit_no_panic`] converts an armed panic into an injected error and
//! is used at sites where unwinding would poison a lock or strand a
//! waiter (journal appends, cache spills, the accept loop).

use crate::metrics::Registry;
use crate::util::fnv1a;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Every failpoint compiled into the binary. Arming a name outside
/// this list is a configuration error (it would silently never fire).
pub const POINTS: &[&str] = &[
    "cellstore.spill.write",
    "cellstore.spill.read",
    "executor.trial.run",
    "journal.append",
    "http.conn.accept",
    "scenario.unit.run",
];

/// What an armed failpoint injects when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Return an `anyhow` error from the hook.
    Error,
    /// Panic (only honoured by [`hit`]; [`hit_no_panic`] downgrades
    /// this to an injected error).
    Panic,
    /// Sleep for a fixed 25 ms, then succeed — exercises timeout and
    /// backpressure paths without changing results.
    Delay,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "error" => Some(FaultKind::Error),
            "panic" => Some(FaultKind::Panic),
            "delay" => Some(FaultKind::Delay),
            _ => None,
        }
    }

    /// Canonical spelling, as accepted by [`FaultSpec::parse`].
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::Delay => "delay",
        }
    }
}

/// One armed failpoint: which point, how often, what to inject, and
/// the seed the per-hit decision derives from.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Registered point name (one of [`POINTS`]).
    pub point: &'static str,
    /// Injection probability per hit, in `[0, 1]`.
    pub rate: f64,
    /// What to inject.
    pub kind: FaultKind,
    /// Decision seed (defaults to 1 when the spec omits it).
    pub seed: u64,
}

impl FaultSpec {
    /// Parse a single `point:rate:kind[:seed]` spec.
    pub fn parse(s: &str) -> anyhow::Result<FaultSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 3 || parts.len() == 4,
            "chaos spec '{s}' must be point:rate:kind[:seed]"
        );
        let point = POINTS
            .iter()
            .copied()
            .find(|p| *p == parts[0])
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown failpoint '{}' (registered: {})",
                    parts[0],
                    POINTS.join(", ")
                )
            })?;
        let rate: f64 = parts[1]
            .parse()
            .map_err(|_| anyhow::anyhow!("chaos spec '{s}': rate '{}' is not a number", parts[1]))?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&rate),
            "chaos spec '{s}': rate must be in [0, 1]"
        );
        let kind = FaultKind::parse(parts[2]).ok_or_else(|| {
            anyhow::anyhow!("chaos spec '{s}': kind '{}' is not error|panic|delay", parts[2])
        })?;
        let seed = match parts.get(3) {
            Some(raw) => raw
                .parse()
                .map_err(|_| anyhow::anyhow!("chaos spec '{s}': seed '{raw}' is not a u64"))?,
            None => 1,
        };
        Ok(FaultSpec { point, rate, kind, seed })
    }

    /// Render back to the `point:rate:kind:seed` wire form.
    pub fn render(&self) -> String {
        format!("{}:{}:{}:{}", self.point, self.rate, self.kind.as_str(), self.seed)
    }
}

/// Number of armed points — the disarmed fast path is this single
/// relaxed load.
static ARMED_COUNT: AtomicUsize = AtomicUsize::new(0);
static ARMED: Mutex<Vec<FaultSpec>> = Mutex::new(Vec::new());

fn armed_lock() -> std::sync::MutexGuard<'static, Vec<FaultSpec>> {
    // A panicking injection can never happen while this lock is held
    // (decisions are computed after release), but be robust anyway.
    ARMED.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm one failpoint. Re-arming a point replaces its previous spec.
pub fn arm(spec: FaultSpec) {
    let mut armed = armed_lock();
    armed.retain(|s| s.point != spec.point);
    armed.push(spec);
    ARMED_COUNT.store(armed.len(), Ordering::SeqCst);
}

/// Parse and arm a comma-separated list of `point:rate:kind[:seed]`
/// specs. An empty string arms nothing.
pub fn arm_from_str(specs: &str) -> anyhow::Result<()> {
    for part in specs.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        arm(FaultSpec::parse(part)?);
    }
    Ok(())
}

/// Arm failpoints for a process: the `CONTAINERSTRESS_CHAOS`
/// environment variable first (highest precedence), then the resolved
/// config/CLI spec. Logs every armed point so chaos runs are
/// self-describing.
pub fn arm_from_config(chaos: Option<&str>) -> anyhow::Result<()> {
    if let Ok(env) = std::env::var("CONTAINERSTRESS_CHAOS") {
        arm_from_str(&env)?;
    } else if let Some(spec) = chaos {
        arm_from_str(spec)?;
    }
    for spec in armed() {
        log::warn!("chaos: failpoint armed: {}", spec.render());
    }
    Ok(())
}

/// Disarm every failpoint (used by tests and between chaos scenarios).
pub fn disarm_all() {
    let mut armed = armed_lock();
    armed.clear();
    ARMED_COUNT.store(0, Ordering::SeqCst);
}

/// Snapshot of the currently armed specs.
pub fn armed() -> Vec<FaultSpec> {
    armed_lock().clone()
}

/// True when at least one failpoint is armed.
#[inline]
pub fn any_armed() -> bool {
    ARMED_COUNT.load(Ordering::Relaxed) != 0
}

/// Serialises tests that arm the (global) registry. Lib unit tests in
/// different modules share one process; each takes this guard before
/// arming so a parallel test never observes a foreign spec.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// The pure injection decision: fires iff the armed rate exceeds a
/// uniform draw derived only from `(seed, point, tag)`.
fn decide(spec: &FaultSpec, point: &str, tag: u64) -> bool {
    if spec.rate >= 1.0 {
        return true;
    }
    if spec.rate <= 0.0 {
        return false;
    }
    let mix = spec
        .seed
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ fnv1a(point.as_bytes())
        ^ tag.rotate_left(29);
    Rng::new(mix).f64() < spec.rate
}

fn fire(spec: FaultSpec, point: &'static str, tag: u64, allow_panic: bool) -> anyhow::Result<()> {
    Registry::global().inc(&format!("chaos.injected.{point}"));
    match spec.kind {
        FaultKind::Delay => {
            std::thread::sleep(std::time::Duration::from_millis(25));
            Ok(())
        }
        FaultKind::Panic if allow_panic => {
            panic!("failpoint '{point}' injected panic (tag {tag:#x})")
        }
        // `hit_no_panic` call sites cannot unwind safely; an armed
        // panic degrades to an injected error there.
        FaultKind::Panic | FaultKind::Error => Err(anyhow::anyhow!(
            "failpoint '{point}' injected error (tag {tag:#x})"
        )),
    }
}

fn hit_slow(point: &'static str, tag: u64, allow_panic: bool) -> anyhow::Result<()> {
    let spec = match armed_lock().iter().find(|s| s.point == point) {
        Some(s) => s.clone(),
        None => return Ok(()),
    };
    if !decide(&spec, point, tag) {
        return Ok(());
    }
    fire(spec, point, tag, allow_panic)
}

/// Evaluate a failpoint. Disarmed: one relaxed atomic load. Armed
/// with kind `panic`, this call panics — only use inside
/// `catch_unwind` scopes; elsewhere use [`hit_no_panic`].
#[inline]
pub fn hit(point: &'static str, tag: u64) -> anyhow::Result<()> {
    if ARMED_COUNT.load(Ordering::Relaxed) == 0 {
        return Ok(());
    }
    hit_slow(point, tag, true)
}

/// Like [`hit`] but never panics: an armed `panic` kind injects an
/// error instead. For call sites where unwinding would poison a lock
/// or strand a blocked waiter.
#[inline]
pub fn hit_no_panic(point: &'static str, tag: u64) -> anyhow::Result<()> {
    if ARMED_COUNT.load(Ordering::Relaxed) == 0 {
        return Ok(());
    }
    hit_slow(point, tag, false)
}

/// True when an error chain contains an injected-fault message —
/// chaos tests use this to classify failures as injected vs organic.
pub fn is_injected(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.to_string().contains("failpoint '"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrip_and_errors() {
        let _g = test_guard();
        let s = FaultSpec::parse("executor.trial.run:0.25:panic:42").unwrap();
        assert_eq!(s.point, "executor.trial.run");
        assert_eq!(s.rate, 0.25);
        assert_eq!(s.kind, FaultKind::Panic);
        assert_eq!(s.seed, 42);
        assert_eq!(s.render(), "executor.trial.run:0.25:panic:42");
        // defaulted seed
        assert_eq!(FaultSpec::parse("journal.append:1:error").unwrap().seed, 1);
        assert!(FaultSpec::parse("nope.point:0.5:error").is_err());
        assert!(FaultSpec::parse("journal.append:1.5:error").is_err());
        assert!(FaultSpec::parse("journal.append:0.5:explode").is_err());
        assert!(FaultSpec::parse("journal.append").is_err());
    }

    #[test]
    fn disarmed_hits_are_free_and_ok() {
        let _g = test_guard();
        disarm_all();
        assert!(!any_armed());
        for t in 0..100 {
            assert!(hit("executor.trial.run", t).is_ok());
            assert!(hit_no_panic("journal.append", t).is_ok());
        }
    }

    #[test]
    fn decisions_are_pure_in_the_tag() {
        let _g = test_guard();
        disarm_all();
        arm(FaultSpec::parse("journal.append:0.3:error:7").unwrap());
        let first: Vec<bool> = (0..200)
            .map(|t| hit_no_panic("journal.append", t).is_err())
            .collect();
        let second: Vec<bool> = (0..200)
            .map(|t| hit_no_panic("journal.append", t).is_err())
            .collect();
        assert_eq!(first, second, "same (seed, point, tag) must decide identically");
        let fired = first.iter().filter(|&&b| b).count();
        // 0.3 ± a generous tolerance over 200 tags.
        assert!((30..=90).contains(&fired), "fired {fired}/200 at rate 0.3");
        // A different point with the same tags decides independently.
        arm(FaultSpec::parse("cellstore.spill.write:0.3:error:7").unwrap());
        let other: Vec<bool> = (0..200)
            .map(|t| hit_no_panic("cellstore.spill.write", t).is_err())
            .collect();
        assert_ne!(first, other);
        disarm_all();
    }

    #[test]
    fn no_panic_variant_downgrades_panics() {
        let _g = test_guard();
        disarm_all();
        arm(FaultSpec::parse("journal.append:1:panic:3").unwrap());
        let err = hit_no_panic("journal.append", 9).unwrap_err();
        assert!(is_injected(&err), "downgraded panic classifies as injected: {err:#}");
        disarm_all();
    }

    #[test]
    fn rearm_replaces_and_env_precedence_parses() {
        let _g = test_guard();
        disarm_all();
        arm_from_str("journal.append:0.1:error:1, http.conn.accept:0.2:delay").unwrap();
        assert_eq!(armed().len(), 2);
        arm_from_str("journal.append:0.9:error:2").unwrap();
        let specs = armed();
        assert_eq!(specs.len(), 2);
        let j = specs.iter().find(|s| s.point == "journal.append").unwrap();
        assert_eq!(j.rate, 0.9);
        assert!(arm_from_str("bogus").is_err());
        disarm_all();
    }
}
