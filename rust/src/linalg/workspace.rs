//! Reusable scratch-buffer arena for the blocked kernel core.
//!
//! Every step of the native MSET trial hot path — similarity products,
//! packed GEMM panels, eigendecomposition scratch, scaled probe windows —
//! needs short-lived working memory of trial-dependent size. Allocating it
//! fresh on every call (what the naive `linalg::Mat` pipeline did) puts
//! `malloc`/`free` on the §II.D hot spot and defeats cache reuse across
//! the thousands of trials a sweep schedules.
//!
//! A [`Workspace`] is a small pool of previously used buffers: kernels
//! check buffers out with [`Workspace::take_f64`], use them, and return
//! them with [`Workspace::give_f64`]. Once the pool is warm, a
//! steady-state trial performs **zero heap allocations** inside the
//! kernel core — buffers keep their capacity across checkouts (`Vec`
//! never shrinks on `resize`), so a worker that measures the same cell
//! shape repeatedly touches the allocator exactly once.
//!
//! ## Ownership model
//!
//! One arena per worker thread, checked out through the thread-local
//! [`Workspace::with`]. The shared `TrialExecutor` runs each `(cell,
//! trial)` task on a long-lived worker thread, so the thread-local arena
//! *is* the per-worker arena — no plumbing through the executor API is
//! needed, and two workers never contend on a buffer. The sweep engine
//! bounds per-worker retention between trials via [`trim_thread`].
//!
//! `with` is re-entrancy safe: if a caller inside a checkout calls `with`
//! again (which the kernel entry points are structured to avoid — they
//! thread `&mut Workspace` down instead), the nested scope receives a
//! fresh temporary arena rather than panicking on the `RefCell`.
//!
//! The arena is kernel-backend-agnostic: the explicit-SIMD tier
//! (`linalg::simd`) uses unaligned vector loads/stores (`loadu` /
//! `vld1q`), so checked-out buffers need no special alignment and the
//! same pool serves the scalar and vector tiers interchangeably.

use std::cell::RefCell;

/// Default per-thread retention cap passed to [`trim_thread`] by the
/// sweep engine between trials: 2²⁰ `f64` elements (8 MiB) per worker.
pub const DEFAULT_RETAIN_ELEMS: usize = 1 << 20;

/// A pool of reusable scratch buffers (see the module docs).
#[derive(Debug, Default)]
pub struct Workspace {
    f64_pool: Vec<Vec<f64>>,
    idx_pool: Vec<Vec<usize>>,
}

thread_local! {
    static THREAD_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

impl Workspace {
    /// Empty arena (no buffers retained yet).
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Check out an `f64` buffer of exactly `len` elements. **Contents
    /// are unspecified** — callers must overwrite every element they
    /// read (use [`Workspace::take_f64_zeroed`] otherwise). Return the
    /// buffer with [`Workspace::give_f64`] when done so the next
    /// checkout reuses its capacity.
    pub fn take_f64(&mut self, len: usize) -> Vec<f64> {
        let mut v = self.f64_pool.pop().unwrap_or_default();
        v.resize(len, 0.0);
        v
    }

    /// Like [`Workspace::take_f64`] but every element is `0.0`.
    pub fn take_f64_zeroed(&mut self, len: usize) -> Vec<f64> {
        let mut v = self.take_f64(len);
        v.fill(0.0);
        v
    }

    /// Return an `f64` buffer to the pool.
    pub fn give_f64(&mut self, v: Vec<f64>) {
        if v.capacity() > 0 {
            self.f64_pool.push(v);
        }
    }

    /// Check out an index buffer of exactly `len` elements (contents
    /// unspecified, like [`Workspace::take_f64`]).
    pub fn take_idx(&mut self, len: usize) -> Vec<usize> {
        let mut v = self.idx_pool.pop().unwrap_or_default();
        v.resize(len, 0);
        v
    }

    /// Return an index buffer to the pool.
    pub fn give_idx(&mut self, v: Vec<usize>) {
        if v.capacity() > 0 {
            self.idx_pool.push(v);
        }
    }

    /// Total `f64`-equivalent elements currently retained by the pool
    /// (index buffers counted at one element each).
    pub fn retained_elems(&self) -> usize {
        self.f64_pool.iter().map(|v| v.capacity()).sum::<usize>()
            + self.idx_pool.iter().map(|v| v.capacity()).sum::<usize>()
    }

    /// Drop pooled buffers (largest first) until at most `max_elems`
    /// elements stay retained. Bounds a long-lived worker's footprint
    /// after it has measured an unusually large cell.
    pub fn trim(&mut self, max_elems: usize) {
        self.f64_pool.sort_by_key(|v| v.capacity());
        self.idx_pool.sort_by_key(|v| v.capacity());
        while self.retained_elems() > max_elems {
            // Pop the largest of either pool; both are sorted ascending.
            let f = self.f64_pool.last().map_or(0, |v| v.capacity());
            let i = self.idx_pool.last().map_or(0, |v| v.capacity());
            if f == 0 && i == 0 {
                break;
            }
            if f >= i {
                self.f64_pool.pop();
            } else {
                self.idx_pool.pop();
            }
        }
    }

    /// Run `f` with this thread's arena. Nested calls (discouraged —
    /// kernel internals thread `&mut Workspace` instead) fall back to a
    /// fresh temporary arena rather than panicking.
    pub fn with<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
        THREAD_WS.with(|cell| match cell.try_borrow_mut() {
            Ok(mut ws) => f(&mut ws),
            Err(_) => f(&mut Workspace::new()),
        })
    }
}

/// Trim the *current thread's* arena to `max_elems` retained elements —
/// called by the sweep engine after each trial so executor workers keep a
/// warm (but bounded) pool between trials.
pub fn trim_thread(max_elems: usize) {
    THREAD_WS.with(|cell| {
        if let Ok(mut ws) = cell.try_borrow_mut() {
            ws.trim(max_elems);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_reuses_capacity() {
        let mut ws = Workspace::new();
        let mut v = ws.take_f64(100);
        v[0] = 3.0;
        let cap = v.capacity();
        let ptr = v.as_ptr();
        ws.give_f64(v);
        let v2 = ws.take_f64(50);
        assert_eq!(v2.len(), 50);
        assert_eq!(v2.capacity(), cap, "capacity must be retained");
        assert_eq!(v2.as_ptr(), ptr, "same buffer must be reused");
    }

    #[test]
    fn take_zeroed_is_zero() {
        let mut ws = Workspace::new();
        let mut v = ws.take_f64(8);
        v.fill(7.0);
        ws.give_f64(v);
        let v = ws.take_f64_zeroed(8);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn trim_bounds_retention() {
        let mut ws = Workspace::new();
        let a = ws.take_f64(1000);
        let b = ws.take_f64(10);
        ws.give_f64(a);
        ws.give_f64(b);
        assert!(ws.retained_elems() >= 1010);
        ws.trim(100);
        assert!(ws.retained_elems() <= 100);
        // trimming to zero empties the pool entirely
        ws.trim(0);
        assert_eq!(ws.retained_elems(), 0);
    }

    #[test]
    fn with_is_reentrant() {
        let out = Workspace::with(|ws| {
            let v = ws.take_f64(4);
            // nested checkout must not panic
            let inner = Workspace::with(|ws2| ws2.take_f64(2).len());
            ws.give_f64(v);
            inner
        });
        assert_eq!(out, 2);
    }

    #[test]
    fn idx_pool_roundtrip() {
        let mut ws = Workspace::new();
        let mut v = ws.take_idx(5);
        v[4] = 9;
        ws.give_idx(v);
        let v = ws.take_idx(3);
        assert_eq!(v.len(), 3);
    }
}
