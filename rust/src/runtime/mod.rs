//! Runtime: loads the AOT artifacts and executes them via PJRT.
//!
//! Layering (DESIGN.md §2.4):
//!
//! - [`manifest`] — parse `artifacts/manifest.json`;
//! - [`router`]  — bucket selection + zero-padding;
//! - [`engine`]  — PJRT client, lazy compile cache, timed execution
//!   (thread-confined: `PjRtClient` is `Rc`-based);
//! - [`DeviceServer`]/[`DeviceHandle`] — the thread-safe front door: a
//!   dedicated device thread owns the [`engine::Engine`]; any number of
//!   coordinator workers hold cloneable handles and submit requests over a
//!   channel. Serialising executions also keeps the Monte Carlo *compute
//!   cost* measurements free of cross-trial contention — matching the
//!   paper's setting of benchmarking one container at a time.
//! - [`mset`]    — high-level `DeviceMset`/`DeviceAakr` sessions that pad,
//!   execute and unpad whole workloads.

pub mod engine;
pub mod manifest;
pub mod mset;
pub mod router;

pub use engine::{ExecResult, Tensor};
pub use manifest::Manifest;
pub use router::Bucket;

use std::sync::mpsc;

enum Request {
    Exec {
        id: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<ExecResult>>,
    },
    Bind {
        session: u64,
        id: String,
        prefix: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<()>>,
    },
    ExecBound {
        session: u64,
        tail: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<ExecResult>>,
    },
    Unbind {
        session: u64,
    },
    Manifest {
        reply: mpsc::Sender<Manifest>,
    },
    CompiledCount {
        reply: mpsc::Sender<usize>,
    },
}

/// Session-id allocator (process-wide; ids never reused).
static NEXT_SESSION: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Cloneable, `Send` handle to the device thread.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: mpsc::Sender<Request>,
}

/// Owns the device thread; the thread exits when every [`DeviceHandle`]
/// (including the server's own) has been dropped.
pub struct DeviceServer {
    handle: DeviceHandle,
    #[allow(dead_code)]
    join: Option<std::thread::JoinHandle<()>>,
}

impl DeviceServer {
    /// Spawn the device thread over an artifact directory.
    pub fn start(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<DeviceServer> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || {
                let mut engine = match engine::Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Exec { id, inputs, reply } => {
                            let res = engine.exec(&id, &inputs);
                            // Release input buffers before replying so a
                            // caller holding an Arc clone can reclaim them
                            // the moment the reply arrives.
                            drop(inputs);
                            let _ = reply.send(res);
                        }
                        Request::Bind {
                            session,
                            id,
                            prefix,
                            reply,
                        } => {
                            let _ = reply.send(engine.bind(session, &id, &prefix));
                        }
                        Request::ExecBound {
                            session,
                            tail,
                            reply,
                        } => {
                            let res = engine.exec_bound(session, &tail);
                            // Drop the tail tensors before the reply: the
                            // streaming chunk loop recovers its staging
                            // buffer via `Arc::try_unwrap` as soon as this
                            // send unblocks it.
                            drop(tail);
                            let _ = reply.send(res);
                        }
                        Request::Unbind { session } => {
                            engine.unbind(session);
                        }
                        Request::Manifest { reply } => {
                            let _ = reply.send(engine.manifest.clone());
                        }
                        Request::CompiledCount { reply } => {
                            let _ = reply.send(engine.compiled_count());
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("device thread died during startup"))??;
        Ok(DeviceServer {
            handle: DeviceHandle { tx },
            join: Some(join),
        })
    }

    /// Cloneable handle for submitting work to the device thread.
    pub fn handle(&self) -> DeviceHandle {
        self.handle.clone()
    }
}

impl DeviceHandle {
    /// Execute an artifact by id (blocking request/reply).
    pub fn exec(&self, id: &str, inputs: Vec<Tensor>) -> anyhow::Result<ExecResult> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Exec {
                id: id.to_string(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("device thread gone"))?
    }

    /// Bind an input prefix on the device thread; returns the session id.
    /// Bound literals are marshaled once and reused by [`Self::exec_bound`]
    /// — the §Perf fix for streaming surveillance (D/G/mask/bw stay
    /// resident instead of being re-marshaled per chunk).
    pub fn bind_session(&self, id: &str, prefix: Vec<Tensor>) -> anyhow::Result<u64> {
        let session = NEXT_SESSION.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Bind {
                session,
                id: id.to_string(),
                prefix,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("device thread gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("device thread gone"))??;
        Ok(session)
    }

    /// Execute a bound session with the remaining inputs.
    pub fn exec_bound(&self, session: u64, tail: Vec<Tensor>) -> anyhow::Result<ExecResult> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::ExecBound {
                session,
                tail,
                reply,
            })
            .map_err(|_| anyhow::anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("device thread gone"))?
    }

    /// Release a bound session (idempotent; best-effort on shutdown).
    pub fn unbind_session(&self, session: u64) {
        let _ = self.tx.send(Request::Unbind { session });
    }

    /// Fetch the manifest (cached copy crossing the channel).
    pub fn manifest(&self) -> anyhow::Result<Manifest> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Manifest { reply })
            .map_err(|_| anyhow::anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("device thread gone"))
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> anyhow::Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::CompiledCount { reply })
            .map_err(|_| anyhow::anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("device thread gone"))
    }
}

/// Default artifact directory (overridable via `CONTAINERSTRESS_ARTIFACTS`).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("CONTAINERSTRESS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
