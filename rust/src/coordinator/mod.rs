//! The **ContainerStress coordinator** — the paper's system contribution.
//!
//! A nested-loop Monte Carlo sweep (paper Fig. 1) over the three ML design
//! parameters (signals × memory vectors × observations): every valid grid
//! cell is measured `trials` times on freshly synthesized TPSS telemetry,
//! through either the AOT/PJRT device path or the native comparator, and
//! aggregated into compute-cost summaries that the [`crate::surface`]
//! layer turns into the paper's 3-D response surfaces.
//!
//! - [`sweep`]   — grid construction, trial execution, aggregation;
//! - [`planner`] — adaptive trial allocation + surface-model cell pruning;
//! - [`jobs`]    — the scoping-job queue (leader/worker service front).

pub mod jobs;
pub mod planner;
pub mod sweep;

pub use sweep::{
    run_sweep, run_sweep_cached, Backend, CellCosts, CellKey, CellMeasure, CellStore,
    SweepResult, SweepSpec,
};
