//! Scenario: **datacenter IT-asset monitoring** — the paper's "Customer A"
//! extreme (§I): ~20 signals sampled once per hour, a couple of MB per
//! year. Runs a small Monte Carlo sweep around the use case, fits the
//! response surfaces, and asks the recommender which cloud shape to buy.
//!
//! Run: `make artifacts && cargo run --release --example scoping_datacenter`

use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::recommend::{recommend, LocalCalibration, Sla};
use containerstress::runtime::DeviceServer;
use containerstress::shapes::Workload;
use containerstress::surface::ResponseSurface;

fn main() -> anyhow::Result<()> {
    containerstress::util::logger::init();
    let server = DeviceServer::start(containerstress::runtime::default_artifact_dir())?;

    // Sweep a neighbourhood of the use case (scaled dev-bucket grid).
    let spec = SweepSpec {
        signals: vec![8, 12, 16],
        memvecs: vec![32, 48, 64],
        obs: vec![64, 128, 256],
        trials: 3,
        seed: 2024,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    };
    println!("sweeping {}×{}×{} cells …", 3, 3, 3);
    let result = run_sweep(&spec, Backend::Device(server.handle()))?;

    let train_surf = ResponseSurface::fit(&result.samples("train"))?;
    let surveil_surf = ResponseSurface::fit(&result.samples("surveil"))?;
    println!(
        "response surfaces: train r²={:.3} exponents {:?}\n                  surveil r²={:.3} exponents {:?}",
        train_surf.r2,
        train_surf.exponents().map(|e| (e * 100.0).round() / 100.0),
        surveil_surf.r2,
        surveil_surf.exponents().map(|e| (e * 100.0).round() / 100.0),
    );

    // Customer A: 20 signals, hourly sampling.
    let workload = Workload::customer_a();
    let cal = LocalCalibration::from_surface(&surveil_surf, 16, 64, 256);
    let rec = recommend(
        &workload,
        &train_surf,
        &surveil_surf,
        cal,
        &Sla::default(),
    );
    println!("\n{}", rec.render());
    let chosen = rec.chosen_shape().expect("customer A must fit somewhere");
    println!(
        "→ scope: {} at ${:.4}/hr ({:.4}% utilised)",
        chosen.shape.name,
        chosen.usd_per_hour,
        chosen.utilization * 100.0
    );
    Ok(())
}
