//! Runtime metrics: counters and timing histograms with text/JSON export.
//!
//! The coordinator and runtime record device calls, cache hits, trial
//! counts and per-phase timings here; `containerstress … --metrics` dumps
//! the registry at exit.

use crate::util::json::Json;
use crate::util::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Global-or-local metrics registry (thread-safe).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    samples: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Registry {
    /// Fresh, empty registry (tests; production uses [`Registry::global`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Process-wide registry.
    pub fn global() -> &'static Registry {
        static GLOBAL: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Increment a counter by one.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `v` to a counter.
    pub fn add(&self, name: &str, v: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += v;
    }

    /// Record a duration sample under `name`.
    pub fn time(&self, name: &str, d: Duration) {
        self.sample(name, d.as_secs_f64());
    }

    /// Record one observation of a sampled statistic.
    pub fn sample(&self, name: &str, v: f64) {
        self.samples
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(v);
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Summary statistics of a sampled series, if any were recorded.
    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.samples
            .lock()
            .unwrap()
            .get(name)
            .filter(|v| !v.is_empty())
            .map(|v| Summary::of(v))
    }

    /// Human-readable dump.
    pub fn render(&self) -> String {
        let mut out = String::from("=== metrics ===\n");
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in self.samples.lock().unwrap().iter() {
            if v.is_empty() {
                continue;
            }
            let s = Summary::of(v);
            out.push_str(&format!(
                "{k}: n={} median={:.3e}s mean={:.3e}s p75={:.3e}s\n",
                s.n, s.median, s.mean, s.p75
            ));
        }
        out
    }

    /// JSON export (counters + summaries).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut samples = BTreeMap::new();
        for (k, v) in self.samples.lock().unwrap().iter() {
            if v.is_empty() {
                continue;
            }
            let s = Summary::of(v);
            samples.insert(
                k.clone(),
                Json::obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("median", Json::Num(s.median)),
                    ("mean", Json::Num(s.mean)),
                    ("min", Json::Num(s.min)),
                    ("max", Json::Num(s.max)),
                ]),
            );
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("timers", Json::Obj(samples)),
        ])
    }

    /// Reset everything (tests).
    pub fn clear(&self) {
        self.counters.lock().unwrap().clear();
        self.samples.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.inc("a");
        r.inc("a");
        r.add("a", 3);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn samples_summarise() {
        let r = Registry::new();
        for i in 1..=5 {
            r.sample("lat", i as f64);
        }
        let s = r.summary("lat").unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert!(r.summary("none").is_none());
    }

    #[test]
    fn render_and_json() {
        let r = Registry::new();
        r.inc("calls");
        r.time("t", Duration::from_millis(5));
        let text = r.render();
        assert!(text.contains("calls: 1"));
        let j = r.to_json();
        assert!(j.get("counters").unwrap().get("calls").is_some());
        assert!(j.get("timers").unwrap().get("t").is_some());
    }

    #[test]
    fn concurrent_increments() {
        let r = Registry::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        r.inc("n");
                    }
                });
            }
        });
        assert_eq!(r.counter("n"), 8000);
    }
}
