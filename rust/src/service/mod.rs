//! The **multi-tenant scoping service** — `containerstress serve`.
//!
//! The paper's framework exists to "autonomously scale any size customer ML
//! use case"; this module is the network surface that makes the coordinator
//! operable as such a service rather than a one-shot CLI. It is built
//! entirely from in-repo substrates (std `TcpListener`,
//! [`crate::util::threadpool`], [`crate::util::json`]) — no external web
//! stack is available offline:
//!
//! - [`http`]   — minimal HTTP/1.1 server core (parse, dispatch, respond);
//! - [`routes`] — the JSON API: submit scope jobs and fleet scenarios,
//!   poll status + live progress, cancel jobs, fetch recommendations,
//!   shape catalog, health, metrics;
//! - [`cache`]  — the content-addressed **cell-level sweep cache**:
//!   identical grid cells across customer requests are measured once, so a
//!   repeat scoping request costs a surface fit + recommend instead of a
//!   full Monte Carlo sweep.

pub mod cache;
pub mod http;
pub mod routes;

pub use cache::{CacheKey, CellCosts, SweepCache};
pub use http::{Handler, HttpOptions, HttpServer, Request, Response};
pub use routes::ServiceState;

use crate::config::Config;
use crate::coordinator::jobs::ScopingService;
use crate::coordinator::{Backend, CellStore};
use std::sync::Arc;

/// Connection-handler pool size. Handlers only parse/serialize JSON and
/// enqueue jobs (sweep compute runs on the shared trial executor), so a
/// small, fixed pool suffices.
const HTTP_WORKERS: usize = 4;

/// A running service instance: HTTP front + scoping queue + sweep cache.
pub struct Server {
    http: HttpServer,
    state: Arc<ServiceState>,
}

impl Server {
    /// Start serving on `cfg.service.host:port` (port 0 picks an ephemeral
    /// port — use [`Server::addr`] for the real one). The sweep cache is
    /// disk-backed at `cfg.service.cache_dir`, or memory-only when `None`.
    pub fn start(cfg: &Config, backend: Backend) -> anyhow::Result<Server> {
        crate::obs::touch_process_start();
        crate::obs::set_access_log(cfg.service.access_log);
        let cache = match &cfg.service.cache_dir {
            Some(dir) => Arc::new(SweepCache::open(dir)?),
            None => Arc::new(SweepCache::in_memory()),
        };
        let svc = ScopingService::start_with_scheduler(
            backend,
            cfg.service.queue_cap,
            Some(Arc::clone(&cache) as Arc<dyn CellStore>),
            cfg.service.executor_workers,
            cfg.service.fair_share,
        );
        let state = Arc::new(
            ServiceState::new(svc, cache, cfg.sweep.clone()).with_stream_heartbeat(
                std::time::Duration::from_millis(cfg.service.stream_heartbeat_ms),
            ),
        );
        let handler_state = Arc::clone(&state);
        let handler: Handler = Arc::new(move |req| handler_state.handle(req));
        let addr = format!("{}:{}", cfg.service.host, cfg.service.port);
        let opts = HttpOptions {
            keep_alive: cfg.service.keep_alive,
            max_requests_per_conn: cfg.service.keep_alive_max_requests,
        };
        let http = HttpServer::bind_with(&addr, HTTP_WORKERS, handler, opts)?;
        log::info!("scoping service listening on http://{}", http.addr());
        Ok(Server { http, state })
    }

    /// The bound socket address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr()
    }

    /// Shared route state (job queue + cache) — tests and embedders.
    pub fn state(&self) -> &ServiceState {
        &self.state
    }

    /// Serve until the process is killed (the `serve` subcommand).
    pub fn join(self) {
        self.http.join();
    }

    /// Stop accepting and drain in-flight connections.
    pub fn shutdown(self) {
        self.http.shutdown();
    }
}
