//! Scoping-job queue: the leader/worker service front of the coordinator.
//!
//! Customers (or the CLI) submit [`ScopeJob`]s; a leader thread drains the
//! queue in FIFO order and runs each sweep (each sweep fans its trials out
//! over the shared thread pool). Results are retrievable by job id, so a
//! long-running service can scope many customer use cases concurrently
//! with bounded resources — the "autonomous" part of the paper's title.

use super::sweep::{run_sweep, Backend, SweepResult, SweepSpec};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// Job identifier.
pub type JobId = u64;

/// Job status as observed by clients.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Queued,
    Running,
    Done(Arc<SweepResult>),
    Failed(String),
}

/// One submitted scoping request.
#[derive(Clone, Debug)]
pub struct ScopeJob {
    pub id: JobId,
    pub spec: SweepSpec,
}

struct Shared {
    statuses: Mutex<HashMap<JobId, JobStatus>>,
    done: Condvar,
}

/// The scoping service (leader thread + job registry).
pub struct ScopingService {
    tx: Option<mpsc::Sender<ScopeJob>>,
    shared: Arc<Shared>,
    next_id: Mutex<JobId>,
    leader: Option<std::thread::JoinHandle<()>>,
    /// Max queued+running jobs before submits are rejected (backpressure).
    queue_cap: usize,
}

impl ScopingService {
    /// Start a service over the given execution backend. `queue_cap`
    /// bounds the number of queued jobs (backpressure: submits fail fast
    /// beyond it rather than accumulating unbounded work).
    pub fn start(backend: Backend, queue_cap: usize) -> ScopingService {
        let (tx, rx) = mpsc::channel::<ScopeJob>();
        let shared = Arc::new(Shared {
            statuses: Mutex::new(HashMap::new()),
            done: Condvar::new(),
        });
        let shared2 = Arc::clone(&shared);
        let leader = std::thread::Builder::new()
            .name("scoping-leader".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    {
                        let mut st = shared2.statuses.lock().unwrap();
                        st.insert(job.id, JobStatus::Running);
                    }
                    let result = run_sweep(&job.spec, backend.clone());
                    let status = match result {
                        Ok(r) => JobStatus::Done(Arc::new(r)),
                        Err(e) => JobStatus::Failed(e.to_string()),
                    };
                    let mut st = shared2.statuses.lock().unwrap();
                    st.insert(job.id, status);
                    shared2.done.notify_all();
                }
            })
            .expect("spawn leader");
        ScopingService {
            tx: Some(tx),
            shared,
            next_id: Mutex::new(1),
            leader: Some(leader),
            queue_cap: queue_cap.max(1),
        }
    }

    /// Submit a sweep; returns its job id, or an error when the queue is
    /// saturated (backpressure).
    pub fn submit(&self, spec: SweepSpec) -> anyhow::Result<JobId> {
        let queued = {
            let st = self.shared.statuses.lock().unwrap();
            st.values()
                .filter(|s| matches!(s, JobStatus::Queued | JobStatus::Running))
                .count()
        };
        let cap = self.queue_cap;
        anyhow::ensure!(
            queued < cap,
            "scoping queue saturated ({queued}/{cap}); retry later"
        );
        let id = {
            let mut n = self.next_id.lock().unwrap();
            let id = *n;
            *n += 1;
            id
        };
        self.shared
            .statuses
            .lock()
            .unwrap()
            .insert(id, JobStatus::Queued);
        self.tx
            .as_ref()
            .expect("service stopped")
            .send(ScopeJob { id, spec })
            .map_err(|_| anyhow::anyhow!("leader thread gone"))?;
        Ok(id)
    }

    /// Non-blocking status check.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared.statuses.lock().unwrap().get(&id).cloned()
    }

    /// Block until a job completes (or fails).
    pub fn wait(&self, id: JobId) -> anyhow::Result<Arc<SweepResult>> {
        let mut st = self.shared.statuses.lock().unwrap();
        loop {
            match st.get(&id) {
                None => anyhow::bail!("unknown job {id}"),
                Some(JobStatus::Done(r)) => return Ok(Arc::clone(r)),
                Some(JobStatus::Failed(e)) => anyhow::bail!("job {id} failed: {e}"),
                Some(_) => {
                    st = self.shared.done.wait(st).unwrap();
                }
            }
        }
    }

    /// Graceful shutdown: stop accepting, finish queued work.
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(l) = self.leader.take() {
            let _ = l.join();
        }
    }
}

impl Drop for ScopingService {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(l) = self.leader.take() {
            let _ = l.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![4],
            memvecs: vec![8],
            obs: vec![32],
            trials: 1,
            seed: 2,
            model: "mset2".into(),
            workers: 1,
        }
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let svc = ScopingService::start(Backend::Native, 8);
        let id = svc.submit(tiny_spec()).unwrap();
        let res = svc.wait(id).unwrap();
        assert_eq!(res.cells.len(), 1);
        svc.shutdown();
    }

    #[test]
    fn jobs_processed_in_order_with_distinct_ids() {
        let svc = ScopingService::start(Backend::Native, 8);
        let a = svc.submit(tiny_spec()).unwrap();
        let b = svc.submit(tiny_spec()).unwrap();
        assert_ne!(a, b);
        svc.wait(a).unwrap();
        svc.wait(b).unwrap();
        svc.shutdown();
    }

    #[test]
    fn unknown_job_errors() {
        let svc = ScopingService::start(Backend::Native, 8);
        assert!(svc.wait(999).is_err());
        assert!(svc.status(999).is_none());
    }

    #[test]
    fn failed_job_reports_error() {
        let svc = ScopingService::start(Backend::Native, 8);
        let bad = SweepSpec {
            model: "no-such-model".into(),
            ..tiny_spec()
        };
        let id = svc.submit(bad).unwrap();
        let err = svc.wait(id).unwrap_err().to_string();
        assert!(err.contains("failed"), "{err}");
        svc.shutdown();
    }
}
