//! Neural-net plug-in: a small auto-associative MLP (autoencoder).
//!
//! The paper (§II.B) names neural nets as one of the "other conventional
//! forms of ML services" ContainerStress should evaluate through the same
//! pluggable interface. This is a deliberately compact implementation —
//! one tanh hidden layer trained by mini-batch SGD with momentum on the
//! z-scored training window — sufficient to scope the *compute-cost
//! shape* of an NN service (training ∝ epochs·N·n·h, streaming ∝ n·h per
//! observation) and to act as a third residual generator in detection
//! studies.

use super::PrognosticModel;
use crate::linalg::{kernel, Mat, Workspace};
use crate::mset::{Estimate, Scaler};
use crate::util::rng::Rng;

/// Auto-associative MLP: n → h → n with tanh hidden activation.
pub struct MlpPlugin {
    /// Hidden width as a fraction of the input (≥ 2 units).
    pub hidden_frac: f64,
    /// Training epochs.
    pub epochs: usize,
    /// Mini-batch size.
    pub batch: usize,
    /// Learning rate.
    pub lr: f64,
    /// SGD momentum coefficient.
    pub momentum: f64,
    /// Weight-init / shuffle seed.
    pub seed: u64,
    scaler: Option<Scaler>,
    /// (h × n) input weights, (h,) hidden bias.
    w1: Option<Mat>,
    b1: Vec<f64>,
    /// (n × h) output weights, (n,) output bias.
    w2: Option<Mat>,
    b2: Vec<f64>,
}

impl Default for MlpPlugin {
    fn default() -> Self {
        MlpPlugin {
            hidden_frac: 0.5,
            epochs: 30,
            batch: 32,
            lr: 0.05,
            momentum: 0.9,
            seed: 17,
            scaler: None,
            w1: None,
            b1: Vec::new(),
            w2: None,
            b2: Vec::new(),
        }
    }
}

impl MlpPlugin {
    fn hidden(&self, n: usize) -> usize {
        ((n as f64 * self.hidden_frac).round() as usize).max(2)
    }

    /// Forward pass for a batch (rows = observations, scaled units).
    fn forward(&self, xs: &Mat) -> (Mat, Mat) {
        Workspace::with(|ws| {
            let mut hid = Mat::zeros(0, 0);
            let mut out = Mat::zeros(0, 0);
            self.forward_ws(xs, &mut hid, &mut out, ws);
            (hid, out)
        })
    }

    /// [`MlpPlugin::forward`] into caller-owned buffers: both layer
    /// products are NT kernels over row-major weights (no transposed
    /// copies), so a reused `hid`/`out` makes the pass allocation-free.
    fn forward_ws(&self, xs: &Mat, hid: &mut Mat, out: &mut Mat, ws: &mut Workspace) {
        let w1 = self.w1.as_ref().unwrap();
        let w2 = self.w2.as_ref().unwrap();
        // hidden = tanh(X W1ᵀ + b1)
        kernel::matmul_nt_into(hid, xs, w1, ws);
        for row in hid.data.chunks_exact_mut(hid.cols.max(1)) {
            for (v, &b) in row.iter_mut().zip(&self.b1) {
                *v = (*v + b).tanh();
            }
        }
        // out = H W2ᵀ + b2
        kernel::matmul_nt_into(out, hid, w2, ws);
        for row in out.data.chunks_exact_mut(out.cols.max(1)) {
            for (v, &b) in row.iter_mut().zip(&self.b2) {
                *v += b;
            }
        }
    }
}

impl PrognosticModel for MlpPlugin {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn fit(&mut self, x_train: &Mat, _m: usize) -> anyhow::Result<()> {
        let n = x_train.cols;
        let h = self.hidden(n);
        let scaler = Scaler::fit(x_train);
        let xs = scaler.transform(x_train);
        let mut rng = Rng::new(self.seed);
        // Xavier-ish init.
        let mut w1 = Mat::zeros(h, n);
        let s1 = (1.0 / n as f64).sqrt();
        for v in w1.data.iter_mut() {
            *v = s1 * rng.gauss();
        }
        let mut w2 = Mat::zeros(n, h);
        let s2 = (1.0 / h as f64).sqrt();
        for v in w2.data.iter_mut() {
            *v = s2 * rng.gauss();
        }
        self.w1 = Some(w1);
        self.w2 = Some(w2);
        self.b1 = vec![0.0; h];
        self.b2 = vec![0.0; n];
        self.scaler = Some(scaler);

        let mut vw1 = Mat::zeros(h, n);
        let mut vw2 = Mat::zeros(n, h);
        let mut vb1 = vec![0.0; h];
        let mut vb2 = vec![0.0; n];
        let t = xs.rows;
        let mut order: Vec<usize> = (0..t).collect();
        // Mini-batch scratch hoisted out of the loop and the kernel
        // workspace held for the whole fit: the SGD inner loop runs
        // allocation-free after the first batch.
        let mut xb = Mat::zeros(0, 0);
        let mut hid = Mat::zeros(0, 0);
        let mut out = Mat::zeros(0, 0);
        let mut w1g = Mat::zeros(0, 0);
        let mut w2g = Mat::zeros(0, 0);
        let mut dhid = Mat::zeros(0, 0);
        let mut db1 = vec![0.0; h];
        let mut db2 = vec![0.0; n];
        Workspace::with(|ws| {
            for _epoch in 0..self.epochs {
                rng.shuffle(&mut order);
                for chunk in order.chunks(self.batch) {
                    let b = chunk.len();
                    xb.reshape(b, n);
                    for (r, &i) in chunk.iter().enumerate() {
                        xb.row_mut(r).copy_from_slice(xs.row(i));
                    }
                    self.forward_ws(&xb, &mut hid, &mut out, ws);
                    // dL/dout = 2(out − x)/b   (MSE), folded into `out`
                    let dout = &mut out;
                    let scale = 2.0 / b as f64;
                    for (v, &x) in dout.data.iter_mut().zip(&xb.data) {
                        *v = (*v - x) * scale;
                    }
                    // grads
                    kernel::matmul_tn_into(&mut w2g, dout, &hid, ws); // n × h
                    for (s, j) in db2.iter_mut().zip(0..n) {
                        *s = dout.col(j).sum();
                    }
                    // dhid = dout W2 ⊙ (1 − hid²)
                    kernel::matmul_into(&mut dhid, dout, self.w2.as_ref().unwrap(), ws);
                    for (dv, &hv) in dhid.data.iter_mut().zip(&hid.data) {
                        *dv *= 1.0 - hv * hv;
                    }
                    kernel::matmul_tn_into(&mut w1g, &dhid, &xb, ws); // h × n
                    for (s, j) in db1.iter_mut().zip(0..h) {
                        *s = dhid.col(j).sum();
                    }
                    // momentum SGD
                    let w1 = self.w1.as_mut().unwrap();
                    let w2 = self.w2.as_mut().unwrap();
                    for (v, g) in vw1.data.iter_mut().zip(&w1g.data) {
                        *v = self.momentum * *v - self.lr * g;
                    }
                    for (w, v) in w1.data.iter_mut().zip(&vw1.data) {
                        *w += v;
                    }
                    for (v, g) in vw2.data.iter_mut().zip(&w2g.data) {
                        *v = self.momentum * *v - self.lr * g;
                    }
                    for (w, v) in w2.data.iter_mut().zip(&vw2.data) {
                        *w += v;
                    }
                    for (vb, (b1, &g)) in vb1.iter_mut().zip(self.b1.iter_mut().zip(&db1)) {
                        *vb = self.momentum * *vb - self.lr * g;
                        *b1 += *vb;
                    }
                    for (vb, (b2, &g)) in vb2.iter_mut().zip(self.b2.iter_mut().zip(&db2)) {
                        *vb = self.momentum * *vb - self.lr * g;
                        *b2 += *vb;
                    }
                }
            }
        });
        Ok(())
    }

    fn estimate(&self, x: &Mat) -> Estimate {
        let xs = self.scaler.as_ref().expect("fit first").transform(x);
        let (_, xhat) = self.forward(&xs);
        let resid = xs.sub(&xhat);
        Estimate { xhat, resid }
    }

    fn train_flops(&self, n: usize, _m: usize) -> f64 {
        let h = self.hidden(n) as f64;
        let n = n as f64;
        // fwd+bwd ≈ 6·n·h per sample per epoch; window size folded into a
        // nominal 4096-sample training window for scoping purposes.
        6.0 * n * h * 4096.0 * self.epochs as f64
    }

    fn surveil_flops_per_obs(&self, n: usize, _m: usize) -> f64 {
        let h = self.hidden(n) as f64;
        4.0 * n as f64 * h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpss::{inject, synthesize, Fault, TpssConfig};

    #[test]
    fn mlp_learns_reconstruction() {
        let cfg = TpssConfig {
            n_signals: 5,
            n_obs: 2000,
            noise_frac: 0.2,
            cross_corr: 0.7, // strong structure → compressible
            ..TpssConfig::default()
        };
        let train = synthesize(&cfg, 1);
        let mut mlp = MlpPlugin::default();
        mlp.fit(&train.data, 0).unwrap();
        let test = synthesize(&TpssConfig { n_obs: 400, ..cfg }, 2);
        let est = mlp.estimate(&test.data);
        let rms = est.resid.norm() / (est.resid.data.len() as f64).sqrt();
        // untrained reconstruction of z-scored data would have RMS ≈ 1
        assert!(rms < 0.7, "reconstruction RMS {rms} — did not learn");
    }

    #[test]
    fn mlp_detects_gross_fault() {
        let cfg = TpssConfig {
            n_signals: 5,
            n_obs: 2000,
            cross_corr: 0.7,
            ..TpssConfig::default()
        };
        let train = synthesize(&cfg, 3);
        let mut mlp = MlpPlugin::default();
        mlp.fit(&train.data, 0).unwrap();
        let probe_cfg = TpssConfig { n_obs: 300, ..cfg };
        let healthy = synthesize(&probe_cfg, 4);
        let mut faulted = synthesize(&probe_cfg, 4);
        inject(&mut faulted, 2, Fault::Step { magnitude: 8.0 }, 0.0, 5);
        let rh = mlp.estimate(&healthy.data).resid.norm();
        let rf = mlp.estimate(&faulted.data).resid.norm();
        assert!(rf > 1.3 * rh, "fault {rf} vs healthy {rh}");
    }

    #[test]
    fn mlp_deterministic_for_seed() {
        let cfg = TpssConfig::sized(4, 500);
        let train = synthesize(&cfg, 6);
        let mut a = MlpPlugin::default();
        let mut b = MlpPlugin::default();
        a.fit(&train.data, 0).unwrap();
        b.fit(&train.data, 0).unwrap();
        let probe = synthesize(&TpssConfig::sized(4, 50), 7);
        let ea = a.estimate(&probe.data);
        let eb = b.estimate(&probe.data);
        assert!(ea.xhat.max_abs_diff(&eb.xhat) < 1e-12);
    }

    #[test]
    fn flop_model_scales() {
        let p = MlpPlugin::default();
        assert!(p.train_flops(32, 0) > p.train_flops(8, 0));
        assert!(p.surveil_flops_per_obs(32, 0) > p.surveil_flops_per_obs(8, 0));
    }
}
