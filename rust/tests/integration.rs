//! Integration: the AOT device path (PJRT-loaded artifacts) must reproduce
//! the native Rust MSET2 oracle on real synthesized telemetry.
//!
//! Requires AOT artifacts (`python/compile/aot.py` into the
//! `CONTAINERSTRESS_ARTIFACTS` dir). Tests **skip** with a notice when the
//! artifacts are absent so the suite stays green on bare checkouts.

use containerstress::linalg::Mat;
use containerstress::mset;
use containerstress::runtime::{DeviceServer, Tensor};
use containerstress::tpss::{synthesize, TpssConfig};
use std::sync::OnceLock;

/// Skip guard: `return` from a test when no artifacts are available.
macro_rules! require_artifacts {
    () => {
        if !containerstress::runtime::default_artifact_dir()
            .join("manifest.json")
            .exists()
        {
            eprintln!(
                "skipping {}: artifacts missing at {} (generate with python/compile/aot.py)",
                module_path!(),
                containerstress::runtime::default_artifact_dir().display()
            );
            return;
        }
    };
}

fn server() -> &'static DeviceServer {
    static SERVER: OnceLock<DeviceServer> = OnceLock::new();
    SERVER.get_or_init(|| {
        let dir = containerstress::runtime::default_artifact_dir();
        DeviceServer::start(&dir).expect("device server")
    })
}

/// Scaled memory matrix + scaled probe window from TPSS data.
fn prep(n: usize, m: usize, t: usize, seed: u64) -> (Mat, Mat, mset::MsetModel) {
    let ds = synthesize(&TpssConfig::sized(n, t), seed);
    let model = mset::train(&ds.data, m).expect("native train");
    let probe_raw = synthesize(&TpssConfig::sized(n, 70), seed + 1);
    let probe_scaled = model.scaler.transform(&probe_raw.data);
    (model.d.clone(), probe_scaled, model)
}

#[test]
fn device_training_matches_native_oracle() {
    require_artifacts!();
    let (d, _, native) = prep(8, 32, 400, 1);
    let mut sess =
        containerstress::runtime::mset::DeviceMset::new(server().handle(), &d).unwrap();
    let (g_dev, cost) = sess.train().unwrap();
    assert_eq!(g_dev.rows, 32);
    assert!(cost.exec.as_nanos() > 0);
    // Device G (f32 similarity + NS inverse) vs native f64 eigendecomposition.
    // Agreement is conditioning-limited (DESIGN.md §4): compare relatively.
    let scale = native.g.data.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    let rel = g_dev.max_abs_diff(&native.g) / scale;
    assert!(rel < 2e-2, "G relative diff {rel}");
}

#[test]
fn device_surveillance_matches_native_oracle() {
    require_artifacts!();
    let (d, probe, native) = prep(8, 32, 400, 2);
    let mut sess =
        containerstress::runtime::mset::DeviceMset::new(server().handle(), &d).unwrap();
    sess.train().unwrap();
    let (xhat_dev, resid_dev, cost) = sess.surveil(&probe).unwrap();
    let est_native = native.surveil_scaled(&probe);
    assert_eq!(xhat_dev.rows, probe.rows);
    // 70 rows at the manifest chunk size → ⌈70/chunk⌉ device calls
    let chunk = server().handle().manifest().unwrap().chunk;
    assert_eq!(cost.calls, probe.rows.div_ceil(chunk));
    let diff = xhat_dev.max_abs_diff(&est_native.xhat);
    assert!(diff < 2e-2, "estimate diff {diff}");
    let rdiff = resid_dev.max_abs_diff(&est_native.resid);
    assert!(rdiff < 2e-2, "residual diff {rdiff}");
    // residual identity holds on-device too
    let recon = probe.sub(&xhat_dev);
    assert!(recon.max_abs_diff(&resid_dev) < 1e-5);
}

#[test]
fn device_bucket_padding_transparent() {
    require_artifacts!();
    // A workload smaller than any bucket must route up and still match the
    // native oracle computed at the real (unpadded) size.
    let (d, probe, native) = prep(5, 20, 300, 3);
    let mut sess =
        containerstress::runtime::mset::DeviceMset::new(server().handle(), &d).unwrap();
    assert_eq!((sess.bucket.n, sess.bucket.m), (8, 32));
    sess.train().unwrap();
    let (xhat_dev, _, _) = sess.surveil(&probe).unwrap();
    let est_native = native.surveil_scaled(&probe);
    let diff = xhat_dev.max_abs_diff(&est_native.xhat);
    assert!(diff < 2e-2, "padded estimate diff {diff}");
}

#[test]
fn device_aakr_matches_native_plugin() {
    require_artifacts!();
    use containerstress::models::{AakrPlugin, PrognosticModel};
    let n = 8;
    let ds = synthesize(&TpssConfig::sized(n, 400), 4);
    let mut plugin = AakrPlugin::default();
    plugin.fit(&ds.data, 32).unwrap();
    // Re-derive the same scaled memory matrix the plugin selected (the
    // selection procedure is deterministic).
    let scaler = mset::Scaler::fit(&ds.data);
    let xs = scaler.transform(&ds.data);
    let idx = mset::select_memory(&xs, 32);
    let mut d = Mat::zeros(32, n);
    for (r, &i) in idx.iter().enumerate() {
        d.row_mut(r).copy_from_slice(xs.row(i));
    }
    let sess =
        containerstress::runtime::mset::DeviceAakr::new(server().handle(), &d).unwrap();
    let probe = synthesize(&TpssConfig::sized(n, 40), 5);
    let probe_scaled = scaler.transform(&probe.data);
    let (xhat_dev, _, _) = sess.surveil(&probe_scaled).unwrap();
    let est_native = plugin.estimate(&probe.data);
    let diff = xhat_dev.max_abs_diff(&est_native.xhat);
    assert!(diff < 1e-3, "aakr estimate diff {diff}");
}

#[test]
fn executable_cache_compiles_once() {
    require_artifacts!();
    let handle = server().handle();
    let man = handle.manifest().unwrap();
    let art = man
        .find("mset2_train", 8, 32)
        .expect("dev artifact present");
    let inputs = || {
        vec![
            Tensor::new(vec![32, 8], vec![0.1; 256]),
            Tensor::new(vec![32], {
                let mut m = vec![0.0; 32];
                m[..16].fill(1.0);
                m
            }),
            Tensor::scalar1(1.414),
        ]
    };
    let r1 = handle.exec(&art.id, inputs()).unwrap();
    let r2 = handle.exec(&art.id, inputs()).unwrap();
    // First call may compile; second must hit the cache.
    assert!(r2.compiled_in.is_none(), "cache miss on second exec");
    // deterministic outputs
    assert_eq!(r1.outputs[0].data, r2.outputs[0].data);
}

#[test]
fn exec_rejects_wrong_shapes() {
    require_artifacts!();
    let handle = server().handle();
    let bad = vec![
        Tensor::new(vec![32, 8], vec![0.1; 256]),
        Tensor::new(vec![31], vec![1.0; 31]), // wrong mask length
        Tensor::scalar1(1.0),
    ];
    assert!(handle.exec("mset2_train_n8_m32", bad).is_err());
    assert!(handle
        .exec("no_such_artifact", vec![Tensor::scalar1(0.0)])
        .is_err());
}
