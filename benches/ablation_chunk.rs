//! **ABL-3**: observation-chunk amortisation.
//!
//! Surveillance streams through the device in fixed `chunk`-row calls; the
//! per-call overhead (literal marshaling, PJRT dispatch) must amortise as
//! the window grows. This bench measures per-observation cost across
//! window sizes (including non-multiples of the chunk — tail padding) and
//! reports the amortisation curve that justified the chunk-size choice.
//!
//! Output: `results/ablation_chunk.csv`.

use containerstress::bench::{figs, table, write_csv, Bencher};
use containerstress::linalg::Mat;
use containerstress::util::rng::Rng;

fn main() {
    containerstress::util::logger::init();
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (sigs, mems) = figs::available_axes(&handle);
    let n = *sigs.last().unwrap();
    let m = *mems.last().unwrap();
    let chunk = handle.manifest().unwrap().chunk;
    let b = if figs::quick() {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    let mut sess = figs::session_for(&handle, n, m, 11);
    sess.train().expect("train");
    let mut rng = Rng::new(12);

    let mut ms = Vec::new();
    let windows = [
        1,
        chunk / 2,
        chunk,
        chunk + 1, // tail padding worst case
        4 * chunk,
        16 * chunk,
        64 * chunk,
    ];
    for &w in &windows {
        let mut probe = Mat::zeros(w, n);
        rng.fill_gauss(&mut probe.data);
        ms.push(b.run_with_units(&format!("window_{w}"), w as f64, || {
            sess.surveil(&probe).expect("surveil")
        }));
    }
    println!("{}", table(&ms));
    let per_obs_small = ms[0].stats.median / 1.0;
    let per_obs_large = ms.last().unwrap().stats.median / (64 * chunk) as f64;
    println!(
        "per-observation cost: {:.1} µs (window=1) → {:.2} µs (window={}) — {:.0}× amortisation",
        per_obs_small * 1e6,
        per_obs_large * 1e6,
        64 * chunk,
        per_obs_small / per_obs_large
    );
    assert!(
        per_obs_large < per_obs_small,
        "chunking must amortise per-call overhead"
    );
    write_csv("results/ablation_chunk.csv", &ms).unwrap();
    println!("ablation_chunk done → results/ablation_chunk.csv");
}
