//! Adaptive sweep planner — variance-targeted trial allocation with
//! surface-model cell pruning.
//!
//! The paper's nested-loop sweep spends a fixed `trials` budget on every
//! grid cell, even where the cost surface is already smooth and
//! low-variance. The planner instead runs the sweep in rounds:
//!
//! 1. **Pilot** — every measurable cell is brought up to
//!    [`SweepSpec::pilot_trials`] cheap trials. Measurements preloaded from
//!    the cell cache count toward this for free, so a warm service skips
//!    straight to convergence checks.
//! 2. **Prune** — when [`SweepSpec::interpolate`] is set, both cost
//!    surfaces (train / surveil) are fitted to the pilot medians. A cell
//!    whose pilot median already agrees with the model's prediction to
//!    within the CI target sits well inside the converged region: it is
//!    marked *interpolated* and receives no further trials. Pruning only
//!    engages when both fits are trustworthy (r² ≥ [`PRUNE_MIN_R2`]).
//!    (In a cache-warm run a pruned cell keeps however many preloaded
//!    trials it arrived with — possibly more than the pilot budget.)
//! 3. **Allocate** — remaining trials go to the cells with the widest
//!    relative confidence intervals, in rounds, until every cell meets
//!    [`SweepSpec::ci_target`] or hits [`SweepSpec::effective_max_trials`].
//!
//! Trial seeds stay content-derived per `(cell, trial index)` — see
//! [`super::sweep`] — so trial `t` of a cell is fed identical synthetic
//! telemetry no matter how many rounds, worker threads, or cache top-ups
//! got the planner there. Adaptive and exhaustive sweeps are therefore
//! fully cache-compatible: an adaptive run can finish on an exhaustive
//! run's stored cells and vice versa.

use super::sweep::{
    grid_keys, run_trial, trial_seed, Backend, CellCosts, CellKey, CellMeasure, CellStore,
    SweepResult, SweepSpec,
};
use crate::metrics::Registry;
use crate::surface::{ResponseSurface, Sample};
use crate::util::threadpool::parallel_map;
use crate::util::Summary;
use std::collections::HashMap;

/// Two-sided normal multiplier for the ~95% confidence interval behind the
/// planner's convergence test.
pub const CI_Z: f64 = 1.96;

/// Minimum response-surface fit quality (r², both phases) before the
/// surface model is trusted to prune cells.
pub const PRUNE_MIN_R2: f64 = 0.9;

/// Relative half-width of the ~95% confidence interval of the mean of
/// `xs`: `z·s / (√n·x̄)` with the sample standard deviation `s`. Returns
/// `f64::INFINITY` below two samples — one timing carries no variance
/// information — so unvisited cells always look unconverged.
pub fn rel_ci(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return f64::INFINITY;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return f64::INFINITY;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    CI_Z * var.sqrt() / ((n as f64).sqrt() * mean)
}

/// Whether both phases of a cell meet the relative-CI target.
pub fn converged(costs: &CellCosts, ci_target: f64) -> bool {
    rel_ci(&costs.train_s) <= ci_target && rel_ci(&costs.surveil_s) <= ci_target
}

/// Trials needed for `rel_ci(xs) ≤ target`, estimated from the current
/// sample: `n ≈ (z·s / (x̄·target))²`. Never less than the current count.
fn needed_trials(xs: &[f64], target: f64) -> usize {
    let n = xs.len();
    if n < 2 {
        return n + 1;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return n;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    let need = (CI_Z * var.sqrt() / (mean * target)).powi(2);
    (need.ceil() as usize).max(n)
}

/// Mutable planner state for one measurable (non-gap) cell.
struct CellState {
    key: CellKey,
    costs: CellCosts,
    /// Trials preloaded from the cache (no store-back needed when the
    /// planner adds nothing beyond them).
    cached_trials: usize,
    interpolated: bool,
}

impl CellState {
    fn trials(&self) -> usize {
        self.costs.train_s.len()
    }
}

/// Execute one round of trials and append the costs in trial-index order.
/// `work` items are `(state index, cell, seed)`.
fn execute_round(
    workers: usize,
    backend: &Backend,
    model: &str,
    states: &mut [CellState],
    work: &[(usize, CellKey, u64)],
) -> anyhow::Result<()> {
    if work.is_empty() {
        return Ok(());
    }
    let results = parallel_map(workers, work, |_, &(_, key, seed)| {
        let r = run_trial(backend, model, key, seed);
        Registry::global().inc("sweep.trials");
        r
    });
    // `parallel_map` returns results in input order and `work` lists each
    // cell's trials in ascending index order, so pushing in order keeps
    // every cost vector aligned with its trial-seed sequence.
    for (&(i, key, _), r) in work.iter().zip(results.into_iter()) {
        let c = r.map_err(|e| anyhow::anyhow!("cell {key:?}: {e}"))?;
        states[i].costs.train_s.push(c.train_s);
        states[i].costs.surveil_s.push(c.surveil_s);
    }
    Ok(())
}

/// Fit both cost surfaces to the current medians and mark unconverged
/// cells whose predictions agree with their pilot medians to within
/// `ci_target`. Returns the number of cells pruned. No-ops when fewer than
/// 10 cells are measurable or either fit is below [`PRUNE_MIN_R2`].
fn prune_by_surface(states: &mut [CellState], ci_target: f64) -> usize {
    if states.len() < 10 {
        return 0;
    }
    let sample = |s: &CellState, cost: f64| Sample {
        n_signals: s.key.n,
        n_memvec: s.key.m,
        n_obs: s.key.obs,
        cost: cost.max(1e-9),
    };
    let train: Vec<Sample> = states
        .iter()
        .map(|s| sample(s, Summary::of(&s.costs.train_s).median))
        .collect();
    let surveil: Vec<Sample> = states
        .iter()
        .map(|s| sample(s, Summary::of(&s.costs.surveil_s).median))
        .collect();
    let (ts, ss) = match (ResponseSurface::fit(&train), ResponseSurface::fit(&surveil)) {
        (Ok(a), Ok(b)) => (a, b),
        _ => return 0,
    };
    if ts.r2 < PRUNE_MIN_R2 || ss.r2 < PRUNE_MIN_R2 {
        log::info!(
            "planner: surface fits too weak to prune (train r²={:.3}, surveil r²={:.3})",
            ts.r2,
            ss.r2
        );
        return 0;
    }
    let mut pruned = 0usize;
    for (i, s) in states.iter_mut().enumerate() {
        if s.interpolated || converged(&s.costs, ci_target) {
            continue;
        }
        // `train`/`surveil` were built in `states` order — reuse their
        // medians instead of re-sorting both phases per cell.
        let med_t = train[i].cost;
        let med_s = surveil[i].cost;
        let pred_t = ts.predict(s.key.n, s.key.m, s.key.obs);
        let pred_s = ss.predict(s.key.n, s.key.m, s.key.obs);
        let within = |pred: f64, med: f64| med > 0.0 && ((pred - med) / med).abs() <= ci_target;
        if within(pred_t, med_t) && within(pred_s, med_s) {
            s.interpolated = true;
            pruned += 1;
        }
    }
    if pruned > 0 {
        Registry::global().add("sweep.planner.interpolated_cells", pruned as u64);
    }
    pruned
}

/// Run the sweep under the adaptive planner (entered from
/// [`super::sweep::run_sweep_cached`] when [`SweepSpec::adaptive`] is set;
/// the spec is already validated).
pub(crate) fn run_adaptive(
    spec: &SweepSpec,
    backend: Backend,
    cache: Option<&dyn CellStore>,
) -> anyhow::Result<SweepResult> {
    let pilot = spec.pilot_trials;
    let max = spec.effective_max_trials();
    let target = spec.ci_target;
    let workers = spec.effective_workers();
    let keys = grid_keys(spec);

    // Preload cell state from the cache; whatever is stored counts toward
    // pilot coverage and convergence for free.
    let mut states: Vec<CellState> = Vec::new();
    for &key in &keys {
        if spec.is_gap(key) {
            continue;
        }
        let mut costs = CellCosts::default();
        if let Some(c) = cache {
            if let Some(mut got) = c.fetch(key, spec, backend.tag()) {
                // Honour the per-cell bound even against oversized entries,
                // and drop any phase-length mismatch from a foreign store
                // (same defence as the exhaustive path).
                got.normalize(max);
                costs = got;
            }
        }
        let cached_trials = costs.train_s.len();
        states.push(CellState {
            key,
            costs,
            cached_trials,
            interpolated: false,
        });
    }

    // Round 1: pilot — bring every cell up to `pilot` trials.
    let mut work: Vec<(usize, CellKey, u64)> = Vec::new();
    for (i, s) in states.iter().enumerate() {
        for t in s.trials()..pilot {
            work.push((i, s.key, trial_seed(spec, s.key, t)));
        }
    }
    log::info!(
        "planner pilot: {} cells × ≤{pilot} trials ({} scheduled, {} from cache), \
         ci_target={target}, max_trials={max}, model={}, backend={}, workers={workers}",
        states.len(),
        work.len(),
        states.iter().map(|s| s.cached_trials).sum::<usize>(),
        spec.model,
        backend.tag()
    );
    execute_round(workers, &backend, &spec.model, &mut states, &work)?;

    // Round 2: surface-model pruning of predictable cells.
    if spec.interpolate {
        let pruned = prune_by_surface(&mut states, target);
        if pruned > 0 {
            log::info!("planner: {pruned} cells accepted via surface interpolation");
        }
    }

    // Rounds 3+: variance-targeted allocation until convergence or cap.
    // Terminates: every non-empty round grows at least one cell's trial
    // count toward `max`, and converged/capped cells leave the pool.
    let mut rounds = 0usize;
    loop {
        let mut work: Vec<(usize, CellKey, u64)> = Vec::new();
        for (i, s) in states.iter().enumerate() {
            if s.interpolated {
                continue;
            }
            let n = s.trials();
            if n >= max || converged(&s.costs, target) {
                continue;
            }
            let goal = needed_trials(&s.costs.train_s, target)
                .max(needed_trials(&s.costs.surveil_s, target))
                .clamp(n + 1, max);
            for t in n..goal {
                work.push((i, s.key, trial_seed(spec, s.key, t)));
            }
        }
        if work.is_empty() {
            break;
        }
        rounds += 1;
        log::info!("planner round {rounds}: {} top-up trials", work.len());
        execute_round(workers, &backend, &spec.model, &mut states, &work)?;
    }
    Registry::global().add("sweep.planner.rounds", rounds as u64);

    // Aggregate in grid order; store freshly measured cells back.
    let by_key: HashMap<CellKey, &CellState> = states.iter().map(|s| (s.key, s)).collect();
    let mut cells = Vec::new();
    for &key in &keys {
        if spec.is_gap(key) {
            cells.push(CellMeasure {
                key,
                train: None,
                surveil: None,
                violated: true,
                interpolated: false,
            });
            Registry::global().inc("sweep.gap_cells");
            continue;
        }
        let s = by_key.get(&key).expect("planner state for measurable cell");
        anyhow::ensure!(
            !s.costs.train_s.is_empty(),
            "no trials completed for {key:?}"
        );
        if let Some(c) = cache {
            if s.trials() > s.cached_trials {
                c.store(key, spec, backend.tag(), s.costs.clone());
            }
        }
        cells.push(CellMeasure {
            key,
            train: Some(Summary::of(&s.costs.train_s)),
            surveil: Some(Summary::of(&s.costs.surveil_s)),
            violated: false,
            interpolated: s.interpolated,
        });
    }
    Ok(SweepResult {
        spec: spec.clone(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_sweep_cached;
    use crate::service::cache::SweepCache;

    fn adaptive_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![2, 3, 4],
            memvecs: vec![8, 12, 16],
            obs: vec![16, 32],
            trials: 4,
            seed: 9,
            model: "mset2".into(),
            workers: 2,
            pilot_trials: 2,
            ci_target: 0.5,
            max_trials: 4,
            interpolate: false,
        }
    }

    #[test]
    fn rel_ci_basics() {
        assert!(rel_ci(&[]).is_infinite());
        assert!(rel_ci(&[1.0]).is_infinite());
        assert_eq!(rel_ci(&[2.0, 2.0, 2.0]), 0.0);
        // wide spread → wide interval
        assert!(rel_ci(&[1.0, 10.0]) > 1.0);
    }

    #[test]
    fn adaptive_counts_stay_within_bounds() {
        let res = run_sweep_cached(&adaptive_spec(), Backend::Native, None).unwrap();
        assert_eq!(res.cells.len(), 18);
        assert!(res.gap_cells().is_empty()); // m ≥ 2n everywhere on this grid
        for c in &res.cells {
            let t = c.train.as_ref().unwrap();
            let s = c.surveil.as_ref().unwrap();
            assert_eq!(t.n, s.n, "phases share the trial schedule");
            assert!(
                (2..=4).contains(&t.n),
                "cell {:?} ran {} trials, outside [pilot, max]",
                c.key,
                t.n
            );
            assert!(!c.interpolated, "interpolate=false must never mark cells");
        }
    }

    #[test]
    fn interpolated_cells_keep_pilot_budget() {
        let spec = SweepSpec {
            interpolate: true,
            ..adaptive_spec()
        };
        let res = run_sweep_cached(&spec, Backend::Native, None).unwrap();
        for c in &res.cells {
            if c.interpolated {
                assert_eq!(
                    c.train.as_ref().unwrap().n,
                    spec.pilot_trials,
                    "pruned cells must stop at the pilot budget"
                );
            }
        }
        // Whether any cell prunes depends on measured noise, but the result
        // must always partition cleanly.
        assert_eq!(
            res.measured_cells() + res.interpolated_cells() + res.gap_cells().len(),
            res.cells.len()
        );
    }

    #[test]
    fn all_gap_grid_yields_no_measurements_and_no_panic() {
        let spec = SweepSpec {
            signals: vec![8],
            memvecs: vec![8], // 8 < 2·8 → gap
            obs: vec![16],
            ..adaptive_spec()
        };
        let res = run_sweep_cached(&spec, Backend::Native, None).unwrap();
        assert_eq!(res.cells.len(), 1);
        assert!(res.cells[0].violated);
        assert_eq!(res.measured_cells(), 0);
        assert_eq!(res.total_trials(), 0);
    }

    #[test]
    fn second_adaptive_run_is_served_from_cache() {
        let cache = SweepCache::in_memory();
        let spec = adaptive_spec();
        let a = run_sweep_cached(&spec, Backend::Native, Some(&cache)).unwrap();
        let stored = cache.len();
        assert_eq!(stored, 18);

        // Identical request: every cell's stored trials already satisfy
        // the planner — each terminated converged or at the cap, and with
        // interpolate=false no noise-dependent prune decision is re-made —
        // so no new trials run and the summaries are bit-identical. (With
        // interpolate=true a warm run may legitimately re-measure a cell
        // the cold run pruned, since the re-fitted surface sees newer
        // medians; that refinement is allowed, just not exercised here.)
        let b = run_sweep_cached(&spec, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 18);
        assert_eq!(cache.len(), stored);
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.key, cb.key);
            assert_eq!(
                ca.train.as_ref().unwrap().n,
                cb.train.as_ref().unwrap().n,
                "cell {:?} re-measured despite warm cache",
                ca.key
            );
            assert_eq!(
                ca.train.as_ref().unwrap().median,
                cb.train.as_ref().unwrap().median
            );
        }
    }

    #[test]
    fn exhaustive_run_tops_up_short_adaptive_entries() {
        // An adaptive sweep may store fewer trials per cell than a later
        // exhaustive request needs; the exhaustive run keeps the stored
        // prefix and measures only the missing trial indices.
        let cache = SweepCache::in_memory();
        let adaptive = adaptive_spec();
        run_sweep_cached(&adaptive, Backend::Native, Some(&cache)).unwrap();
        let exhaustive = SweepSpec {
            ci_target: 0.0,
            trials: 4,
            ..adaptive_spec()
        };
        let probe = CellKey { n: 2, m: 8, obs: 16 };
        let before = CellStore::fetch(&cache, probe, &exhaustive, "native").unwrap();
        let res = run_sweep_cached(&exhaustive, Backend::Native, Some(&cache)).unwrap();
        for c in &res.cells {
            assert_eq!(c.train.as_ref().unwrap().n, 4);
            assert!(!c.interpolated);
        }
        let after = CellStore::fetch(&cache, probe, &exhaustive, "native").unwrap();
        assert_eq!(after.train_s.len(), 4);
        assert_eq!(
            &after.train_s[..before.train_s.len()],
            &before.train_s[..],
            "the cached prefix must be reused, not re-measured"
        );
    }
}
