//! ASCII rendering of the paper's 3-D response surfaces.
//!
//! The paper presents compute cost as 3-D response surfaces with a blue→red
//! colour ramp. In a terminal we render the same data as a heat-map: rows =
//! one axis, columns = the other, glyph density = normalised cost. Cells the
//! sweep skipped (the m ≥ 2n training constraint, Fig. 6) render as blanks —
//! the "missing parts of the training surface".

/// Glyph ramp from cold to hot.
const RAMP: &[char] = &['·', '░', '▒', '▓', '█'];

/// Render a heat-map. `grid[r][c]` is the value at row `r`, column `c`;
/// `None` marks constraint gaps. Rows are printed top-down in given order.
pub fn heatmap(
    title: &str,
    row_label: &str,
    col_label: &str,
    row_ticks: &[String],
    col_ticks: &[String],
    grid: &[Vec<Option<f64>>],
    log_scale: bool,
) -> String {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for row in grid {
        for v in row.iter().flatten() {
            let v = if log_scale { v.max(1e-30).ln() } else { *v };
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    let span = (hi - lo).max(1e-12);
    let tick_w = row_ticks.iter().map(|t| t.len()).max().unwrap_or(4).max(4);
    let cell_w = col_ticks.iter().map(|t| t.len()).max().unwrap_or(3).max(3) + 1;

    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!(
        "  rows: {row_label}   cols: {col_label}   ramp: {} (low) → {} (high){}\n",
        RAMP[0],
        RAMP[RAMP.len() - 1],
        if log_scale { "  [log scale]" } else { "" }
    ));
    for (r, row) in grid.iter().enumerate() {
        let tick = row_ticks.get(r).map(String::as_str).unwrap_or("");
        out.push_str(&format!("  {tick:>tick_w$} |"));
        for v in row {
            match v {
                None => out.push_str(&" ".repeat(cell_w)),
                Some(x) => {
                    let x = if log_scale { x.max(1e-30).ln() } else { *x };
                    let t = ((x - lo) / span).clamp(0.0, 1.0);
                    let g = RAMP[((t * (RAMP.len() - 1) as f64).round()) as usize];
                    let pad = cell_w - 1;
                    out.push_str(&" ".repeat(pad / 2));
                    out.push(g);
                    out.push_str(&" ".repeat(pad - pad / 2));
                }
            }
        }
        out.push('\n');
    }
    out.push_str(&format!("  {:>tick_w$} +", ""));
    for _ in col_ticks {
        out.push_str(&"-".repeat(cell_w));
    }
    out.push('\n');
    out.push_str(&format!("  {:>tick_w$}  ", ""));
    for t in col_ticks {
        out.push_str(&format!("{t:>cell_w$}"));
    }
    out.push('\n');
    out
}

/// CSV export of the same grid (long format: row,col,value) for gnuplot /
/// external plotting; gaps are written as empty values.
pub fn grid_csv(
    row_name: &str,
    col_name: &str,
    value_name: &str,
    row_vals: &[f64],
    col_vals: &[f64],
    grid: &[Vec<Option<f64>>],
) -> String {
    let mut out = format!("{row_name},{col_name},{value_name}\n");
    for (r, row) in grid.iter().enumerate() {
        for (c, v) in row.iter().enumerate() {
            match v {
                Some(x) => out.push_str(&format!("{},{},{}\n", row_vals[r], col_vals[c], x)),
                None => out.push_str(&format!("{},{},\n", row_vals[r], col_vals[c])),
            }
        }
    }
    out
}

/// Emit a gnuplot script that renders the CSV as a paper-style 3-D surface
/// (pm3d, blue→red palette).
pub fn gnuplot_script(csv_path: &str, png_path: &str, title: &str, log_xy: bool) -> String {
    let mut s = String::new();
    s.push_str("set datafile separator ','\n");
    s.push_str(&format!("set output '{png_path}'\n"));
    s.push_str("set terminal pngcairo size 900,700\n");
    s.push_str(&format!("set title '{title}'\n"));
    s.push_str("set palette defined (0 'blue', 0.5 'yellow', 1 'red')\n");
    s.push_str("set pm3d at s\nset hidden3d\nset dgrid3d 32,32\n");
    if log_xy {
        s.push_str("set logscale xy 2\n");
    }
    s.push_str(&format!(
        "splot '{csv_path}' every ::1 using 1:2:3 with pm3d notitle\n"
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ticks(v: &[f64]) -> Vec<String> {
        v.iter().map(|x| format!("{x}")).collect()
    }

    #[test]
    fn heatmap_renders_all_rows_and_gaps() {
        let grid = vec![
            vec![Some(1.0), Some(2.0), None],
            vec![Some(4.0), None, Some(8.0)],
        ];
        let s = heatmap(
            "t",
            "m",
            "n",
            &ticks(&[32.0, 64.0]),
            &ticks(&[8.0, 16.0, 32.0]),
            &grid,
            true,
        );
        assert!(s.contains('█'));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    fn heatmap_constant_grid_no_panic() {
        let grid = vec![vec![Some(5.0); 3]; 3];
        let s = heatmap("c", "a", "b", &ticks(&[1., 2., 3.]), &ticks(&[1., 2., 3.]), &grid, false);
        assert!(!s.is_empty());
    }

    #[test]
    fn csv_long_format() {
        let grid = vec![vec![Some(1.5), None]];
        let csv = grid_csv("m", "n", "cost", &[32.0], &[8.0, 16.0], &grid);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "m,n,cost");
        assert_eq!(lines[1], "32,8,1.5");
        assert_eq!(lines[2], "32,16,");
    }

    #[test]
    fn gnuplot_script_mentions_files() {
        let s = gnuplot_script("a.csv", "a.png", "Fig 4", true);
        assert!(s.contains("a.csv") && s.contains("a.png") && s.contains("logscale"));
    }
}
