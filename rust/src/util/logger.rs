//! Tiny stderr logger backing the `log` facade.
//!
//! Level comes from `CONTAINERSTRESS_LOG`
//! (`off|error|warn|info|debug|trace`), defaulting to `info`; an
//! unrecognized value warns once instead of silently meaning `info`.
//! Lines carry absolute UTC wall-clock timestamps
//! (`[2026-08-07T12:34:56.789Z INFO  target] …`) so service logs can be
//! correlated across processes and hosts — the old relative-to-boot
//! seconds were meaningless outside a single run.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::{SystemTime, UNIX_EPOCH};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{} {lvl} {}] {}",
            utc_timestamp(SystemTime::now()),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Gregorian civil date from days since 1970-01-01 (Howard Hinnant's
/// `civil_from_days`, valid far beyond any plausible log timestamp).
fn civil_from_days(days: i64) -> (i64, u32, u32) {
    let z = days + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = (if mp < 10 { mp + 3 } else { mp - 9 }) as u32;
    (y + i64::from(m <= 2), m, d)
}

/// RFC 3339 UTC timestamp with millisecond precision.
fn utc_timestamp(t: SystemTime) -> String {
    let d = t.duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = d.as_secs();
    let (year, month, day) = civil_from_days((secs / 86_400) as i64);
    let tod = secs % 86_400;
    format!(
        "{year:04}-{month:02}-{day:02}T{:02}:{:02}:{:02}.{:03}Z",
        tod / 3600,
        (tod % 3600) / 60,
        tod % 60,
        d.subsec_millis()
    )
}

/// Install the logger (idempotent).
pub fn init() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        crate::obs::touch_process_start();
        let raw = std::env::var("CONTAINERSTRESS_LOG");
        let (level, unrecognized) = match raw.as_deref() {
            Ok("off") => (LevelFilter::Off, None),
            Ok("error") => (LevelFilter::Error, None),
            Ok("warn") => (LevelFilter::Warn, None),
            Ok("info") | Err(_) => (LevelFilter::Info, None),
            Ok("debug") => (LevelFilter::Debug, None),
            Ok("trace") => (LevelFilter::Trace, None),
            Ok(other) => (LevelFilter::Info, Some(other.to_string())),
        };
        if log::set_boxed_logger(Box::new(StderrLogger)).is_ok() {
            log::set_max_level(level);
            if let Some(bad) = unrecognized {
                log::warn!(
                    "unrecognized CONTAINERSTRESS_LOG level '{bad}', defaulting to info \
                     (expected off|error|warn|info|debug|trace)"
                );
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }

    #[test]
    fn utc_timestamps_are_absolute() {
        let at = |secs: u64| utc_timestamp(UNIX_EPOCH + Duration::from_secs(secs));
        assert_eq!(at(0), "1970-01-01T00:00:00.000Z");
        assert_eq!(at(1_456_704_000), "2016-02-29T00:00:00.000Z"); // leap day
        assert_eq!(at(1_583_020_800), "2020-03-01T00:00:00.000Z");
        assert_eq!(
            utc_timestamp(UNIX_EPOCH + Duration::from_millis(86_399_999)),
            "1970-01-01T23:59:59.999Z"
        );
    }
}
