"""AOT pipeline: manifest emission, bucket filtering, HLO text sanity."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def dev_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.emit(str(out), "dev")
    return out


def test_manifest_written_and_parses(dev_artifacts):
    with open(dev_artifacts / "manifest.json") as f:
        man = json.load(f)
    assert man["version"] == 1
    assert man["gamma"] == 0.5
    assert man["ridge_rel"] == 1e-3
    assert man["chunk"] == 32
    assert len(man["artifacts"]) > 0


def test_all_listed_files_exist_and_are_hlo(dev_artifacts):
    with open(dev_artifacts / "manifest.json") as f:
        man = json.load(f)
    for art in man["artifacts"]:
        path = dev_artifacts / art["file"]
        assert path.exists(), art["file"]
        text = path.read_text()
        assert "HloModule" in text, f"{art['file']} is not HLO text"
        # 64-bit-id proto issue does not apply to text, but the text must
        # contain an ENTRY computation the runtime can compile.
        assert "ENTRY" in text


def test_constraint_filters_buckets(dev_artifacts):
    """No artifact may violate the paper's m ≥ 2n training constraint."""
    with open(dev_artifacts / "manifest.json") as f:
        man = json.load(f)
    for art in man["artifacts"]:
        assert art["m"] >= 2 * art["n"], art["id"]


def test_graph_coverage(dev_artifacts):
    """Every valid (n, m) bucket ships all three graphs."""
    with open(dev_artifacts / "manifest.json") as f:
        man = json.load(f)
    combos = {}
    for art in man["artifacts"]:
        combos.setdefault((art["n"], art["m"]), set()).add(art["graph"])
    for (n, m), graphs in combos.items():
        assert graphs == {"mset2_train", "mset2_surveil", "aakr_surveil"}, (
            n,
            m,
            graphs,
        )
    # dev grid: n ∈ {8,16} × m ∈ {32,64}, all satisfy m ≥ 2n
    assert set(combos) == {(8, 32), (8, 64), (16, 32), (16, 64)}


def test_io_shapes_recorded(dev_artifacts):
    with open(dev_artifacts / "manifest.json") as f:
        man = json.load(f)
    chunk = man["chunk"]
    for art in man["artifacts"]:
        ins = {i["name"]: i["shape"] for i in art["inputs"]}
        outs = {o["name"]: o["shape"] for o in art["outputs"]}
        n, m = art["n"], art["m"]
        assert ins["d"] == [m, n]
        assert ins["mask"] == [m]
        assert ins["bw"] == [1]
        if art["graph"] == "mset2_train":
            assert outs["g"] == [m, m]
        else:
            assert ins["x"] == [chunk, n]
            assert outs["xhat"] == [chunk, n]
            assert outs["resid"] == [chunk, n]


def test_profiles_defined():
    assert set(aot.PROFILES) == {"dev", "full"}
    full = aot.PROFILES["full"]
    # the full grid covers the scaled paper ranges (DESIGN.md §5)
    assert max(full["memvecs"]) == 512
    assert max(full["signals"]) == 128
