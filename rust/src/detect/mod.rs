//! **SPRT** — Wald's Sequential Probability Ratio Test over MSET residuals.
//!
//! The paper's headline claim for MSET2 is "very high sensitivity for
//! proactive warnings of incipient anomalies, and ultra-low false-alarm and
//! missed-alarm probabilities". In the MSET literature that property comes
//! from pairing the estimator with SPRT fault detection on the residuals:
//! for each signal we run four sequential tests (positive/negative mean
//! shift, nominal/degraded variance is reduced here to the two mean tests,
//! the classic configuration), with thresholds derived from the target
//! false-alarm probability α and missed-alarm probability β.
//!
//! `h_hi = ln((1−β)/α)`, `h_lo = ln(β/(1−α))`; the log-likelihood ratio for
//! a mean shift of `M·σ` under Gaussian residuals accumulates as
//! `llr += M/σ·(r − M·σ/2)/σ` per sample. Crossing `h_hi` raises an alarm;
//! crossing `h_lo` accepts health and resets.

use crate::linalg::Mat;

/// SPRT configuration.
#[derive(Clone, Copy, Debug)]
pub struct SprtConfig {
    /// Target false-alarm probability.
    pub alpha: f64,
    /// Target missed-alarm probability.
    pub beta: f64,
    /// Hypothesised mean shift in units of residual σ.
    pub shift: f64,
    /// Hypothesised degraded-variance ratio (> 1) for the variance tests;
    /// classic MSET runs four SPRTs per signal: mean ±shift·σ plus
    /// nominal-vs-degraded variance. Set ≤ 1 to disable variance tests.
    pub var_ratio: f64,
}

impl Default for SprtConfig {
    fn default() -> Self {
        SprtConfig {
            alpha: 1e-4,
            beta: 1e-4,
            shift: 3.0,
            var_ratio: 4.0,
        }
    }
}

/// Which sequential test fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlarmKind {
    /// Positive mean shift.
    MeanHigh,
    /// Negative mean shift.
    MeanLow,
    /// Degraded (inflated) residual variance.
    Variance,
}

/// One alarm event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Alarm {
    /// Index of the signal that raised the alarm.
    pub signal: usize,
    /// Observation index at which the SPRT crossed the alarm threshold.
    pub at: usize,
    /// Sign of the detected shift (+1 high, −1 low; 0 for variance).
    pub direction: i8,
    /// Which SPRT test crossed its threshold.
    pub kind: AlarmKind,
}

/// Streaming SPRT detector over per-signal residuals.
#[derive(Clone, Debug)]
pub struct Sprt {
    cfg: SprtConfig,
    /// Residual σ per signal (estimated from healthy data).
    sigma: Vec<f64>,
    /// Log-likelihood accumulators, positive & negative mean test and
    /// degraded-variance test per signal.
    llr_pos: Vec<f64>,
    llr_neg: Vec<f64>,
    llr_var: Vec<f64>,
    h_hi: f64,
    h_lo: f64,
    /// Samples consumed so far.
    t: usize,
}

impl Sprt {
    /// Build from healthy-window residuals (used to estimate σ per signal).
    pub fn from_healthy(resid: &Mat, cfg: SprtConfig) -> Sprt {
        let n = resid.cols;
        let rows = resid.rows as f64;
        let mut sigma = vec![0.0; n];
        for (j, s) in sigma.iter_mut().enumerate() {
            // two streaming passes over the column iterator — no copy
            let mean = resid.col(j).sum::<f64>() / rows;
            let var = resid.col(j).map(|x| (x - mean) * (x - mean)).sum::<f64>() / rows;
            *s = var.sqrt().max(1e-9);
        }
        Sprt {
            cfg,
            h_hi: ((1.0 - cfg.beta) / cfg.alpha).ln(),
            h_lo: (cfg.beta / (1.0 - cfg.alpha)).ln(),
            llr_pos: vec![0.0; n],
            llr_neg: vec![0.0; n],
            llr_var: vec![0.0; n],
            sigma,
            t: 0,
        }
    }

    /// Number of signals the detector was calibrated over.
    pub fn n_signals(&self) -> usize {
        self.sigma.len()
    }

    /// Consume one residual row; returns any alarms fired at this step.
    /// Alarmed accumulators reset so detection can re-arm.
    pub fn step(&mut self, resid_row: &[f64]) -> Vec<Alarm> {
        assert_eq!(resid_row.len(), self.sigma.len());
        let mut alarms = Vec::new();
        let m = self.cfg.shift;
        let v = self.cfg.var_ratio;
        for (j, &r) in resid_row.iter().enumerate() {
            let s = self.sigma[j];
            let z = r / s;
            // LLR increments for shift +Mσ and −Mσ
            self.llr_pos[j] += m * (z - 0.5 * m);
            self.llr_neg[j] += m * (-z - 0.5 * m);
            // degraded-variance test: H1 σ² → V·σ²;
            // llr += ½·[z²·(1−1/V) − ln V]
            if v > 1.0 {
                self.llr_var[j] += 0.5 * (z * z * (1.0 - 1.0 / v) - v.ln());
            }
            let tests = [
                (&mut self.llr_pos[j], 1i8, AlarmKind::MeanHigh),
                (&mut self.llr_neg[j], -1i8, AlarmKind::MeanLow),
                (&mut self.llr_var[j], 0i8, AlarmKind::Variance),
            ];
            for (llr, dir, kind) in tests {
                if *llr >= self.h_hi {
                    alarms.push(Alarm {
                        signal: j,
                        at: self.t,
                        direction: dir,
                        kind,
                    });
                    *llr = 0.0;
                } else if *llr <= self.h_lo {
                    *llr = 0.0; // accept health, restart test
                }
            }
        }
        self.t += 1;
        alarms
    }

    /// Run over a whole residual matrix, collecting alarms.
    pub fn run(&mut self, resid: &Mat) -> Vec<Alarm> {
        let mut out = Vec::new();
        for r in 0..resid.rows {
            out.extend(self.step(resid.row(r)));
        }
        out
    }
}

/// Empirical false-/missed-alarm measurement on labelled data: returns
/// `(false_alarm_rate, missed_alarm_rate, detection_latency)` where latency
/// is observations from fault onset to first alarm on the faulted signal
/// (`None` if never detected).
///
/// False alarms are counted **before fault onset only**: MSET estimates
/// couple signals, so after onset a real fault legitimately perturbs the
/// residuals of *other* signals too (secondary indications, not false
/// alarms in the MSET literature's accounting).
pub fn measure(
    detector: &mut Sprt,
    resid: &Mat,
    fault_signal: Option<usize>,
    fault_start: usize,
) -> (f64, Option<f64>, Option<usize>) {
    let alarms = detector.run(resid);
    let horizon = if fault_signal.is_some() {
        fault_start
    } else {
        resid.rows
    };
    let pre_fault = alarms.iter().filter(|a| a.at < horizon).count();
    let n_healthy_samples = horizon * resid.cols;
    let far = pre_fault as f64 / n_healthy_samples.max(1) as f64;
    match fault_signal {
        None => (far, None, None),
        Some(f) => {
            let first = alarms
                .iter()
                .filter(|a| a.signal == f && a.at >= fault_start)
                .map(|a| a.at)
                .min();
            let missed = if first.is_none() { 1.0 } else { 0.0 };
            (far, Some(missed), first.map(|t| t - fault_start))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_resid(rows: usize, cols: usize, seed: u64, sigma: f64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = sigma * rng.gauss();
        }
        m
    }

    #[test]
    fn no_alarms_on_healthy_gaussian_residuals() {
        let healthy = gaussian_resid(2000, 4, 1, 0.1);
        let mut det = Sprt::from_healthy(&healthy, SprtConfig::default());
        let probe = gaussian_resid(20_000, 4, 2, 0.1);
        let alarms = det.run(&probe);
        // α=1e-4 per test; 20k samples × 4 signals × 2 tests → expect ≲ a few
        assert!(
            alarms.len() <= 8,
            "too many false alarms: {} on healthy data",
            alarms.len()
        );
    }

    #[test]
    fn detects_mean_shift_quickly() {
        let healthy = gaussian_resid(2000, 3, 3, 0.1);
        let mut det = Sprt::from_healthy(&healthy, SprtConfig::default());
        let mut probe = gaussian_resid(500, 3, 4, 0.1);
        // inject +4σ shift on signal 1 from t=100
        for r in 100..500 {
            probe[(r, 1)] += 0.4;
        }
        let (far, missed, latency) = measure(&mut det, &probe, Some(1), 100);
        assert_eq!(missed, Some(0.0), "shift missed");
        let lat = latency.unwrap();
        assert!(lat < 20, "latency {lat} too high for 4σ shift");
        assert!(far < 1e-3, "false alarm rate {far}");
    }

    #[test]
    fn detects_negative_shift_with_direction() {
        let healthy = gaussian_resid(1000, 2, 5, 0.2);
        let mut det = Sprt::from_healthy(&healthy, SprtConfig::default());
        let mut probe = gaussian_resid(300, 2, 6, 0.2);
        for r in 50..300 {
            probe[(r, 0)] -= 1.0; // −5σ
        }
        let alarms = det.run(&probe);
        let neg = alarms
            .iter()
            .find(|a| a.signal == 0 && a.direction == -1)
            .expect("negative-direction alarm expected");
        assert!(neg.at >= 50 && neg.at < 70);
    }

    #[test]
    fn sub_threshold_drift_eventually_caught() {
        // 1.5σ shift is below the 3σ design point but SPRT accumulates.
        let healthy = gaussian_resid(2000, 1, 7, 1.0);
        let mut det = Sprt::from_healthy(&healthy, SprtConfig::default());
        let mut probe = gaussian_resid(3000, 1, 8, 1.0);
        for r in 0..3000 {
            probe[(r, 0)] += 1.5;
        }
        let alarms = det.run(&probe);
        assert!(!alarms.is_empty(), "1.5σ sustained shift never detected");
    }

    #[test]
    fn variance_test_catches_noise_inflation() {
        // Pure variance degradation (no mean shift) must fire the variance
        // SPRT — the failure mode the mean tests are blind to.
        let healthy = gaussian_resid(2000, 2, 11, 0.1);
        let mut det = Sprt::from_healthy(&healthy, SprtConfig::default());
        let mut rng = Rng::new(12);
        let mut probe = Mat::zeros(600, 2);
        for r in 0..600 {
            // signal 0: 3× σ after t=100 (9× variance); signal 1: healthy
            let s0 = if r >= 100 { 0.3 } else { 0.1 };
            probe[(r, 0)] = s0 * rng.gauss();
            probe[(r, 1)] = 0.1 * rng.gauss();
        }
        let alarms = det.run(&probe);
        let var_alarm = alarms
            .iter()
            .find(|a| a.signal == 0 && a.kind == AlarmKind::Variance)
            .expect("variance degradation not detected");
        assert!(var_alarm.at >= 100 && var_alarm.at < 200, "at={}", var_alarm.at);
        // healthy signal stays quiet
        assert!(alarms.iter().filter(|a| a.signal == 1).count() <= 1);
    }

    #[test]
    fn variance_test_disabled_when_ratio_leq_one() {
        let healthy = gaussian_resid(500, 1, 13, 1.0);
        let mut det = Sprt::from_healthy(
            &healthy,
            SprtConfig {
                var_ratio: 1.0,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(14);
        let mut probe = Mat::zeros(400, 1);
        for r in 0..400 {
            probe[(r, 0)] = 5.0 * rng.gauss(); // huge variance, zero mean
        }
        let alarms = det.run(&probe);
        assert!(
            alarms.iter().all(|a| a.kind != AlarmKind::Variance),
            "variance test should be off"
        );
    }

    #[test]
    fn thresholds_respond_to_alpha() {
        let healthy = gaussian_resid(500, 1, 9, 1.0);
        let strict = Sprt::from_healthy(
            &healthy,
            SprtConfig {
                alpha: 1e-8,
                ..Default::default()
            },
        );
        let lax = Sprt::from_healthy(
            &healthy,
            SprtConfig {
                alpha: 1e-2,
                ..Default::default()
            },
        );
        assert!(strict.h_hi > lax.h_hi);
    }
}
