//! Structured-span observability: per-job flight recorders, trace IDs,
//! and process-level telemetry switches.
//!
//! Each scoping/scenario job owns a [`FlightRecorder`] — a fixed-capacity
//! ring buffer of [`SpanRecord`]s. Instrumentation points across the
//! pipeline (job driver → planner rounds → executor trial tasks →
//! per-trial train/surveil phases → scenario units) push spans into the
//! recorder of the job they belong to; `GET /v1/jobs/{id}/trace` serves
//! the ordered timeline with queue-wait vs. run-time per span.
//!
//! Propagation uses two complementary mechanisms:
//! - a **thread-local current recorder** ([`install`] / [`current`]),
//!   set by the job driver thread for code that runs on that thread
//!   (planner rounds, demand resolution, the job span itself), and
//! - **explicit capture**: dispatch points grab `current()` once and move
//!   the `Arc` into task closures, so spans recorded on executor worker
//!   threads still land in the right job's recorder.
//!
//! When no recorder is installed (plain CLI sweeps, the telemetry-disabled
//! bench twin) every instrumentation point is a thread-local read plus a
//! branch — the overhead budget is enforced by `benches/obs_overhead.rs`
//! (≤ 5% on the native trial hot path).
//!
//! The **ops plane** builds on this substrate:
//! - every span carries a `span_id`/`parent_id` pair and every recorder a
//!   [`TraceContext`], parsed from / emitted as a W3C `traceparent`
//!   header, so traces stitch across the HTTP hop (and, later, across
//!   coordinator → worker processes);
//! - retired spans are fanned out through the process-wide
//!   [`TelemetrySink`] to the `/v1/trace/stream` firehose bus and the
//!   durable [`journal`], both off by default (one relaxed atomic load on
//!   the hot path when disabled);
//! - [`slo`] evaluates burn rates over the metrics this plumbing feeds.

pub mod journal;
pub mod slo;

use crate::util::fnv1a;
use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Default ring capacity per job: enough for every phase of a typical
/// adaptive sweep (hundreds of trials) while bounding memory at
/// `capacity × sizeof(SpanRecord)` regardless of job size.
pub const DEFAULT_SPAN_CAPACITY: usize = 512;

/// One completed span: a named phase of work inside a job, with offsets
/// in microseconds from the owning recorder's epoch (job submission).
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Component that produced the span (`"job"`, `"planner"`, `"trial"`,
    /// `"scenario"`, …).
    pub name: &'static str,
    /// Phase within the component (`"run"`, `"train"`, `"surveil"`,
    /// `"round"`, …).
    pub phase: &'static str,
    /// Span identifier (W3C `parent-id` field width: 64 bits, rendered as
    /// 16 hex digits). 0 means "not assigned" (hand-built test spans).
    pub span_id: u64,
    /// Parent span identifier; 0 means the span is a trace root (no
    /// parent known).
    pub parent_id: u64,
    /// Work start, µs since the recorder epoch (after any queue wait).
    pub start_us: u64,
    /// Work end, µs since the recorder epoch.
    pub end_us: u64,
    /// Time spent queued before work started, µs (0 when the span never
    /// waited in an executor queue).
    pub queue_us: u64,
    /// Free-form context, e.g. `"cell=4/8/32 trial=1"`.
    pub meta: String,
}

impl SpanRecord {
    /// Run time (end − start) in µs.
    pub fn run_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    /// JSON object for the `/trace` endpoints. `parent_id` is `null` for
    /// root spans; ids render as 16-hex strings (the W3C field format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("phase", Json::Str(self.phase.to_string())),
            ("span_id", Json::Str(format!("{:016x}", self.span_id))),
            (
                "parent_id",
                if self.parent_id == 0 {
                    Json::Null
                } else {
                    Json::Str(format!("{:016x}", self.parent_id))
                },
            ),
            ("start_us", Json::Num(self.start_us as f64)),
            ("end_us", Json::Num(self.end_us as f64)),
            ("queue_us", Json::Num(self.queue_us as f64)),
            ("run_us", Json::Num(self.run_us() as f64)),
            ("meta", Json::Str(self.meta.clone())),
        ])
    }
}

/// Propagated trace context: the trace identifier plus the span that any
/// work started under it should report as its parent.
///
/// The HTTP layer builds one from an inbound W3C `traceparent` header
/// (falling back to `x-request-id` with no parent); job submission stamps
/// it on the job's [`FlightRecorder`], whose job-envelope span becomes the
/// child of the caller's span — so a client, the HTTP request span, and
/// every trial span share one stitchable trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceContext {
    /// Trace identifier. A 32-hex-digit W3C trace-id when propagated over
    /// the wire; free-form (e.g. an `x-request-id`) otherwise.
    pub trace_id: String,
    /// Caller's span id (0 = none known).
    pub parent_span: u64,
}

impl TraceContext {
    /// Context with a bare trace id and no parent span.
    pub fn from_id(trace_id: impl Into<String>) -> TraceContext {
        TraceContext {
            trace_id: trace_id.into(),
            parent_span: 0,
        }
    }

    /// Parse a W3C `traceparent` header value
    /// (`00-{32 hex trace-id}-{16 hex parent-id}-{2 hex flags}`).
    /// Returns `None` for unknown versions, malformed fields, or the
    /// all-zero trace/parent ids the spec declares invalid.
    pub fn parse_traceparent(v: &str) -> Option<TraceContext> {
        let mut parts = v.trim().split('-');
        let (version, trace, parent, flags) =
            (parts.next()?, parts.next()?, parts.next()?, parts.next()?);
        if parts.next().is_some() || version != "00" || flags.len() != 2 {
            return None;
        }
        let lower_hex =
            |s: &str| s.chars().all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase());
        if trace.len() != 32 || parent.len() != 16 {
            return None;
        }
        if !lower_hex(trace) || !lower_hex(parent) || !lower_hex(flags) {
            return None;
        }
        if trace.chars().all(|c| c == '0') {
            return None;
        }
        let parent_span = u64::from_str_radix(parent, 16).ok()?;
        if parent_span == 0 {
            return None;
        }
        Some(TraceContext {
            trace_id: trace.to_string(),
            parent_span,
        })
    }

    /// Render a `traceparent` header value for an outbound hop that
    /// continues this trace under `span_id`. Non-hex trace ids (an
    /// `x-request-id` fallback) are hashed to a stable 32-hex form so the
    /// emitted header is always spec-valid.
    pub fn traceparent(&self, span_id: u64) -> String {
        format!("00-{}-{:016x}-01", trace_id_hex32(&self.trace_id), span_id.max(1))
    }
}

/// Normalize a trace id to the 32-lowercase-hex W3C wire form: already
/// conformant ids pass through; anything else is hashed (FNV-1a over the
/// raw id, two rounds) to a stable 32-hex digest.
pub fn trace_id_hex32(id: &str) -> String {
    let ok = id.len() == 32
        && id
            .chars()
            .all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase())
        && !id.chars().all(|c| c == '0');
    if ok {
        return id.to_string();
    }
    let lo = fnv1a(id.as_bytes());
    let hi = fnv1a(&lo.to_le_bytes());
    format!("{hi:016x}{lo:016x}")
}

/// Mint a non-zero 64-bit span id (FNV-1a over wall-clock nanos plus a
/// process-wide sequence; unique within a process).
pub fn mint_span_id() -> u64 {
    static SEQ: AtomicU64 = AtomicU64::new(0x5eed);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_nanos() as u64;
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&nanos.to_le_bytes());
    bytes[8..].copy_from_slice(&seq.to_le_bytes());
    fnv1a(&bytes).max(1)
}

struct Ring {
    spans: VecDeque<SpanRecord>,
    dropped: u64,
}

/// Fixed-capacity per-job span ring buffer ("flight recorder").
///
/// Memory is bounded by construction: once `capacity` spans are held, the
/// oldest span is evicted per push and counted in `dropped`, so the
/// recorder keeps the most recent window of a very long job.
pub struct FlightRecorder {
    epoch: Instant,
    trace_id: String,
    /// Root span id: the job-envelope span recorded by [`push_root`]
    /// carries this id, and every plain [`push`] parents under it.
    ///
    /// [`push_root`]: FlightRecorder::push_root
    /// [`push`]: FlightRecorder::push
    root_span: u64,
    /// Caller's span id from the propagated [`TraceContext`] (0 = none):
    /// the root span's parent.
    external_parent: u64,
    capacity: usize,
    inner: Mutex<Ring>,
}

impl FlightRecorder {
    /// Recorder with the default capacity; `trace_id` is the request's
    /// correlation ID (inbound `traceparent`/`x-request-id` or a minted
    /// one).
    pub fn new(trace_id: impl Into<String>) -> FlightRecorder {
        FlightRecorder::with_capacity(trace_id, DEFAULT_SPAN_CAPACITY)
    }

    /// Recorder continuing a propagated [`TraceContext`]: spans share the
    /// caller's trace id and the root span reports the caller's span as
    /// its parent.
    pub fn from_context(ctx: TraceContext) -> FlightRecorder {
        let mut rec = FlightRecorder::new(ctx.trace_id);
        rec.external_parent = ctx.parent_span;
        rec
    }

    /// Recorder with an explicit ring capacity (min 1).
    pub fn with_capacity(trace_id: impl Into<String>, capacity: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            trace_id: trace_id.into(),
            root_span: mint_span_id(),
            external_parent: 0,
            capacity: capacity.max(1),
            inner: Mutex::new(Ring {
                spans: VecDeque::new(),
                dropped: 0,
            }),
        }
    }

    /// Correlation ID this recorder was created with.
    pub fn trace_id(&self) -> &str {
        &self.trace_id
    }

    /// Root span id (the parent of every plain [`FlightRecorder::push`]).
    pub fn root_span(&self) -> u64 {
        self.root_span
    }

    /// Context for an outbound hop that should parent under this
    /// recorder's root span — render it with [`TraceContext::traceparent`]
    /// to continue the trace in another process.
    pub fn context(&self) -> TraceContext {
        TraceContext {
            trace_id: self.trace_id.clone(),
            parent_span: self.root_span,
        }
    }

    /// Ring capacity (the memory bound, in spans).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Spans evicted so far because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Microseconds between the recorder epoch and `at` (0 if earlier).
    pub fn offset_us(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record a completed span from raw instants. `queue` is the time the
    /// work sat in an executor queue before `start`. The span gets a
    /// fresh id, parented under the recorder's root span; the minted id
    /// is returned for callers that chain children under it.
    pub fn push(
        &self,
        name: &'static str,
        phase: &'static str,
        start: Instant,
        end: Instant,
        queue: Duration,
        meta: String,
    ) -> u64 {
        self.push_under(self.root_span, name, phase, start, end, queue, meta)
    }

    /// [`FlightRecorder::push`] with an explicit parent span id (e.g. a
    /// planner-round span parenting the trials it dispatched).
    #[allow(clippy::too_many_arguments)]
    pub fn push_under(
        &self,
        parent_id: u64,
        name: &'static str,
        phase: &'static str,
        start: Instant,
        end: Instant,
        queue: Duration,
        meta: String,
    ) -> u64 {
        let span_id = mint_span_id();
        self.record(SpanRecord {
            name,
            phase,
            span_id,
            parent_id,
            start_us: self.offset_us(start),
            end_us: self.offset_us(end),
            queue_us: queue.as_micros() as u64,
            meta,
        });
        span_id
    }

    /// Record the trace-root envelope span (the job's `run` span): it
    /// carries the recorder's root span id and parents under the
    /// propagated caller span, if any — the joint that stitches a job's
    /// timeline under the submitting request's trace.
    pub fn push_root(
        &self,
        name: &'static str,
        phase: &'static str,
        start: Instant,
        end: Instant,
        queue: Duration,
        meta: String,
    ) -> u64 {
        self.record(SpanRecord {
            name,
            phase,
            span_id: self.root_span,
            parent_id: self.external_parent,
            start_us: self.offset_us(start),
            end_us: self.offset_us(end),
            queue_us: queue.as_micros() as u64,
            meta,
        });
        self.root_span
    }

    /// Record a pre-built span, evicting the oldest entry when full.
    /// Retired spans are also fanned out through the process-wide
    /// [`TelemetrySink`] (firehose stream + journal) when enabled.
    pub fn record(&self, span: SpanRecord) {
        sink().retire(&self.trace_id, &span);
        let mut ring = self.inner.lock().unwrap();
        if ring.spans.len() >= self.capacity {
            ring.spans.pop_front();
            ring.dropped += 1;
        }
        ring.spans.push_back(span);
    }

    /// Spans ordered by start offset (stable for equal starts).
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut v: Vec<SpanRecord> = self.inner.lock().unwrap().spans.iter().cloned().collect();
        v.sort_by_key(|s| s.start_us);
        v
    }

    /// Full timeline as JSON for the `/trace` endpoints.
    pub fn to_json(&self) -> Json {
        let spans = self.snapshot();
        Json::obj(vec![
            ("trace_id", Json::Str(self.trace_id.clone())),
            ("capacity", Json::Num(self.capacity as f64)),
            (
                "dropped",
                Json::Num(self.inner.lock().unwrap().dropped as f64),
            ),
            (
                "spans",
                Json::Arr(spans.iter().map(SpanRecord::to_json).collect()),
            ),
        ])
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<FlightRecorder>>> = const { RefCell::new(None) };
}

/// Recorder installed on this thread, if any (cheap: a thread-local read).
pub fn current() -> Option<Arc<FlightRecorder>> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Install `rec` as this thread's current recorder for the guard's
/// lifetime; the previous recorder (usually `None`) is restored on drop,
/// including on unwind.
pub fn install(rec: Option<Arc<FlightRecorder>>) -> CurrentGuard {
    let prev = CURRENT.with(|c| c.replace(rec));
    CurrentGuard { prev }
}

/// RAII guard returned by [`install`]; restores the previous recorder.
pub struct CurrentGuard {
    prev: Option<Arc<FlightRecorder>>,
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

/// Mint a 16-hex-digit trace ID: FNV-1a over wall-clock nanos and a
/// process-wide sequence number (unique within a process, collision-safe
/// enough across restarts for log correlation).
pub fn mint_trace_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_nanos() as u64;
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&nanos.to_le_bytes());
    bytes[8..].copy_from_slice(&seq.to_le_bytes());
    format!("{:016x}", fnv1a(&bytes))
}

/// Default bounded event history retained per [`EventBus`] for replay to
/// late subscribers.
pub const DEFAULT_EVENT_HISTORY: usize = 256;

/// One published progress event: a pre-serialised compact JSON object (one
/// NDJSON line, newline excluded) plus its per-bus sequence number.
#[derive(Clone, Debug)]
pub struct BusEvent {
    /// Monotone per-bus sequence number, starting at 0.
    pub seq: u64,
    /// Compact JSON object text.
    pub line: Arc<str>,
}

#[derive(Debug)]
struct BusInner {
    history: VecDeque<BusEvent>,
    subscribers: Vec<mpsc::Sender<BusEvent>>,
    next_seq: u64,
    dropped: u64,
    closed: bool,
}

/// Per-job progress event bus feeding the `/events` streaming endpoints.
///
/// Publishers (planner cell retirements, exhaustive-sweep retirements,
/// scenario units, the job driver's terminal summary) push serialised JSON
/// lines; each subscriber gets a bounded history replay plus a live
/// channel. Memory is bounded: the history ring keeps the most recent
/// [`DEFAULT_EVENT_HISTORY`] events (older ones are counted in
/// `dropped`), and a subscriber that goes away is pruned on the next
/// publish. After [`EventBus::close`] the live channels disconnect and
/// late subscribers see history only — which always includes the terminal
/// event, since it is published last.
#[derive(Debug)]
pub struct EventBus {
    capacity: usize,
    inner: Mutex<BusInner>,
}

impl Default for EventBus {
    fn default() -> Self {
        EventBus::new()
    }
}

impl EventBus {
    /// Bus with the default history capacity.
    pub fn new() -> EventBus {
        EventBus::with_capacity(DEFAULT_EVENT_HISTORY)
    }

    /// Bus with an explicit history capacity (min 1).
    pub fn with_capacity(capacity: usize) -> EventBus {
        EventBus {
            capacity: capacity.max(1),
            inner: Mutex::new(BusInner {
                history: VecDeque::new(),
                subscribers: Vec::new(),
                next_seq: 0,
                dropped: 0,
                closed: false,
            }),
        }
    }

    /// Publish one pre-serialised event line (ignored after close).
    pub fn publish(&self, line: String) {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return;
        }
        let ev = BusEvent {
            seq: inner.next_seq,
            line: Arc::from(line.as_str()),
        };
        inner.next_seq += 1;
        if inner.history.len() >= self.capacity {
            inner.history.pop_front();
            inner.dropped += 1;
        }
        inner.history.push_back(ev.clone());
        inner.subscribers.retain(|tx| tx.send(ev.clone()).is_ok());
    }

    /// Publish a JSON object as a compact event line.
    pub fn publish_json(&self, v: &Json) {
        self.publish(v.to_string());
    }

    /// Close the bus: live subscriber channels disconnect (after draining
    /// already-sent events) and further publishes are ignored.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        inner.subscribers.clear();
    }

    /// Whether [`EventBus::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Events evicted from the history ring so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Subscribe: returns the retained history for replay and, while the
    /// bus is open, a live receiver for subsequent events. `None` means
    /// the bus already closed and the history is complete.
    pub fn subscribe(&self) -> (Vec<BusEvent>, Option<mpsc::Receiver<BusEvent>>) {
        let mut inner = self.inner.lock().unwrap();
        let replay: Vec<BusEvent> = inner.history.iter().cloned().collect();
        if inner.closed {
            return (replay, None);
        }
        let (tx, rx) = mpsc::channel();
        inner.subscribers.push(tx);
        (replay, Some(rx))
    }
}

/// Process-wide fan-out for retired spans: a bounded firehose
/// [`EventBus`] feeding `GET /v1/trace/stream` (replay-then-follow) and
/// an optional durable [`journal::Journal`].
///
/// Both outputs are **off by default**: with neither enabled,
/// [`FlightRecorder::record`] pays two relaxed atomic loads and returns —
/// the obs-overhead bench gate (≤ 5%) covers the enabled paths
/// separately. The sink is a process singleton ([`sink`]) because span
/// retirement happens deep in executor workers that know nothing about
/// the service instance.
pub struct TelemetrySink {
    stream_on: AtomicBool,
    journal_on: AtomicBool,
    bus: EventBus,
    journal: Mutex<Option<Arc<journal::Journal>>>,
}

impl TelemetrySink {
    fn new() -> TelemetrySink {
        TelemetrySink {
            stream_on: AtomicBool::new(false),
            journal_on: AtomicBool::new(false),
            bus: EventBus::new(),
            journal: Mutex::new(None),
        }
    }

    /// Turn the span firehose bus on/off (the service enables it at
    /// startup; benches and plain CLI runs leave it off).
    pub fn enable_stream(&self, on: bool) {
        self.stream_on.store(on, Ordering::Relaxed);
    }

    /// Whether the firehose bus is currently fed.
    pub fn stream_enabled(&self) -> bool {
        self.stream_on.load(Ordering::Relaxed)
    }

    /// The span firehose bus: subscribe for a bounded replay of recent
    /// spans plus live follow. Never closed — streams end only when the
    /// client disconnects.
    pub fn span_bus(&self) -> &EventBus {
        &self.bus
    }

    /// Install (or remove, with `None`) the durable journal every retired
    /// span and periodic snapshot is appended to.
    pub fn set_journal(&self, j: Option<Arc<journal::Journal>>) {
        let mut slot = self.journal.lock().unwrap();
        self.journal_on.store(j.is_some(), Ordering::Relaxed);
        *slot = j;
    }

    /// Currently installed journal, if any.
    pub fn journal(&self) -> Option<Arc<journal::Journal>> {
        self.journal.lock().unwrap().clone()
    }

    /// Append a non-span record (`kind: "metrics"` / `"slo"` snapshots
    /// from the ops tick thread) to the journal only.
    pub fn journal_event(&self, frame: &Json) {
        if !self.journal_on.load(Ordering::Relaxed) {
            return;
        }
        if let Some(j) = self.journal() {
            j.append(frame);
        }
    }

    /// Fan a retired span out to the enabled outputs. The frame is the
    /// span's `/trace` JSON plus `kind`, `ts_ms` (wall clock at
    /// retirement) and `trace_id` — self-describing, so journal readers
    /// and stream consumers need no side channel.
    fn retire(&self, trace_id: &str, span: &SpanRecord) {
        let stream = self.stream_on.load(Ordering::Relaxed);
        let journal_on = self.journal_on.load(Ordering::Relaxed);
        if !stream && !journal_on {
            return;
        }
        let Json::Obj(mut fields) = span.to_json() else {
            return;
        };
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default()
            .as_millis() as u64;
        fields.insert("kind".to_string(), Json::Str("span".to_string()));
        fields.insert("ts_ms".to_string(), Json::Num(ts_ms as f64));
        fields.insert("trace_id".to_string(), Json::Str(trace_id.to_string()));
        let frame = Json::Obj(fields);
        if stream {
            self.bus.publish_json(&frame);
        }
        if journal_on {
            if let Some(j) = self.journal() {
                j.append(&frame);
            }
        }
    }
}

/// The process-wide telemetry sink.
pub fn sink() -> &'static TelemetrySink {
    static SINK: OnceLock<TelemetrySink> = OnceLock::new();
    SINK.get_or_init(TelemetrySink::new)
}

static ACCESS_LOG: AtomicBool = AtomicBool::new(false);

/// Turn HTTP access logging on/off (`containerstress serve --access-log`).
pub fn set_access_log(on: bool) {
    ACCESS_LOG.store(on, Ordering::Relaxed);
}

/// Whether per-request HTTP access-log lines are emitted.
pub fn access_log_enabled() -> bool {
    ACCESS_LOG.load(Ordering::Relaxed)
}

static START: OnceLock<Instant> = OnceLock::new();

/// Anchor the process-start instant (first caller wins; `logger::init`
/// calls this at boot so `/healthz` uptime covers the whole process).
pub fn touch_process_start() {
    START.get_or_init(Instant::now);
}

/// Seconds since the process-start anchor.
pub fn uptime_s() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_and_orders_spans() {
        let rec = FlightRecorder::with_capacity("t-1", 4);
        let t0 = Instant::now();
        for i in 0..6u64 {
            rec.record(SpanRecord {
                name: "trial",
                phase: "train",
                span_id: mint_span_id(),
                parent_id: 0,
                start_us: 100 - i * 10, // reversed starts: snapshot must sort
                end_us: 200,
                queue_us: i,
                meta: format!("i={i}"),
            });
        }
        assert_eq!(rec.capacity(), 4);
        assert_eq!(rec.dropped(), 2);
        let spans = rec.snapshot();
        assert_eq!(spans.len(), 4);
        assert!(spans.windows(2).all(|w| w[0].start_us <= w[1].start_us));
        assert!(rec.offset_us(t0) < 1_000_000);
        let j = rec.to_json();
        assert_eq!(j.get("trace_id").and_then(Json::as_str), Some("t-1"));
        assert_eq!(j.get("spans").and_then(Json::as_arr).unwrap().len(), 4);
    }

    #[test]
    fn install_guard_restores_previous() {
        assert!(current().is_none());
        let rec = Arc::new(FlightRecorder::new("outer"));
        {
            let _g = install(Some(rec.clone()));
            assert_eq!(current().unwrap().trace_id(), "outer");
            {
                let inner = Arc::new(FlightRecorder::new("inner"));
                let _g2 = install(Some(inner));
                assert_eq!(current().unwrap().trace_id(), "inner");
            }
            assert_eq!(current().unwrap().trace_id(), "outer");
        }
        assert!(current().is_none());
    }

    #[test]
    fn trace_ids_are_distinct_hex() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn event_bus_replays_then_streams_live() {
        let bus = EventBus::new();
        bus.publish("{\"seq\":\"a\"}".to_string());
        let (replay, rx) = bus.subscribe();
        let rx = rx.expect("bus open");
        assert_eq!(replay.len(), 1);
        assert_eq!(&*replay[0].line, "{\"seq\":\"a\"}");
        bus.publish("{\"seq\":\"b\"}".to_string());
        let live = rx.recv().unwrap();
        assert_eq!(live.seq, 1);
        assert_eq!(&*live.line, "{\"seq\":\"b\"}");
        bus.publish("terminal".to_string());
        bus.close();
        // Already-sent events drain; then the channel disconnects.
        assert_eq!(&*rx.recv().unwrap().line, "terminal");
        assert!(rx.recv().is_err());
        // Late subscriber: history only, terminal event included.
        let (replay, rx) = bus.subscribe();
        assert!(rx.is_none());
        assert_eq!(&*replay.last().unwrap().line, "terminal");
    }

    #[test]
    fn event_bus_history_is_bounded() {
        let bus = EventBus::with_capacity(2);
        for i in 0..5 {
            bus.publish(format!("e{i}"));
        }
        assert_eq!(bus.dropped(), 3);
        let (replay, _rx) = bus.subscribe();
        assert_eq!(
            replay.iter().map(|e| e.line.to_string()).collect::<Vec<_>>(),
            vec!["e3", "e4"]
        );
        assert_eq!(replay[0].seq, 3);
    }

    #[test]
    fn traceparent_roundtrip_and_rejection() {
        let ctx = TraceContext::parse_traceparent(
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01",
        )
        .expect("valid header parses");
        assert_eq!(ctx.trace_id, "0af7651916cd43dd8448eb211c80319c");
        assert_eq!(ctx.parent_span, 0xb7ad6b7169203331);
        // re-emission preserves the trace id and carries the new span
        let out = ctx.traceparent(0x1234);
        assert_eq!(
            out,
            "00-0af7651916cd43dd8448eb211c80319c-0000000000001234-01"
        );
        assert_eq!(TraceContext::parse_traceparent(&out).unwrap().trace_id, ctx.trace_id);
        for bad in [
            "",
            "01-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331-01", // version
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b7169203331",    // missing flags
            "00-00000000000000000000000000000000-b7ad6b7169203331-01", // zero trace
            "00-0af7651916cd43dd8448eb211c80319c-0000000000000000-01", // zero parent
            "00-0AF7651916CD43DD8448EB211C80319C-b7ad6b7169203331-01", // uppercase
            "00-0af7651916cd43dd8448eb211c8031-b7ad6b7169203331-01",   // short trace
            "00-0af7651916cd43dd8448eb211c80319c-b7ad6b71692033-01",   // short parent
        ] {
            assert!(TraceContext::parse_traceparent(bad).is_none(), "{bad:?}");
        }
        // non-hex fallback ids are hashed into a stable wire form
        let fallback = TraceContext::from_id("req-abc123");
        let tp = fallback.traceparent(7);
        let parsed = TraceContext::parse_traceparent(&tp).unwrap();
        assert_eq!(parsed.trace_id, trace_id_hex32("req-abc123"));
        assert_eq!(trace_id_hex32("req-abc123"), trace_id_hex32("req-abc123"));
    }

    #[test]
    fn spans_parent_under_root_and_root_under_caller() {
        let rec = FlightRecorder::from_context(TraceContext {
            trace_id: "0af7651916cd43dd8448eb211c80319c".into(),
            parent_span: 0xfeed,
        });
        let t0 = Instant::now();
        let child = rec.push("trial", "train", t0, t0, Duration::ZERO, String::new());
        let root = rec.push_root("job", "run", t0, t0, Duration::ZERO, String::new());
        assert_eq!(root, rec.root_span());
        assert_ne!(child, root);
        let spans = rec.snapshot();
        let trial = spans.iter().find(|s| s.name == "trial").unwrap();
        let job = spans.iter().find(|s| s.name == "job").unwrap();
        assert_eq!(trial.parent_id, job.span_id, "trial is the job span's child");
        assert_eq!(job.parent_id, 0xfeed, "job parents under the caller's span");
        // outbound context continues the chain under the root span
        let ctx = rec.context();
        assert_eq!(ctx.parent_span, root);
        let tp = ctx.traceparent(ctx.parent_span);
        assert!(tp.starts_with("00-0af7651916cd43dd8448eb211c80319c-"));
    }

    #[test]
    fn sink_fans_retired_spans_to_stream() {
        let rec = FlightRecorder::new("sink-test-trace");
        let t0 = Instant::now();
        let mine = |replay: &[BusEvent]| -> Vec<Json> {
            replay
                .iter()
                .filter_map(|e| Json::parse(&e.line).ok())
                .filter(|j| j.get("trace_id").and_then(Json::as_str) == Some("sink-test-trace"))
                .collect()
        };
        // disabled by default: recording does not publish
        rec.push("trial", "train", t0, t0, Duration::ZERO, "off".into());
        assert!(mine(&sink().span_bus().subscribe().0).is_empty());
        sink().enable_stream(true);
        rec.push("trial", "surveil", t0, t0, Duration::ZERO, "on".into());
        sink().enable_stream(false);
        let (replay, _rx) = sink().span_bus().subscribe();
        let mine: Vec<Json> = mine(&replay);
        assert_eq!(mine.len(), 1, "only the enabled-window span is published");
        let frame = &mine[0];
        assert_eq!(frame.get("kind").and_then(Json::as_str), Some("span"));
        assert_eq!(frame.get("phase").and_then(Json::as_str), Some("surveil"));
        assert!(frame.get("ts_ms").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
        assert!(frame.get("span_id").and_then(Json::as_str).is_some());
    }

    #[test]
    fn span_run_time_and_queue_wait() {
        let rec = FlightRecorder::new("t");
        let start = Instant::now();
        let end = start + Duration::from_millis(3);
        rec.push(
            "trial",
            "surveil",
            start,
            end,
            Duration::from_millis(7),
            String::new(),
        );
        let s = &rec.snapshot()[0];
        assert_eq!(s.queue_us, 7_000);
        assert!((2_000..=4_000).contains(&s.run_us()), "run {}", s.run_us());
    }
}
