//! Observability substrate properties: the metrics [`Registry`] under
//! concurrent hammering, [`Histogram`] merge/quantile contracts over
//! generated distributions, and the fixed-memory bounds that let a
//! long-lived `serve` process record telemetry forever.

use containerstress::metrics::{Histogram, Registry};
use containerstress::obs::FlightRecorder;

/// Deterministic LCG (no rand crate offline) → uniform f64 in (0, 1].
struct Lcg(u64);

impl Lcg {
    fn next_f64(&mut self) -> f64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((self.0 >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }
}

/// Empirical quantile of a sorted sample, matching the histogram's
/// rank convention (`ceil(q·n)`, 1-based).
fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank - 1]
}

#[test]
fn registry_survives_concurrent_hammering_with_exact_totals() {
    let r = Registry::new();
    const THREADS: usize = 8;
    const OPS: usize = 10_000;
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let r = &r;
            s.spawn(move || {
                for i in 0..OPS {
                    r.inc("ops");
                    r.add("bulk", 2);
                    r.sample("lat", (1 + (i % 997)) as f64 * 1e-6);
                    r.set_gauge("depth", t as f64);
                }
            });
        }
    });
    assert_eq!(r.counter("ops"), (THREADS * OPS) as u64);
    assert_eq!(r.counter("bulk"), 2 * (THREADS * OPS) as u64);
    let h = r.histogram("lat").expect("samples recorded");
    assert_eq!(h.count(), (THREADS * OPS) as u64, "no sample may be lost");
    let g = r.gauge("depth").expect("gauge set");
    assert!(
        (0.0..THREADS as f64).contains(&g),
        "last write came from a thread"
    );
    // The exposition formats must stay coherent mid/after contention.
    let prom = r.render_prometheus();
    assert!(prom.contains("ops_total 80000"));
    assert!(prom.contains("lat_count 80000"));
}

#[test]
fn registry_memory_is_bounded_under_sustained_sampling() {
    let r = Registry::new();
    // A long-lived service records HTTP latencies forever; the histogram
    // layout must stay at its fixed slot count no matter the volume.
    let mut rng = Lcg(7);
    for _ in 0..200_000 {
        r.sample("service.http.request_seconds", rng.next_f64() * 10.0);
    }
    let h = r.histogram("service.http.request_seconds").unwrap();
    assert_eq!(h.count(), 200_000);
    // Non-empty buckets can never exceed the fixed layout, and the
    // cumulative series the Prometheus renderer walks is bounded too.
    assert!(h.cumulative_buckets().len() <= Histogram::BUCKETS);
    // A clone (what `Registry::histogram` hands out) costs the same fixed
    // layout — merging snapshots cannot grow it either.
    let mut merged = Histogram::new();
    for _ in 0..16 {
        merged.merge(&h);
    }
    assert_eq!(merged.count(), 16 * 200_000);
    assert!(merged.cumulative_buckets().len() <= Histogram::BUCKETS);
}

#[test]
fn histogram_merge_equals_combined_recording_across_shards() {
    // Property: recording a stream into S shards and merging is
    // indistinguishable (counts, sums, quantiles) from one histogram.
    let mut rng = Lcg(42);
    let mut shards: Vec<Histogram> = (0..5).map(|_| Histogram::new()).collect();
    let mut combined = Histogram::new();
    let mut values = Vec::new();
    for i in 0..50_000 {
        // Log-uniform across ~9 decades: exercises many octaves.
        let v = 1e-8 * (10f64).powf(rng.next_f64() * 9.0);
        shards[i % 5].record(v);
        combined.record(v);
        values.push(v);
    }
    let mut merged = Histogram::new();
    for s in &shards {
        merged.merge(s);
    }
    assert_eq!(merged.count(), combined.count());
    assert!((merged.sum() - combined.sum()).abs() <= 1e-9 * combined.sum());
    assert_eq!(merged.min(), combined.min());
    assert_eq!(merged.max(), combined.max());
    for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
        assert_eq!(merged.quantile(q), combined.quantile(q), "q={q}");
    }
    // And both honour the documented ≤5% bound against the raw sample.
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [0.1, 0.5, 0.9] {
        let exact = exact_quantile(&values, q);
        let got = merged.quantile(q).unwrap();
        let rel = (got - exact).abs() / exact;
        assert!(rel <= 0.05, "q={q}: got {got:e}, exact {exact:e}, rel {rel}");
    }
}

#[test]
fn quantile_error_bound_holds_across_distributions() {
    // Uniform, heavy-tailed (u²), and microsecond-scale latency shapes.
    let shapes: [(&str, fn(f64) -> f64); 3] = [
        ("uniform", |u| u),
        ("heavy-tail", |u| u * u * 100.0),
        ("micro-latency", |u| 1e-6 * (1.0 + 50.0 * u)),
    ];
    for (label, f) in shapes {
        let mut rng = Lcg(1234);
        let mut h = Histogram::new();
        let mut values = Vec::new();
        for _ in 0..20_000 {
            let v = f(rng.next_f64());
            h.record(v);
            values.push(v);
        }
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.05, 0.25, 0.5, 0.75, 0.95, 0.99] {
            let exact = exact_quantile(&values, q);
            let got = h.quantile(q).unwrap();
            let rel = (got - exact).abs() / exact;
            assert!(
                rel <= 0.05,
                "{label} q={q}: got {got:e}, exact {exact:e}, rel {rel}"
            );
        }
    }
}

#[test]
fn flight_recorder_ring_is_bounded_under_sustained_load() {
    use std::time::{Duration, Instant};
    let rec = FlightRecorder::with_capacity("load", 256);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let rec = &rec;
            s.spawn(move || {
                for i in 0..5_000u64 {
                    rec.push(
                        "trial",
                        "train",
                        t0 + Duration::from_micros(i),
                        t0 + Duration::from_micros(i + 5),
                        Duration::ZERO,
                        String::new(),
                    );
                }
            });
        }
    });
    // 20 000 pushes through a 256-slot ring: bounded, nothing unaccounted.
    let spans = rec.snapshot();
    assert_eq!(spans.len(), 256, "ring must hold exactly its capacity");
    assert_eq!(rec.dropped(), 20_000 - 256);
    assert!(spans.windows(2).all(|w| w[0].start_us <= w[1].start_us));
}
