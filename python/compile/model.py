"""L2: MSET2 compute graphs in JAX, calling the L1 Pallas kernels.

Three graphs are AOT-lowered per (n, m) bucket by ``aot.py``:

- ``mset2_train``   — similarity matrix + regularised inverse (the paper's
  training phase; GPU version used the CUDA similarity kernel + cuSOLVER).
- ``mset2_surveil`` — similarity + fused weight/estimate/residual step (the
  paper's streaming surveillance phase).
- ``aakr_surveil``  — the AAKR pluggable alternative.

Bucket padding contract (DESIGN.md §2.3): callers may zero-pad the signal
dimension to the bucket's ``n`` and the memory dimension to ``m``; the
``mask`` input is 1.0 for real memory vectors and 0.0 for padding, and
``bw`` carries γ·√n_real so bandwidth reflects the *unpadded* signal
count. Padded memory rows are replaced by identity rows in S, making
(S+λI)⁻¹ block-diagonal: padding can never leak into real estimates.

The SPD inverse is computed **in-graph** with Newton–Schulz iteration —
pure matmuls on the MXU — instead of calling out to LAPACK/cuSOLVER: the
CPU PJRT runtime used by the Rust coordinator (xla_extension 0.5.1)
predates jax's FFI custom-call ABI, so ``jnp.linalg.eigh``'s lapack
custom-calls cannot execute there, and a matmul-only inverse is the
natural TPU formulation anyway (DESIGN.md §7). Convergence: S is PD
(reciprocal-Euclidean kernels are completely monotone ⇒ PD), so
λ_min(S+λI) ≥ λ = 1e-3; with X₀ = I/max-row-sum the error contracts as
e_{k+1} = e_k² from e₀ ≤ 1 − λ/m, giving < 1e-6 residual within 30
iterations for every shipped bucket (verified by ``tests/test_model.py``).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.estimate import estimate_pallas
from .kernels.similarity import sim_pallas

NS_ITERS = ref.NS_ITERS
RIDGE_REL = ref.RIDGE_REL


def ns_inverse(a, iters=NS_ITERS):
    """Newton–Schulz inverse of an SPD matrix, matmuls only.

    X₀ = I / ‖A‖_∞ (row-sum bound ⇒ ‖I − X₀A‖₂ < 1),
    X_{k+1} = X_k (2I − A X_k).
    """
    m = a.shape[0]
    eye = jnp.eye(m, dtype=a.dtype)
    scale = 1.0 / jnp.max(jnp.sum(jnp.abs(a), axis=1))
    x0 = scale * eye

    def body(_, x):
        return x @ (2.0 * eye - a @ x)

    return jax.lax.fori_loop(0, iters, body, x0)


#: Refinement iterations of the mixed-precision inverse (EXPERIMENTS.md
#: §Perf): the f32 phase converges to its ~eps32·cond fixed point (≤3e-2
#: at the worst shipped conditioning), after which quadratic convergence
#: needs 3 f64 steps to pass 1e-7.
NS_REFINE_ITERS = 3


def ns_inverse_mixed(a32, coarse_iters=NS_ITERS, refine_iters=NS_REFINE_ITERS):
    """Mixed-precision Newton–Schulz: bulk iterations in f32 (half the
    matmul cost on CPU, and the MXU-native dtype on TPU), then a short f64
    refinement that restores full accuracy (quadratic convergence from the
    f32 fixed point). ≈2× cheaper than the all-f64 variant at equal final
    residual — the §Perf optimisation of the training graph.
    """
    x32 = ns_inverse(a32.astype(jnp.float32), coarse_iters)
    a64 = a32.astype(jnp.float64)
    m = a64.shape[0]
    eye = jnp.eye(m, dtype=jnp.float64)

    def body(_, x):
        return x @ (2.0 * eye - a64 @ x)

    return jax.lax.fori_loop(0, refine_iters, body, x32.astype(jnp.float64))


def mset2_train(d, mask, bw):
    """Training graph: memory matrix → regularised similarity inverse.

    d: (m, n) scaled memory matrix (padded rows zero)
    mask: (m,) 1.0 = real row, 0.0 = padding
    bw: (1,) bandwidth γ·√n_real
    returns (G,) with G = (S_masked + λI)⁻¹, (m, m)
    """
    m = d.shape[0]
    s_raw = sim_pallas(d, d, bw)
    outer = mask[:, None] * mask[None, :]
    # Pin the diagonal to exactly 1 (Gram-trick f32 rounding would leave
    # ~1e-3 noise there — same order as λ); padded rows become identity rows.
    s = s_raw * outer
    s = s - jnp.diag(jnp.diagonal(s)) + jnp.eye(m, dtype=s.dtype)
    # Mixed-precision inverse (EXPERIMENTS.md §Perf): f32 bulk iterations +
    # f64 refinement reach the same final residual as the all-f64 variant
    # (the paper's f64 cuSOLVER analogue) at ≈half the matmul cost.
    a = s + RIDGE_REL * jnp.eye(m, dtype=s.dtype)
    g = ns_inverse_mixed(a).astype(jnp.float32)
    return (g,)


def mset2_surveil(d, g, mask, bw, x):
    """Surveillance graph: estimate + residual for one observation chunk.

    d: (m, n), g: (m, m), mask: (m,), bw: (1,), x: (B, n) scaled chunk
    returns (xhat, resid) both (B, n)
    """
    k = sim_pallas(d, x, bw) * mask[:, None]
    xhat, resid = estimate_pallas(g, k, d, x)
    return xhat, resid


def aakr_surveil(d, mask, bw, x):
    """AAKR pluggable alternative: similarity-weighted memory average."""
    k = sim_pallas(d, x, bw) * mask[:, None]
    wsum = jnp.maximum(jnp.sum(k, axis=0, keepdims=True), 1e-12)
    w = k / wsum
    xhat = w.T @ d
    return xhat, x - xhat


# ---------------------------------------------------------------------------
# Reference (pure-jnp) variants for pytest — identical maths, no Pallas.
# ---------------------------------------------------------------------------


def mset2_train_ref(d, mask, bw):
    s = ref.masked_similarity(d, mask, bw)
    a = s + RIDGE_REL * jnp.eye(d.shape[0], dtype=s.dtype)
    return (ns_inverse(a),)


def mset2_surveil_ref(d, g, mask, bw, x):
    k = ref.sim_cross(d, x, bw) * mask[:, None]
    return ref.estimate(g, k, d, x)


def aakr_surveil_ref(d, mask, bw, x):
    k = ref.sim_cross(d, x, bw) * mask[:, None]
    return ref.aakr_estimate(k, d, x)
