//! Configuration system for the launcher.
//!
//! JSON config files (own parser — no serde offline) with CLI-flag
//! overrides, profile presets, and validation. Every `containerstress`
//! subcommand builds its effective configuration through here, so runs are
//! reproducible from a single file.

use crate::coordinator::SweepSpec;
use crate::obs::journal::FsyncPolicy;
use crate::obs::slo::{SloObjective, SloSettings};
use crate::scenario::ScenarioSpec;
use crate::util::cli::Args;
use crate::util::json::Json;
use std::path::PathBuf;

/// Effective run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Directory holding the AOT artifact bundle (device backend).
    pub artifact_dir: PathBuf,
    /// Directory reports and figures are written to.
    pub output_dir: PathBuf,
    /// Execution backend: "device" | "native".
    pub backend: String,
    /// Kernel compute tier for the native hot path: "scalar" | "simd" |
    /// "auto" (see `linalg::simd`). `None` inherits the
    /// `CONTAINERSTRESS_KERNEL` env knob, defaulting to the bit-exact
    /// scalar tier.
    pub kernel_backend: Option<String>,
    /// Sweep grid, trial budget, and adaptive-planner knobs.
    pub sweep: SweepSpec,
    /// `containerstress serve` settings.
    pub service: ServiceConfig,
    /// Fleet scenario for `containerstress simulate` — from the config
    /// file's `"scenario"` object or a `--scenario file.json` flag;
    /// `None` makes `simulate` fall back to the built-in demo scenario.
    pub scenario: Option<ScenarioSpec>,
    /// Deterministic fault injection: comma-separated
    /// `point:rate:kind[:seed]` failpoint specs (see
    /// [`crate::util::failpoint`]), from the config file's `"chaos"` key
    /// or `--chaos`. The `CONTAINERSTRESS_CHAOS` env var takes
    /// precedence when set. `None` leaves every failpoint disarmed
    /// (the production default: one relaxed atomic load per hook).
    pub chaos: Option<String>,
}

/// `containerstress serve` settings.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Bind host (loopback by default — front with a proxy to expose).
    pub host: String,
    /// Bind port; 0 picks an ephemeral port (tests).
    pub port: u16,
    /// Max queued+running scope jobs before submits are rejected.
    pub queue_cap: usize,
    /// Sweep-cache spill directory; `None` keeps the cache memory-only.
    pub cache_dir: Option<PathBuf>,
    /// Worker threads in the shared trial executor all jobs' `(cell,
    /// trial)` tasks run on (0 = machine parallelism).
    pub executor_workers: usize,
    /// Weighted fair interleaving across concurrent jobs (default). Off =
    /// strict job-arrival FIFO, the old single-leader discipline, kept for
    /// A/B comparisons.
    pub fair_share: bool,
    /// Emit one HTTP access-log line per request (method, path, status,
    /// latency, request ID) on the `http.access` log target.
    pub access_log: bool,
    /// HTTP/1.1 keep-alive: serve multiple (pipelined) requests per
    /// connection. Off reverts to the one-shot `Connection: close` model.
    pub keep_alive: bool,
    /// Requests served on one connection before the server closes it
    /// (bounds per-connection resource lifetime under keep-alive).
    pub keep_alive_max_requests: usize,
    /// Heartbeat cadence (ms) on idle `/events` streams, keeping slow
    /// jobs distinguishable from dead connections.
    pub stream_heartbeat_ms: u64,
    /// SLO objectives + burn-rate windows (`service.slo` / `--slo`); no
    /// objectives = engine disabled.
    pub slo: SloSettings,
    /// Telemetry-journal directory; `None` disables the journal.
    pub journal_dir: Option<PathBuf>,
    /// Journal file rotation threshold, bytes.
    pub journal_max_file_bytes: u64,
    /// Journal whole-directory disk cap, bytes (oldest files deleted).
    pub journal_max_total_bytes: u64,
    /// Journal durability policy (`never` | `rotate` | `always`).
    pub journal_fsync: FsyncPolicy,
    /// Cadence (ms) of periodic metric/SLO snapshot frames written to
    /// the journal.
    pub journal_snapshot_ms: u64,
    /// Job write-ahead-log directory; `None` disables durable job
    /// recovery. Submitted job specs are journalled (fsync-always)
    /// before they run, so a crashed server can replay unfinished jobs
    /// on restart with `--resume`.
    pub wal_dir: Option<PathBuf>,
    /// Replay unfinished WAL jobs at startup (requires `wal_dir`).
    pub resume: bool,
    /// Graceful-shutdown budget (ms): on SIGTERM the server stops
    /// accepting connections and waits up to this long for in-flight
    /// jobs before exiting (jobs still running stay pending in the WAL
    /// and are replayed by the next `--resume` start).
    pub drain_deadline_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            host: "127.0.0.1".into(),
            port: 8080,
            queue_cap: 64,
            cache_dir: Some(PathBuf::from("results/sweep_cache")),
            executor_workers: 0,
            fair_share: true,
            access_log: false,
            keep_alive: true,
            keep_alive_max_requests: 1024,
            stream_heartbeat_ms: 1000,
            slo: SloSettings::default(),
            journal_dir: None,
            journal_max_file_bytes: crate::obs::journal::DEFAULT_MAX_FILE_BYTES,
            journal_max_total_bytes: crate::obs::journal::DEFAULT_MAX_TOTAL_BYTES,
            journal_fsync: FsyncPolicy::Never,
            journal_snapshot_ms: 5000,
            wal_dir: None,
            resume: false,
            drain_deadline_ms: 5000,
        }
    }
}

/// Strict: every element must be a non-negative integer — silently
/// dropping bad entries would run a different grid than requested.
fn usize_list(j: &Json) -> Option<Vec<usize>> {
    let arr = j.as_arr()?;
    let v: Vec<usize> = arr.iter().filter_map(Json::as_usize).collect();
    (v.len() == arr.len()).then_some(v)
}

/// Reject out-of-range ports instead of silently truncating to `u16`.
fn port_u16(v: usize) -> anyhow::Result<u16> {
    u16::try_from(v).map_err(|_| anyhow::anyhow!("port must be 0..=65535, got {v}"))
}

/// Render a full [`SweepSpec`] as the same JSON schema
/// [`sweep_spec_from_json`] reads — every overlay key is present, so
/// `sweep_spec_from_json(any_base, &sweep_spec_to_json(&s))` reproduces
/// `s` exactly regardless of the base. The job WAL depends on this
/// round-trip for bit-identical replay after a crash.
pub fn sweep_spec_to_json(s: &SweepSpec) -> Json {
    Json::obj(vec![
        (
            "signals",
            Json::arr_f64(&s.signals.iter().map(|&v| v as f64).collect::<Vec<_>>()),
        ),
        (
            "memvecs",
            Json::arr_f64(&s.memvecs.iter().map(|&v| v as f64).collect::<Vec<_>>()),
        ),
        (
            "obs",
            Json::arr_f64(&s.obs.iter().map(|&v| v as f64).collect::<Vec<_>>()),
        ),
        ("trials", Json::Num(s.trials as f64)),
        ("seed", Json::Num(s.seed as f64)),
        ("model", Json::Str(s.model.clone())),
        ("workers", Json::Num(s.workers as f64)),
        ("pilot_trials", Json::Num(s.pilot_trials as f64)),
        ("ci_target", Json::Num(s.ci_target)),
        ("max_trials", Json::Num(s.max_trials as f64)),
        ("interpolate", Json::Bool(s.interpolate)),
    ])
}

/// Overlay sweep keys from a JSON object onto `base` (missing keys keep the
/// base value). Shared by config files and the service's `POST /v1/scope`
/// body so both speak the same schema. A present-but-malformed key is an
/// error, never a silent fallback to the base value.
pub fn sweep_spec_from_json(base: &SweepSpec, j: &Json) -> anyhow::Result<SweepSpec> {
    let mut s = base.clone();
    let axis = |name: &str, v: &Json| {
        usize_list(v).ok_or_else(|| {
            anyhow::anyhow!("sweep.{name} must be an array of non-negative integers")
        })
    };
    if let Some(v) = j.get("signals") {
        s.signals = axis("signals", v)?;
    }
    if let Some(v) = j.get("memvecs") {
        s.memvecs = axis("memvecs", v)?;
    }
    if let Some(v) = j.get("obs") {
        s.obs = axis("obs", v)?;
    }
    if let Some(v) = j.get("trials") {
        s.trials = v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("sweep.trials must be a non-negative integer"))?;
    }
    if let Some(v) = j.get("seed") {
        let f = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("sweep.seed must be a number"))?;
        // JSON numbers are f64: only integers ≤ 2^53 survive a round-trip,
        // and the sweep cache keys on the exact seed — reject the rest.
        anyhow::ensure!(
            f >= 0.0 && f.fract() == 0.0 && f <= 9_007_199_254_740_992.0,
            "sweep.seed must be a non-negative integer ≤ 2^53"
        );
        s.seed = f as u64;
    }
    if let Some(v) = j.get("model") {
        s.model = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("sweep.model must be a string"))?
            .to_string();
    }
    if let Some(v) = j.get("workers") {
        s.workers = v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("sweep.workers must be a non-negative integer"))?;
    }
    if let Some(v) = j.get("pilot_trials") {
        s.pilot_trials = v.as_usize().ok_or_else(|| {
            anyhow::anyhow!("sweep.pilot_trials must be a non-negative integer")
        })?;
    }
    if let Some(v) = j.get("ci_target") {
        s.ci_target = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("sweep.ci_target must be a number"))?;
    }
    if let Some(v) = j.get("max_trials") {
        s.max_trials = v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("sweep.max_trials must be a non-negative integer"))?;
    }
    if let Some(v) = j.get("interpolate") {
        s.interpolate = v
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("sweep.interpolate must be a boolean"))?;
    }
    Ok(s)
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifact_dir: crate::runtime::default_artifact_dir(),
            output_dir: PathBuf::from("results"),
            backend: "device".into(),
            kernel_backend: None,
            sweep: SweepSpec::default(),
            service: ServiceConfig::default(),
            scenario: None,
            chaos: None,
        }
    }
}

impl Config {
    /// Load from a JSON file (all keys optional; defaults fill the rest).
    pub fn from_file(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("config {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("config {path}: {e}"))?;
        let mut cfg = Config::default();
        cfg.apply_json(&j)?;
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        if let Some(v) = j.get("artifact_dir").and_then(Json::as_str) {
            self.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("output_dir").and_then(Json::as_str) {
            self.output_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            self.backend = v.to_string();
        }
        match j.get("kernel_backend") {
            None => {}
            Some(Json::Null) => self.kernel_backend = None,
            Some(v) => {
                self.kernel_backend = Some(
                    v.as_str()
                        .ok_or_else(|| {
                            anyhow::anyhow!("kernel_backend must be a string or null")
                        })?
                        .to_string(),
                )
            }
        }
        if let Some(s) = j.get("sweep") {
            self.sweep = sweep_spec_from_json(&self.sweep, s)?;
        }
        match j.get("scenario") {
            None => {}
            Some(Json::Null) => self.scenario = None,
            Some(s) => self.scenario = Some(ScenarioSpec::from_json(s)?),
        }
        match j.get("chaos") {
            None => {}
            Some(Json::Null) => self.chaos = None,
            Some(Json::Str(v)) if v.is_empty() => self.chaos = None,
            Some(Json::Str(v)) => self.chaos = Some(v.clone()),
            Some(_) => anyhow::bail!("chaos must be a string or null"),
        }
        if let Some(s) = j.get("service") {
            // Same rule as the sweep section: a present-but-malformed key
            // is an error, never a silent fallback to the default.
            if let Some(v) = s.get("host") {
                self.service.host = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("service.host must be a string"))?
                    .to_string();
            }
            if let Some(v) = s.get("port") {
                let v = v
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("service.port must be an integer"))?;
                self.service.port = port_u16(v)?;
            }
            if let Some(v) = s.get("queue_cap") {
                self.service.queue_cap = v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("service.queue_cap must be a non-negative integer")
                })?;
            }
            if let Some(v) = s.get("executor_workers") {
                self.service.executor_workers = v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("service.executor_workers must be a non-negative integer")
                })?;
            }
            if let Some(v) = s.get("fair_share") {
                self.service.fair_share = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("service.fair_share must be a boolean")
                })?;
            }
            if let Some(v) = s.get("access_log") {
                self.service.access_log = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("service.access_log must be a boolean")
                })?;
            }
            if let Some(v) = s.get("keep_alive") {
                self.service.keep_alive = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("service.keep_alive must be a boolean")
                })?;
            }
            if let Some(v) = s.get("keep_alive_max_requests") {
                self.service.keep_alive_max_requests = v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!(
                        "service.keep_alive_max_requests must be a non-negative integer"
                    )
                })?;
            }
            if let Some(v) = s.get("stream_heartbeat_ms") {
                self.service.stream_heartbeat_ms =
                    v.as_usize().map(|n| n as u64).ok_or_else(|| {
                        anyhow::anyhow!(
                            "service.stream_heartbeat_ms must be a non-negative integer"
                        )
                    })?;
            }
            match s.get("cache_dir") {
                None => {}
                Some(Json::Null) => self.service.cache_dir = None,
                Some(Json::Str(v)) if v == "none" || v.is_empty() => {
                    self.service.cache_dir = None
                }
                Some(Json::Str(v)) => self.service.cache_dir = Some(PathBuf::from(v)),
                Some(_) => {
                    anyhow::bail!("service.cache_dir must be a string or null")
                }
            }
            if let Some(v) = s.get("slo") {
                self.service.slo = SloSettings::from_json(&self.service.slo, v)?;
            }
            match s.get("journal_dir") {
                None => {}
                Some(Json::Null) => self.service.journal_dir = None,
                Some(Json::Str(v)) if v == "none" || v.is_empty() => {
                    self.service.journal_dir = None
                }
                Some(Json::Str(v)) => self.service.journal_dir = Some(PathBuf::from(v)),
                Some(_) => {
                    anyhow::bail!("service.journal_dir must be a string or null")
                }
            }
            if let Some(v) = s.get("journal_max_file_bytes") {
                self.service.journal_max_file_bytes =
                    v.as_usize().map(|n| n as u64).ok_or_else(|| {
                        anyhow::anyhow!(
                            "service.journal_max_file_bytes must be a non-negative integer"
                        )
                    })?;
            }
            if let Some(v) = s.get("journal_max_total_bytes") {
                self.service.journal_max_total_bytes =
                    v.as_usize().map(|n| n as u64).ok_or_else(|| {
                        anyhow::anyhow!(
                            "service.journal_max_total_bytes must be a non-negative integer"
                        )
                    })?;
            }
            if let Some(v) = s.get("journal_fsync") {
                let v = v.as_str().ok_or_else(|| {
                    anyhow::anyhow!("service.journal_fsync must be a string")
                })?;
                self.service.journal_fsync = FsyncPolicy::parse(v)?;
            }
            if let Some(v) = s.get("journal_snapshot_ms") {
                self.service.journal_snapshot_ms =
                    v.as_usize().map(|n| n as u64).ok_or_else(|| {
                        anyhow::anyhow!(
                            "service.journal_snapshot_ms must be a non-negative integer"
                        )
                    })?;
            }
            match s.get("wal_dir") {
                None => {}
                Some(Json::Null) => self.service.wal_dir = None,
                Some(Json::Str(v)) if v == "none" || v.is_empty() => {
                    self.service.wal_dir = None
                }
                Some(Json::Str(v)) => self.service.wal_dir = Some(PathBuf::from(v)),
                Some(_) => {
                    anyhow::bail!("service.wal_dir must be a string or null")
                }
            }
            if let Some(v) = s.get("resume") {
                self.service.resume = v.as_bool().ok_or_else(|| {
                    anyhow::anyhow!("service.resume must be a boolean")
                })?;
            }
            if let Some(v) = s.get("drain_deadline_ms") {
                self.service.drain_deadline_ms =
                    v.as_usize().map(|n| n as u64).ok_or_else(|| {
                        anyhow::anyhow!(
                            "service.drain_deadline_ms must be a non-negative integer"
                        )
                    })?;
            }
        }
        Ok(())
    }

    /// Apply CLI overrides (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> anyhow::Result<()> {
        if let Some(v) = args.get("artifacts") {
            self.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("out") {
            self.output_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("backend") {
            self.backend = v.to_string();
        }
        if let Some(v) = args.get("kernel-backend") {
            self.kernel_backend = Some(v.to_string());
        }
        if let Some(v) = args.get("model") {
            self.sweep.model = v.to_string();
        }
        self.sweep.signals = args.get_usize_list("signals", &self.sweep.signals)?;
        self.sweep.memvecs = args.get_usize_list("memvecs", &self.sweep.memvecs)?;
        self.sweep.obs = args.get_usize_list("obs", &self.sweep.obs)?;
        self.sweep.trials = args.get_usize("trials", self.sweep.trials)?;
        self.sweep.seed = args.get_u64("seed", self.sweep.seed)?;
        self.sweep.workers = args.get_usize("workers", self.sweep.workers)?;
        self.sweep.pilot_trials = args.get_usize("pilot-trials", self.sweep.pilot_trials)?;
        self.sweep.ci_target = args.get_f64("ci-target", self.sweep.ci_target)?;
        self.sweep.max_trials = args.get_usize("max-trials", self.sweep.max_trials)?;
        if let Some(v) = args.get("interpolate") {
            self.sweep.interpolate = match v {
                "true" | "yes" | "on" => true,
                "false" | "no" | "off" => false,
                _ => anyhow::bail!("--interpolate expects true|false, got '{v}'"),
            };
        }
        if let Some(v) = args.get("host") {
            self.service.host = v.to_string();
        }
        self.service.port = port_u16(args.get_usize("port", self.service.port as usize)?)?;
        self.service.queue_cap = args.get_usize("queue-cap", self.service.queue_cap)?;
        self.service.executor_workers =
            args.get_usize("executor-workers", self.service.executor_workers)?;
        if let Some(v) = args.get("fair-share") {
            self.service.fair_share = match v {
                "true" | "yes" | "on" => true,
                "false" | "no" | "off" => false,
                _ => anyhow::bail!("--fair-share expects true|false, got '{v}'"),
            };
        }
        if let Some(v) = args.get("access-log") {
            self.service.access_log = match v {
                "true" | "yes" | "on" => true,
                "false" | "no" | "off" => false,
                _ => anyhow::bail!("--access-log expects true|false, got '{v}'"),
            };
        }
        if let Some(v) = args.get("keep-alive") {
            self.service.keep_alive = match v {
                "true" | "yes" | "on" => true,
                "false" | "no" | "off" => false,
                _ => anyhow::bail!("--keep-alive expects true|false, got '{v}'"),
            };
        }
        self.service.keep_alive_max_requests = args.get_usize(
            "keep-alive-max-requests",
            self.service.keep_alive_max_requests,
        )?;
        self.service.stream_heartbeat_ms = args.get_u64(
            "stream-heartbeat-ms",
            self.service.stream_heartbeat_ms,
        )?;
        if let Some(v) = args.get("cache-dir") {
            self.service.cache_dir = if v == "none" || v.is_empty() {
                None
            } else {
                Some(PathBuf::from(v))
            };
        }
        if let Some(v) = args.get("slo") {
            // `--slo ""` clears the objectives; otherwise the flag list
            // replaces whatever a config file declared.
            self.service.slo.objectives = if v.is_empty() {
                Vec::new()
            } else {
                v.split(',')
                    .map(SloObjective::parse_flag)
                    .collect::<anyhow::Result<Vec<_>>>()?
            };
        }
        self.service.slo.window_s = args.get_u64("slo-window-s", self.service.slo.window_s)?;
        self.service.slo.tick_ms = args.get_u64("slo-tick-ms", self.service.slo.tick_ms)?;
        if let Some(v) = args.get("journal-dir") {
            self.service.journal_dir = if v == "none" || v.is_empty() {
                None
            } else {
                Some(PathBuf::from(v))
            };
        }
        self.service.journal_max_file_bytes = args.get_u64(
            "journal-max-file-bytes",
            self.service.journal_max_file_bytes,
        )?;
        self.service.journal_max_total_bytes = args.get_u64(
            "journal-max-total-bytes",
            self.service.journal_max_total_bytes,
        )?;
        if let Some(v) = args.get("journal-fsync") {
            self.service.journal_fsync = FsyncPolicy::parse(v)?;
        }
        self.service.journal_snapshot_ms = args.get_u64(
            "journal-snapshot-ms",
            self.service.journal_snapshot_ms,
        )?;
        if let Some(v) = args.get("wal-dir") {
            self.service.wal_dir = if v == "none" || v.is_empty() {
                None
            } else {
                Some(PathBuf::from(v))
            };
        }
        // Accept both the bare `--resume` flag and the valued
        // `--resume true|false` form (the parser binds a following
        // non-flag token as a value, so both spellings occur).
        if args.flag("resume") {
            self.service.resume = true;
        } else if let Some(v) = args.get("resume") {
            self.service.resume = match v {
                "true" | "yes" | "on" => true,
                "false" | "no" | "off" => false,
                _ => anyhow::bail!("--resume expects true|false, got '{v}'"),
            };
        }
        self.service.drain_deadline_ms = args.get_u64(
            "drain-deadline-ms",
            self.service.drain_deadline_ms,
        )?;
        if let Some(v) = args.get("chaos") {
            self.chaos = if v.is_empty() {
                None
            } else {
                Some(v.to_string())
            };
        }
        if let Some(path) = args.get("scenario") {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("scenario {path}: {e}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("scenario {path}: {e}"))?;
            self.scenario = Some(ScenarioSpec::from_json(&j)?);
        }
        // simulate overrides: tweak the loaded scenario in place. With no
        // scenario loaded, an override flag materialises the built-in demo
        // first — otherwise `simulate --epochs 12` would silently run the
        // untouched demo defaults.
        let wants_override = args.get("epochs").is_some()
            || args.get("tenants").is_some()
            || args.get("scenario-seed").is_some();
        if self.scenario.is_none() && wants_override {
            self.scenario = Some(ScenarioSpec::default());
        }
        if let Some(s) = &mut self.scenario {
            s.epochs = args.get_usize("epochs", s.epochs)?;
            s.seed = args.get_u64("scenario-seed", s.seed)?;
            let n = args.get_usize("tenants", s.arrivals.max_tenants)?;
            s.arrivals.max_tenants = n;
            s.arrivals.initial = s.arrivals.initial.min(n);
        }
        self.validate()
    }

    /// Build the effective config: optional `--config file` then flags.
    pub fn resolve(args: &Args) -> anyhow::Result<Config> {
        let mut cfg = match args.get("config") {
            Some(path) => Config::from_file(path)?,
            None => Config::default(),
        };
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    /// Cross-field validation (backend name, sweep spec, service bounds).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(self.backend.as_str(), "device" | "native"),
            "backend must be 'device' or 'native', got '{}'",
            self.backend
        );
        if let Some(kb) = &self.kernel_backend {
            // Validate the spelling only — whether a SIMD tier exists is a
            // property of the host, checked at install time (main), so a
            // config file stays portable across machines.
            anyhow::ensure!(
                crate::linalg::simd::BackendRequest::parse(kb).is_some(),
                "kernel_backend must be 'scalar', 'simd' or 'auto', got '{kb}'"
            );
        }
        self.sweep.validate()?;
        anyhow::ensure!(self.service.queue_cap >= 1, "queue_cap must be ≥ 1");
        anyhow::ensure!(!self.service.host.is_empty(), "service host must be set");
        anyhow::ensure!(
            self.service.keep_alive_max_requests >= 1,
            "keep_alive_max_requests must be ≥ 1"
        );
        anyhow::ensure!(
            self.service.stream_heartbeat_ms >= 1,
            "stream_heartbeat_ms must be ≥ 1"
        );
        self.service.slo.validate()?;
        anyhow::ensure!(
            self.service.journal_max_file_bytes >= 1024,
            "journal_max_file_bytes must be ≥ 1024"
        );
        anyhow::ensure!(
            self.service.journal_max_total_bytes >= self.service.journal_max_file_bytes,
            "journal_max_total_bytes must be ≥ journal_max_file_bytes"
        );
        anyhow::ensure!(
            self.service.journal_snapshot_ms >= 1,
            "journal_snapshot_ms must be ≥ 1"
        );
        anyhow::ensure!(
            self.service.drain_deadline_ms >= 1,
            "drain_deadline_ms must be ≥ 1"
        );
        anyhow::ensure!(
            !self.service.resume || self.service.wal_dir.is_some(),
            "--resume requires a WAL directory (--wal-dir)"
        );
        if let Some(chaos) = &self.chaos {
            // Validate spec spelling and point names up front, so a typo'd
            // chaos plan fails at config time instead of silently never
            // injecting. Arming happens in main, after resolve.
            for part in chaos.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                crate::util::failpoint::FaultSpec::parse(part)?;
            }
        }
        if let Some(s) = &self.scenario {
            s.validate()?;
        }
        Ok(())
    }

    /// Serialise back to JSON (for run provenance in results/).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "artifact_dir",
                Json::Str(self.artifact_dir.display().to_string()),
            ),
            (
                "output_dir",
                Json::Str(self.output_dir.display().to_string()),
            ),
            ("backend", Json::Str(self.backend.clone())),
            ("sweep", sweep_spec_to_json(&self.sweep)),
            (
                "service",
                Json::obj(vec![
                    ("host", Json::Str(self.service.host.clone())),
                    ("port", Json::Num(self.service.port as f64)),
                    ("queue_cap", Json::Num(self.service.queue_cap as f64)),
                    (
                        "cache_dir",
                        match &self.service.cache_dir {
                            Some(d) => Json::Str(d.display().to_string()),
                            None => Json::Null,
                        },
                    ),
                    (
                        "executor_workers",
                        Json::Num(self.service.executor_workers as f64),
                    ),
                    ("fair_share", Json::Bool(self.service.fair_share)),
                    ("access_log", Json::Bool(self.service.access_log)),
                    ("keep_alive", Json::Bool(self.service.keep_alive)),
                    (
                        "keep_alive_max_requests",
                        Json::Num(self.service.keep_alive_max_requests as f64),
                    ),
                    (
                        "stream_heartbeat_ms",
                        Json::Num(self.service.stream_heartbeat_ms as f64),
                    ),
                    ("slo", self.service.slo.to_json()),
                    (
                        "journal_dir",
                        match &self.service.journal_dir {
                            Some(d) => Json::Str(d.display().to_string()),
                            None => Json::Null,
                        },
                    ),
                    (
                        "journal_max_file_bytes",
                        Json::Num(self.service.journal_max_file_bytes as f64),
                    ),
                    (
                        "journal_max_total_bytes",
                        Json::Num(self.service.journal_max_total_bytes as f64),
                    ),
                    (
                        "journal_fsync",
                        Json::Str(self.service.journal_fsync.as_str().to_string()),
                    ),
                    (
                        "journal_snapshot_ms",
                        Json::Num(self.service.journal_snapshot_ms as f64),
                    ),
                    (
                        "wal_dir",
                        match &self.service.wal_dir {
                            Some(d) => Json::Str(d.display().to_string()),
                            None => Json::Null,
                        },
                    ),
                    ("resume", Json::Bool(self.service.resume)),
                    (
                        "drain_deadline_ms",
                        Json::Num(self.service.drain_deadline_ms as f64),
                    ),
                ]),
            ),
        ];
        if let Some(kb) = &self.kernel_backend {
            fields.push(("kernel_backend", Json::Str(kb.clone())));
        }
        if let Some(s) = &self.scenario {
            fields.push(("scenario", s.to_json()));
        }
        if let Some(c) = &self.chaos {
            fields.push(("chaos", Json::Str(c.clone())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = Config::default();
        cfg.apply_args(&args(
            "sweep --signals 4,8 --trials 5 --model aakr --backend native",
        ))
        .unwrap();
        assert_eq!(cfg.sweep.signals, vec![4, 8]);
        assert_eq!(cfg.sweep.trials, 5);
        assert_eq!(cfg.sweep.model, "aakr");
        assert_eq!(cfg.backend, "native");
    }

    #[test]
    fn bad_values_rejected() {
        let mut cfg = Config::default();
        assert!(cfg.apply_args(&args("x --backend warp")).is_err());
        let mut cfg = Config::default();
        assert!(cfg.apply_args(&args("x --model svm")).is_err());
        let mut cfg = Config::default();
        assert!(cfg.apply_args(&args("x --trials 0")).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let cfg0 = {
            let mut c = Config::default();
            c.sweep.signals = vec![8, 16, 32];
            c.sweep.model = "ridge".into();
            c.backend = "native".into();
            c
        };
        let path = std::env::temp_dir().join("cs_config_test.json");
        std::fs::write(&path, cfg0.to_json().to_pretty()).unwrap();
        let cfg1 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg1.sweep.signals, vec![8, 16, 32]);
        assert_eq!(cfg1.sweep.model, "ridge");
        assert_eq!(cfg1.backend, "native");
    }

    #[test]
    fn service_keys_roundtrip_and_override() {
        let mut cfg = Config::default();
        cfg.apply_args(&args(
            "serve --port 9001 --queue-cap 5 --cache-dir /tmp/cs_cache --backend native",
        ))
        .unwrap();
        assert_eq!(cfg.service.port, 9001);
        assert_eq!(cfg.service.queue_cap, 5);
        assert_eq!(cfg.service.cache_dir, Some(PathBuf::from("/tmp/cs_cache")));

        // file roundtrip keeps the service section
        let path = std::env::temp_dir().join("cs_config_service.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.service.port, 9001);
        assert_eq!(cfg2.service.queue_cap, 5);

        // cache can be disabled from the CLI and from a file
        let mut cfg3 = Config::default();
        cfg3.apply_args(&args("serve --cache-dir none --backend native"))
            .unwrap();
        assert_eq!(cfg3.service.cache_dir, None);
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"cache_dir": null, "port": 0}}"#,
        )
        .unwrap();
        let cfg4 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg4.service.cache_dir, None);
        assert_eq!(cfg4.service.port, 0);

        let mut bad = Config::default();
        assert!(bad.apply_args(&args("serve --queue-cap 0")).is_err());
        let mut bad = Config::default();
        let err = bad
            .apply_args(&args("serve --port 70000"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("65535"), "{err}");
    }

    #[test]
    fn planner_knobs_from_flags_file_and_roundtrip() {
        let mut cfg = Config::default();
        cfg.apply_args(&args(
            "scope --ci-target 0.2 --pilot-trials 3 --max-trials 12 \
             --interpolate false --backend native",
        ))
        .unwrap();
        assert_eq!(cfg.sweep.ci_target, 0.2);
        assert_eq!(cfg.sweep.pilot_trials, 3);
        assert_eq!(cfg.sweep.max_trials, 12);
        assert!(!cfg.sweep.interpolate);
        assert!(cfg.sweep.adaptive());

        // file roundtrip keeps every planner knob
        let path = std::env::temp_dir().join("cs_config_planner.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.sweep.ci_target, 0.2);
        assert_eq!(cfg2.sweep.pilot_trials, 3);
        assert_eq!(cfg2.sweep.max_trials, 12);
        assert!(!cfg2.sweep.interpolate);

        // malformed knobs are errors, not silent defaults
        let mut bad = Config::default();
        assert!(bad.apply_args(&args("x --interpolate maybe")).is_err());
        let base = SweepSpec::default();
        let j = Json::parse(r#"{"interpolate": "yes"}"#).unwrap();
        assert!(sweep_spec_from_json(&base, &j).is_err());
        let j = Json::parse(r#"{"ci_target": "tight"}"#).unwrap();
        assert!(sweep_spec_from_json(&base, &j).is_err());

        // adaptive specs validate their internal consistency
        let mut bad = Config::default();
        let err = bad
            .apply_args(&args("x --ci-target 0.2 --pilot-trials 1 --backend native"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("pilot_trials"), "{err}");
        let mut bad = Config::default();
        let err = bad
            .apply_args(&args(
                "x --ci-target 0.2 --pilot-trials 4 --max-trials 2 --backend native",
            ))
            .unwrap_err()
            .to_string();
        assert!(err.contains("max_trials"), "{err}");
    }

    #[test]
    fn scheduler_knobs_from_flags_file_and_roundtrip() {
        let mut cfg = Config::default();
        assert_eq!(cfg.service.executor_workers, 0);
        assert!(cfg.service.fair_share);
        assert!(!cfg.service.access_log);
        cfg.apply_args(&args(
            "serve --executor-workers 6 --fair-share false --access-log on --backend native",
        ))
        .unwrap();
        assert_eq!(cfg.service.executor_workers, 6);
        assert!(!cfg.service.fair_share);
        assert!(cfg.service.access_log);

        // file roundtrip keeps both scheduler knobs
        let path = std::env::temp_dir().join("cs_config_sched.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.service.executor_workers, 6);
        assert!(!cfg2.service.fair_share);
        assert!(cfg2.service.access_log);

        // malformed knobs are errors, not silent defaults
        let mut bad = Config::default();
        assert!(bad.apply_args(&args("serve --fair-share maybe")).is_err());
        let mut bad = Config::default();
        assert!(bad.apply_args(&args("serve --access-log maybe")).is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"fair_share": "yes"}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"executor_workers": -2}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn wire_knobs_from_flags_file_and_roundtrip() {
        let mut cfg = Config::default();
        assert!(cfg.service.keep_alive);
        assert_eq!(cfg.service.keep_alive_max_requests, 1024);
        assert_eq!(cfg.service.stream_heartbeat_ms, 1000);
        cfg.apply_args(&args(
            "serve --keep-alive off --keep-alive-max-requests 8 \
             --stream-heartbeat-ms 250 --backend native",
        ))
        .unwrap();
        assert!(!cfg.service.keep_alive);
        assert_eq!(cfg.service.keep_alive_max_requests, 8);
        assert_eq!(cfg.service.stream_heartbeat_ms, 250);

        // file roundtrip keeps every wire knob
        let path = std::env::temp_dir().join("cs_config_wire.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert!(!cfg2.service.keep_alive);
        assert_eq!(cfg2.service.keep_alive_max_requests, 8);
        assert_eq!(cfg2.service.stream_heartbeat_ms, 250);

        // malformed knobs are errors, not silent defaults
        let mut bad = Config::default();
        assert!(bad.apply_args(&args("serve --keep-alive maybe")).is_err());
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --keep-alive-max-requests 0"))
            .is_err());
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --stream-heartbeat-ms 0"))
            .is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"keep_alive": "yes"}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"stream_heartbeat_ms": "fast"}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn ops_plane_knobs_from_flags_file_and_roundtrip() {
        let mut cfg = Config::default();
        assert!(cfg.service.slo.objectives.is_empty());
        assert_eq!(cfg.service.journal_dir, None);
        cfg.apply_args(&args(
            "serve --slo all:250:0.99:0.999,scope:500:0.95:0.99 --slo-window-s 60 \
             --slo-tick-ms 50 --journal-dir /tmp/cs-journal --journal-max-file-bytes 4096 \
             --journal-max-total-bytes 16384 --journal-fsync rotate \
             --journal-snapshot-ms 100 --backend native",
        ))
        .unwrap();
        assert_eq!(cfg.service.slo.objectives.len(), 2);
        assert_eq!(cfg.service.slo.objectives[1].route, "scope");
        assert_eq!(cfg.service.slo.window_s, 60);
        assert_eq!(cfg.service.slo.tick_ms, 50);
        assert_eq!(
            cfg.service.journal_dir.as_deref(),
            Some(std::path::Path::new("/tmp/cs-journal"))
        );
        assert_eq!(cfg.service.journal_max_file_bytes, 4096);
        assert_eq!(cfg.service.journal_max_total_bytes, 16384);
        assert_eq!(cfg.service.journal_fsync, FsyncPolicy::Rotate);
        assert_eq!(cfg.service.journal_snapshot_ms, 100);

        // file roundtrip keeps every ops-plane knob
        let path = std::env::temp_dir().join("cs_config_ops.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.service.slo, cfg.service.slo);
        assert_eq!(cfg2.service.journal_dir, cfg.service.journal_dir);
        assert_eq!(cfg2.service.journal_max_file_bytes, 4096);
        assert_eq!(cfg2.service.journal_fsync, FsyncPolicy::Rotate);
        assert_eq!(cfg2.service.journal_snapshot_ms, 100);

        // `--slo ""` / `--journal-dir none` clear file-configured state
        let mut cfg3 = Config::from_file(path.to_str().unwrap()).unwrap();
        let clear = ["serve", "--slo", "", "--journal-dir", "none", "--backend", "native"];
        cfg3.apply_args(&Args::parse(clear.iter().map(|s| s.to_string())))
            .unwrap();
        assert!(cfg3.service.slo.objectives.is_empty());
        assert_eq!(cfg3.service.journal_dir, None);

        // malformed knobs are errors, not silent defaults
        let mut bad = Config::default();
        assert!(bad.apply_args(&args("serve --slo all:250:0.99")).is_err());
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --journal-fsync eventually"))
            .is_err());
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --journal-max-file-bytes 10"))
            .is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"slo": {"objectives": [{"route": "all"}]}}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"journal_fsync": "eventually"}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn fault_tolerance_knobs_from_flags_file_and_roundtrip() {
        let mut cfg = Config::default();
        assert_eq!(cfg.service.wal_dir, None);
        assert!(!cfg.service.resume);
        assert_eq!(cfg.service.drain_deadline_ms, 5000);
        assert_eq!(cfg.chaos, None);
        cfg.apply_args(&args(
            "serve --wal-dir /tmp/cs-wal --resume --drain-deadline-ms 1200 \
             --chaos journal.append:0.5:error:7 --backend native",
        ))
        .unwrap();
        assert_eq!(
            cfg.service.wal_dir.as_deref(),
            Some(std::path::Path::new("/tmp/cs-wal"))
        );
        assert!(cfg.service.resume);
        assert_eq!(cfg.service.drain_deadline_ms, 1200);
        assert_eq!(cfg.chaos.as_deref(), Some("journal.append:0.5:error:7"));

        // file roundtrip keeps every fault-tolerance knob
        let path = std::env::temp_dir().join("cs_config_fault.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.service.wal_dir, cfg.service.wal_dir);
        assert!(cfg2.service.resume);
        assert_eq!(cfg2.service.drain_deadline_ms, 1200);
        assert_eq!(cfg2.chaos, cfg.chaos);

        // `--wal-dir none` / `--chaos ""` clear file-configured state
        let mut cfg3 = Config::from_file(path.to_str().unwrap()).unwrap();
        cfg3.service.resume = false; // resume without wal_dir must fail below
        let clear = ["serve", "--wal-dir", "none", "--chaos", "", "--backend", "native"];
        cfg3.apply_args(&Args::parse(clear.iter().map(|s| s.to_string())))
            .unwrap();
        assert_eq!(cfg3.service.wal_dir, None);
        assert_eq!(cfg3.chaos, None);

        // malformed knobs are errors, not silent defaults
        let mut bad = Config::default();
        let err = bad
            .apply_args(&args("serve --resume --backend native"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("wal"), "{err}");
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --chaos no.such.point:1:error"))
            .is_err());
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --chaos journal.append:2:error"))
            .is_err());
        let mut bad = Config::default();
        assert!(bad
            .apply_args(&args("serve --drain-deadline-ms 0"))
            .is_err());
        std::fs::write(&path, r#"{"backend": "native", "chaos": 7}"#).unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
        std::fs::write(
            &path,
            r#"{"backend": "native", "service": {"resume": "yes"}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn sweep_spec_json_roundtrip_is_exact_over_any_base() {
        let spec = SweepSpec {
            signals: vec![3, 9],
            memvecs: vec![8, 24],
            obs: vec![64],
            trials: 4,
            seed: 1234567,
            model: "ridge".into(),
            workers: 3,
            pilot_trials: 2,
            ci_target: 0.15,
            max_trials: 9,
            interpolate: false,
            ..SweepSpec::default()
        };
        let j = sweep_spec_to_json(&spec);
        // Overlaying the rendered JSON on a *different* base reproduces
        // the original spec exactly — the WAL replay path depends on it.
        let weird_base = SweepSpec {
            signals: vec![99],
            trials: 1,
            model: "mset2".into(),
            ..SweepSpec::default()
        };
        let back = sweep_spec_from_json(&weird_base, &j).unwrap();
        assert_eq!(back.signals, spec.signals);
        assert_eq!(back.memvecs, spec.memvecs);
        assert_eq!(back.obs, spec.obs);
        assert_eq!(back.trials, spec.trials);
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.model, spec.model);
        assert_eq!(back.workers, spec.workers);
        assert_eq!(back.pilot_trials, spec.pilot_trials);
        assert_eq!(back.ci_target, spec.ci_target);
        assert_eq!(back.max_trials, spec.max_trials);
        assert_eq!(back.interpolate, spec.interpolate);
    }

    #[test]
    fn kernel_backend_knob_from_flags_file_and_roundtrip() {
        let mut cfg = Config::default();
        assert_eq!(cfg.kernel_backend, None);
        cfg.apply_args(&args("sweep --kernel-backend auto --backend native"))
            .unwrap();
        assert_eq!(cfg.kernel_backend.as_deref(), Some("auto"));

        // file roundtrip keeps the knob; null clears it
        let path = std::env::temp_dir().join("cs_config_kernel.json");
        std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.kernel_backend.as_deref(), Some("auto"));
        std::fs::write(
            &path,
            r#"{"backend": "native", "kernel_backend": null}"#,
        )
        .unwrap();
        assert_eq!(
            Config::from_file(path.to_str().unwrap())
                .unwrap()
                .kernel_backend,
            None
        );

        // spelling is validated host-independently: "simd" is accepted by
        // the config layer even on machines without a vector tier (the
        // install step in main reports the hardware error)
        let mut cfg3 = Config::default();
        cfg3.apply_args(&args("sweep --kernel-backend simd --backend native"))
            .unwrap();
        assert_eq!(cfg3.kernel_backend.as_deref(), Some("simd"));

        // malformed knobs are errors, not silent defaults
        let mut bad = Config::default();
        let err = bad
            .apply_args(&args("sweep --kernel-backend warp --backend native"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("kernel_backend"), "{err}");
        std::fs::write(
            &path,
            r#"{"backend": "native", "kernel_backend": 7}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn scenario_from_file_flag_and_overrides() {
        // config-file "scenario" object round-trips through to_json
        let path = std::env::temp_dir().join("cs_config_scenario.json");
        std::fs::write(
            &path,
            r#"{"backend": "native",
                "scenario": {"name": "cfg", "epochs": 40,
                             "demand": {"kind": "steps", "step_every": 8}}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(path.to_str().unwrap()).unwrap();
        let s = cfg.scenario.as_ref().expect("scenario loaded");
        assert_eq!(s.name, "cfg");
        assert_eq!(s.epochs, 40);
        let path2 = std::env::temp_dir().join("cs_config_scenario2.json");
        std::fs::write(&path2, cfg.to_json().to_pretty()).unwrap();
        let cfg2 = Config::from_file(path2.to_str().unwrap()).unwrap();
        assert_eq!(cfg2.scenario.as_ref().unwrap().epochs, 40);

        // --scenario FILE + CLI overrides
        let spath = std::env::temp_dir().join("cs_scenario_spec.json");
        std::fs::write(&spath, r#"{"name": "flagged", "epochs": 30}"#).unwrap();
        let mut cfg = Config::default();
        cfg.apply_args(&args(&format!(
            "simulate --backend native --scenario {} --epochs 12 \
             --tenants 5 --scenario-seed 42",
            spath.to_str().unwrap()
        )))
        .unwrap();
        let s = cfg.scenario.unwrap();
        assert_eq!(s.name, "flagged");
        assert_eq!(s.epochs, 12);
        assert_eq!(s.seed, 42);
        assert_eq!(s.arrivals.max_tenants, 5);
        assert!(s.arrivals.initial <= 5);

        // override flags with no scenario loaded materialise the demo
        // first (otherwise `simulate --epochs 9` would silently run the
        // untouched defaults)
        let mut cfg = Config::default();
        cfg.apply_args(&args("simulate --backend native --epochs 9"))
            .unwrap();
        assert_eq!(cfg.scenario.unwrap().epochs, 9);

        // a malformed scenario in a config file is an error
        std::fs::write(
            &path,
            r#"{"backend": "native", "scenario": {"epochs": "many"}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
        // an invalid scenario fails validation
        std::fs::write(
            &path,
            r#"{"backend": "native", "scenario": {"epochs": 0}}"#,
        )
        .unwrap();
        assert!(Config::from_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn resolve_config_plus_flags() {
        let path = std::env::temp_dir().join("cs_config_test2.json");
        std::fs::write(
            &path,
            r#"{"backend": "native", "sweep": {"trials": 7}}"#,
        )
        .unwrap();
        let a = args(&format!(
            "sweep --config {} --trials 9",
            path.to_str().unwrap()
        ));
        let cfg = Config::resolve(&a).unwrap();
        assert_eq!(cfg.backend, "native"); // from file
        assert_eq!(cfg.sweep.trials, 9); // flag wins
    }
}
