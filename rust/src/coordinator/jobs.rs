//! Scoping-job front of the coordinator: fair multi-tenant scheduling.
//!
//! Customers (or the CLI) submit [`ScopeJob`]s; each job is driven by a
//! lightweight coordinator thread that streams its `(cell, trial)` tasks
//! into the **shared [`TrialExecutor`]**, where they interleave fairly
//! with every other job's tasks. The old single-leader FIFO — one job at a
//! time, a 1000-cell sweep head-of-line-blocking every 10-cell request —
//! is gone: a small job submitted behind a giant one finishes as soon as
//! its own trials do.
//!
//! Per job the service tracks live [`SweepProgress`] (updated atomically
//! from executor worker threads) and a cooperative [`CancelToken`]:
//! cancelling reclaims the job's queued trial tasks within one scheduling
//! quantum, lets in-flight trials finish (they are still written to the
//! cell store), and reports the job as [`JobStatus::Cancelled`].

use super::sweep::{
    run_sweep_executor, Backend, Cancelled, CellStore, ProgressSnapshot, SweepProgress,
    SweepResult, SweepSpec,
};
use super::wal::JobWal;
use crate::metrics::Registry;
use crate::obs::{self, EventBus, FlightRecorder};
use crate::scenario::fleet::{
    run_scenario_executor, ScenarioOutcome, ScenarioProgress, ScenarioSnapshot,
};
use crate::scenario::oracle::{MeasureCtx, SurfaceOracle};
use crate::scenario::spec::ScenarioSpec;
use crate::util::json::Json;
use crate::util::threadpool::{CancelToken, ExecutorStats, JobTicket, TrialExecutor};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Job identifier.
pub type JobId = u64;

/// Completed (done/failed/cancelled) jobs retained for status queries.
/// Oldest completed results are evicted beyond this, so a long-running
/// service does not grow without bound; in-flight jobs are never evicted.
pub const COMPLETED_RETAIN: usize = 256;

/// Job status as observed by clients.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Accepted; its driver has not started streaming trials yet.
    Queued,
    /// Sweep in progress (poll [`ScopingService::progress`] for detail).
    Running,
    /// Sweep finished; the result is shared until evicted.
    Done(Arc<SweepResult>),
    /// Scenario replay finished; the outcome is shared until evicted.
    DoneScenario(Arc<ScenarioOutcome>),
    /// Cancelled via [`ScopingService::cancel`]; trials measured before
    /// the cancellation are in the cell store.
    Cancelled,
    /// Sweep failed with this error message.
    Failed(String),
}

impl JobStatus {
    /// Whether the job still occupies a queue slot (backpressure gauge).
    fn in_flight(&self) -> bool {
        matches!(self, JobStatus::Queued | JobStatus::Running)
    }
}

/// One submitted scoping request.
#[derive(Clone, Debug)]
pub struct ScopeJob {
    /// Identifier handed back to the submitter.
    pub id: JobId,
    /// The sweep to run (exhaustive or adaptive — see
    /// [`SweepSpec::adaptive`]).
    pub spec: SweepSpec,
}

struct JobEntry {
    status: JobStatus,
    progress: Arc<SweepProgress>,
    /// Present for scenario jobs only (also how they are told apart).
    scenario: Option<Arc<ScenarioProgress>>,
    cancel: CancelToken,
    /// Per-job span ring buffer, served by `GET /v1/jobs/{id}/trace`.
    recorder: Arc<FlightRecorder>,
    /// Per-job live event bus, served by `GET /v1/jobs/{id}/events`;
    /// closed (with a terminal summary in its history) when the job ends.
    events: Arc<EventBus>,
}

struct Shared {
    jobs: Mutex<HashMap<JobId, JobEntry>>,
    done: Condvar,
}

/// The scoping service: a shared trial executor plus the job registry.
/// Jobs run concurrently; their `(cell, trial)` tasks interleave on the
/// executor under weighted fair queueing.
pub struct ScopingService {
    exec: Arc<TrialExecutor>,
    shared: Arc<Shared>,
    backend: Backend,
    cache: Option<Arc<dyn CellStore>>,
    next_id: Mutex<JobId>,
    drivers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Max queued+running jobs before submits are rejected (backpressure).
    queue_cap: usize,
    /// Optional job write-ahead log: submissions are journalled before
    /// their drivers start, terminal states when they end, so a crashed
    /// process's unfinished jobs can be replayed (see [`super::wal`]).
    wal: Mutex<Option<Arc<JobWal>>>,
}

impl ScopingService {
    /// Start a service over the given execution backend. `queue_cap`
    /// bounds the number of concurrent jobs (backpressure: submits fail
    /// fast beyond it rather than accumulating unbounded work). The
    /// executor is sized to the machine with fair interleaving on; use
    /// [`ScopingService::start_with_scheduler`] to tune either.
    pub fn start(backend: Backend, queue_cap: usize) -> ScopingService {
        Self::start_with_cache(backend, queue_cap, None)
    }

    /// [`ScopingService::start`] with a shared cell store: cells measured
    /// by any job are reused by every later job with an identical cell
    /// context (see [`crate::service::cache`] for the standard store).
    pub fn start_with_cache(
        backend: Backend,
        queue_cap: usize,
        cache: Option<Arc<dyn CellStore>>,
    ) -> ScopingService {
        Self::start_with_scheduler(backend, queue_cap, cache, 0, true)
    }

    /// Fully configured start: `executor_workers` sizes the shared trial
    /// executor (0 = machine parallelism) and `fair_share` selects
    /// weighted fair interleaving across jobs (`false` = strict
    /// job-arrival FIFO, the old leader discipline).
    pub fn start_with_scheduler(
        backend: Backend,
        queue_cap: usize,
        cache: Option<Arc<dyn CellStore>>,
        executor_workers: usize,
        fair_share: bool,
    ) -> ScopingService {
        let workers = if executor_workers == 0 {
            crate::util::threadpool::default_workers()
        } else {
            executor_workers
        };
        ScopingService {
            exec: Arc::new(TrialExecutor::new(workers, fair_share)),
            shared: Arc::new(Shared {
                jobs: Mutex::new(HashMap::new()),
                done: Condvar::new(),
            }),
            backend,
            cache,
            next_id: Mutex::new(1),
            drivers: Mutex::new(Vec::new()),
            queue_cap: queue_cap.max(1),
            wal: Mutex::new(None),
        }
    }

    /// Attach a job write-ahead log. Submissions from here on are
    /// journalled durably before their drivers start; jobs already in
    /// flight are unaffected.
    pub fn set_wal(&self, wal: Arc<JobWal>) {
        *self.wal.lock().unwrap() = Some(wal);
    }

    /// The attached WAL, if any.
    pub fn wal(&self) -> Option<Arc<JobWal>> {
        self.wal.lock().unwrap().clone()
    }

    /// Submit a sweep with an equal fair share; returns its job id, or an
    /// error when the service is saturated (backpressure).
    pub fn submit(&self, spec: SweepSpec) -> anyhow::Result<JobId> {
        self.submit_weighted(spec, 1.0)
    }

    /// [`ScopingService::submit`] with an explicit fair-share `weight`
    /// (clamped to `[1/16, 16]` by the executor): while jobs contend, a
    /// weight-2 job's trials are dispatched twice as often as a weight-1
    /// job's.
    pub fn submit_weighted(&self, spec: SweepSpec, weight: f64) -> anyhow::Result<JobId> {
        self.submit_traced(spec, weight, None)
    }

    /// [`ScopingService::submit_weighted`] with an explicit trace context
    /// (usually parsed from the HTTP request's `traceparent` or
    /// `x-request-id` header) stamped on the job's flight recorder, so
    /// `/trace` timelines correlate with client logs and the job's root
    /// span parents under the submitting request's span.
    pub fn submit_traced(
        &self,
        spec: SweepSpec,
        weight: f64,
        ctx: Option<obs::TraceContext>,
    ) -> anyhow::Result<JobId> {
        self.submit_traced_durable(spec, weight, ctx, None)
    }

    /// [`ScopingService::submit_traced`] with an opaque `extra` JSON value
    /// journalled alongside the spec in the WAL submit record (the HTTP
    /// layer stores the request's workload/SLA context there, so a resumed
    /// job's recommendation endpoint works like the original's). A no-op
    /// without an attached WAL.
    pub fn submit_traced_durable(
        &self,
        spec: SweepSpec,
        weight: f64,
        ctx: Option<obs::TraceContext>,
        extra: Option<Json>,
    ) -> anyhow::Result<JobId> {
        let wal_entry = self.wal().map(|w| {
            let mut payload = vec![
                ("spec", crate::config::sweep_spec_to_json(&spec)),
                ("weight", Json::Num(weight)),
            ];
            if let Some(extra) = &extra {
                payload.push(("extra", extra.clone()));
            }
            let id = w.log_submit("sweep", Json::obj(payload));
            (w, id)
        });
        let backend = self.backend.clone();
        let cache = self.cache.clone();
        let result = self.spawn_driver(
            weight,
            None,
            ctx,
            wal_entry.clone(),
            move |ticket, progress| {
                let result =
                    run_sweep_executor(&spec, backend, cache.as_deref(), &ticket, &progress);
                match result {
                    Ok(r) => JobStatus::Done(Arc::new(r)),
                    Err(e) if e.is::<Cancelled>() => JobStatus::Cancelled,
                    Err(e) => JobStatus::Failed(e.to_string()),
                }
            },
        );
        if result.is_err() {
            // The submit was journalled but the job never got a slot; a
            // dangling submit record would replay a job the client was
            // told was rejected.
            if let Some((w, id)) = &wal_entry {
                w.log_terminal(*id, "rejected");
            }
        }
        result
    }

    /// Submit a fleet scenario replay with an equal fair share; it runs
    /// as a job like any sweep (same queue cap, progress, cancellation).
    /// See [`ScopingService::submit_scenario_weighted`].
    pub fn submit_scenario(
        &self,
        scenario: ScenarioSpec,
        sweep: Option<SweepSpec>,
    ) -> anyhow::Result<JobId> {
        self.submit_scenario_weighted(scenario, sweep, 1.0)
    }

    /// Submit a fleet scenario replay with an explicit fair-share weight.
    ///
    /// Workload-mode scenarios require `sweep`: the job first runs that
    /// sweep through the shared executor (a warm cell cache serves it
    /// without executing a single trial) and fits the surface oracle from
    /// it; the same spec is the content-address template for any
    /// out-of-domain backstop cells the replay needs. Direct-mode
    /// scenarios may pass `sweep` purely for the backstop, or `None`.
    /// Specs are validated here so callers get a clean error instead of a
    /// failed job.
    pub fn submit_scenario_weighted(
        &self,
        scenario: ScenarioSpec,
        sweep: Option<SweepSpec>,
        weight: f64,
    ) -> anyhow::Result<JobId> {
        self.submit_scenario_traced(scenario, sweep, weight, None)
    }

    /// [`ScopingService::submit_scenario_weighted`] with an explicit trace
    /// context stamped on the job's flight recorder (see
    /// [`ScopingService::submit_traced`]).
    pub fn submit_scenario_traced(
        &self,
        scenario: ScenarioSpec,
        sweep: Option<SweepSpec>,
        weight: f64,
        ctx: Option<obs::TraceContext>,
    ) -> anyhow::Result<JobId> {
        scenario.validate()?;
        if let Some(s) = &sweep {
            s.validate()?;
        }
        anyhow::ensure!(
            scenario.workload.is_none() || sweep.is_some(),
            "workload-mode scenario needs a sweep spec to fit its oracle"
        );
        let wal_entry = self.wal().map(|w| {
            let id = w.log_submit(
                "scenario",
                Json::obj(vec![
                    ("scenario", scenario.to_json()),
                    (
                        "sweep",
                        match &sweep {
                            Some(s) => crate::config::sweep_spec_to_json(s),
                            None => Json::Null,
                        },
                    ),
                    ("weight", Json::Num(weight)),
                ]),
            );
            (w, id)
        });
        let backend = self.backend.clone();
        let cache = self.cache.clone();
        let scen_progress = Arc::new(ScenarioProgress::default());
        let scen = Arc::clone(&scen_progress);
        let result = self.spawn_driver(weight, Some(scen_progress), ctx, wal_entry.clone(), move |ticket, sweep_progress| {
            let run = || -> anyhow::Result<ScenarioOutcome> {
                let oracle = match (&scenario.workload, &sweep) {
                    (Some(_), Some(spec)) => {
                        let result = run_sweep_executor(
                            spec,
                            backend.clone(),
                            cache.as_deref(),
                            &ticket,
                            &sweep_progress,
                        )?;
                        Some(SurfaceOracle::from_sweep(&result)?)
                    }
                    _ => None,
                };
                let ctx = sweep.as_ref().map(|spec| MeasureCtx {
                    spec,
                    backend: &backend,
                    cache: cache.as_deref(),
                    ticket: &ticket,
                });
                run_scenario_executor(&scenario, oracle.as_ref(), ctx.as_ref(), &ticket, &scen)
            };
            match run() {
                Ok(o) => JobStatus::DoneScenario(Arc::new(o)),
                Err(e) if e.is::<Cancelled>() => JobStatus::Cancelled,
                Err(e) => JobStatus::Failed(e.to_string()),
            }
        });
        if result.is_err() {
            if let Some((w, id)) = &wal_entry {
                w.log_terminal(*id, "rejected");
            }
        }
        result
    }

    /// Shared driver machinery behind both job kinds: reserve a slot
    /// under the queue cap, register an executor job, run `work` on a
    /// named driver thread, and record its final status (evicting the
    /// oldest completed jobs beyond the retention bound).
    fn spawn_driver<F>(
        &self,
        weight: f64,
        scenario: Option<Arc<ScenarioProgress>>,
        ctx: Option<obs::TraceContext>,
        wal_entry: Option<(Arc<JobWal>, u64)>,
        work: F,
    ) -> anyhow::Result<JobId>
    where
        F: FnOnce(JobTicket, Arc<SweepProgress>) -> JobStatus + Send + 'static,
    {
        // Count + insert under one jobs lock, so concurrent submitters
        // cannot jointly overshoot the cap (check-then-act would race).
        let ticket = self.exec.register(weight);
        let progress = Arc::new(SweepProgress::default());
        let recorder = Arc::new(FlightRecorder::from_context(ctx.unwrap_or_else(|| {
            obs::TraceContext::from_id(obs::mint_trace_id())
        })));
        // One bus per job: sweep cell retirements and scenario unit
        // completions publish to it; the driver closes it with a terminal
        // summary, so late `/events` subscribers replay the full story.
        let events = Arc::new(EventBus::new());
        progress.attach_events(Arc::clone(&events));
        if let Some(s) = &scenario {
            s.attach_events(Arc::clone(&events));
        }
        let scen_progress = scenario.clone();
        let submitted = Instant::now();
        let id = {
            let mut jobs = self.shared.jobs.lock().unwrap();
            let in_flight = jobs.values().filter(|e| e.status.in_flight()).count();
            let cap = self.queue_cap;
            anyhow::ensure!(
                in_flight < cap,
                "scoping queue saturated ({in_flight}/{cap}); retry later"
            );
            let id = {
                let mut n = self.next_id.lock().unwrap();
                let id = *n;
                *n += 1;
                id
            };
            jobs.insert(
                id,
                JobEntry {
                    status: JobStatus::Queued,
                    progress: Arc::clone(&progress),
                    scenario,
                    cancel: ticket.cancel_token(),
                    recorder: Arc::clone(&recorder),
                    events: Arc::clone(&events),
                },
            );
            id
        };
        let shared = Arc::clone(&self.shared);
        let driver = std::thread::Builder::new()
            .name(format!("scope-job-{id}"))
            .spawn(move || {
                let started = Instant::now();
                let queue_wait = started.saturating_duration_since(submitted);
                {
                    let mut jobs = shared.jobs.lock().unwrap();
                    if let Some(e) = jobs.get_mut(&id) {
                        e.status = JobStatus::Running;
                    }
                }
                // Per-job progress gauges: live from the Running flip,
                // final values at completion, removed when the entry is
                // evicted from retention (see below) so the registry does
                // not accumulate stale series forever.
                Registry::global().set_gauge(&format!("service.job.{id}.trials_done"), 0.0);
                Registry::global().set_gauge(&format!("service.job.{id}.cells_done"), 0.0);
                // Install the recorder on the driver thread so planner
                // rounds (and anything else on this thread) see it via
                // `obs::current()`; dispatch points clone it into executor
                // task closures themselves.
                let _obs_guard = obs::install(Some(Arc::clone(&recorder)));
                let status = work(ticket, Arc::clone(&progress));
                let ended = Instant::now();
                // The trace-root envelope: carries the recorder's root
                // span id and parents under the propagated request span.
                recorder.push_root("job", "run", started, ended, queue_wait, format!("job={id}"));
                Registry::global().time("service.job_seconds", ended - started);
                let snap = progress.snapshot();
                Registry::global().set_gauge(
                    &format!("service.job.{id}.trials_done"),
                    snap.trials_done as f64,
                );
                Registry::global().set_gauge(
                    &format!("service.job.{id}.cells_done"),
                    snap.cells_done as f64,
                );
                let mut jobs = shared.jobs.lock().unwrap();
                if let Some(e) = jobs.get_mut(&id) {
                    e.status = status.clone();
                }
                // Evict the oldest completed entries beyond the retention
                // bound (ids are monotonic → oldest = min).
                let mut completed: Vec<JobId> = jobs
                    .iter()
                    .filter(|(_, e)| !e.status.in_flight())
                    .map(|(&id, _)| id)
                    .collect();
                if completed.len() > COMPLETED_RETAIN {
                    completed.sort_unstable();
                    for id in &completed[..completed.len() - COMPLETED_RETAIN] {
                        jobs.remove(id);
                        // Drop the evicted job's gauges with it — a gauge
                        // whose owner no longer answers `/v1/jobs/{id}`
                        // is stale data, not history.
                        Registry::global()
                            .remove_gauges_prefixed(&format!("service.job.{id}."));
                    }
                }
                drop(jobs);
                // Terminal summary: published after the status flip (a
                // poller woken by the event observes the final status) and
                // before close(), so late subscribers still replay it from
                // the bus history.
                let (state, error) = match &status {
                    JobStatus::Done(_) | JobStatus::DoneScenario(_) => ("done", None),
                    JobStatus::Cancelled => ("cancelled", None),
                    JobStatus::Failed(e) => ("failed", Some(e.clone())),
                    JobStatus::Queued | JobStatus::Running => ("running", None),
                };
                // Retire the WAL entry: after this record is durable the
                // job will not replay on a `--resume` restart.
                if let Some((w, wal_id)) = &wal_entry {
                    w.log_terminal(*wal_id, state);
                }
                let p = progress.snapshot();
                let mut fields = vec![
                    ("event", Json::Str("summary".to_string())),
                    ("job", Json::Num(id as f64)),
                    ("status", Json::Str(state.to_string())),
                    ("trials_done", Json::Num(p.trials_done as f64)),
                    ("cells_done", Json::Num(p.cells_done as f64)),
                    ("cells_total", Json::Num(p.cells_total as f64)),
                ];
                if let Some(s) = &scen_progress {
                    let sp = s.snapshot();
                    fields.push(("units_done", Json::Num(sp.units_done as f64)));
                    fields.push(("units_total", Json::Num(sp.units_total as f64)));
                }
                if let Some(e) = error {
                    fields.push(("error", Json::Str(e)));
                }
                events.publish_json(&Json::obj(fields));
                events.close();
                shared.done.notify_all();
            });
        match driver {
            Ok(handle) => {
                let mut drivers = self.drivers.lock().unwrap();
                // Reap drivers of completed jobs so a long-running service
                // does not accumulate joinable handles without bound.
                let mut i = 0;
                while i < drivers.len() {
                    if drivers[i].is_finished() {
                        let _ = drivers.swap_remove(i).join();
                    } else {
                        i += 1;
                    }
                }
                drivers.push(handle);
                Ok(id)
            }
            Err(e) => {
                // Roll the reservation back, or the ghost job would pin
                // in_flight() at the cap forever.
                self.shared.jobs.lock().unwrap().remove(&id);
                Err(anyhow::anyhow!("spawn job driver: {e}"))
            }
        }
    }

    /// Request cancellation of a queued/running job. Queued trial tasks
    /// are reclaimed within one scheduling quantum; in-flight trials
    /// finish (and land in the cell store) before the status flips to
    /// [`JobStatus::Cancelled`]. Returns the status observed at the time
    /// of the request, or `None` for unknown ids. Cancelling an already
    /// completed job is a no-op.
    pub fn cancel(&self, id: JobId) -> Option<JobStatus> {
        let jobs = self.shared.jobs.lock().unwrap();
        jobs.get(&id).map(|e| {
            if e.status.in_flight() {
                e.cancel.cancel();
            }
            e.status.clone()
        })
    }

    /// Number of jobs currently queued or running (the backpressure gauge
    /// reported by the service's `/healthz`).
    pub fn in_flight(&self) -> usize {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .values()
            .filter(|e| e.status.in_flight())
            .count()
    }

    /// Configured backpressure bound.
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Worker threads in the shared trial executor.
    pub fn executor_workers(&self) -> usize {
        self.exec.workers()
    }

    /// Whether fair interleaving across jobs is enabled.
    pub fn fair_share(&self) -> bool {
        self.exec.fair()
    }

    /// Non-blocking status check.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|e| e.status.clone())
    }

    /// Live progress snapshot of a job (available from submission until
    /// eviction; final values remain visible after completion). For
    /// scenario jobs this covers the embedded oracle sweep (if any); the
    /// replay itself reports through
    /// [`ScopingService::scenario_progress`].
    pub fn progress(&self, id: JobId) -> Option<ProgressSnapshot> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|e| e.progress.snapshot())
    }

    /// Live replay progress of a scenario job; `None` for unknown ids
    /// **and** for sweep jobs (which is how the service tells the two
    /// kinds apart).
    pub fn scenario_progress(&self, id: JobId) -> Option<ScenarioSnapshot> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .and_then(|e| e.scenario.as_ref().map(|p| p.snapshot()))
    }

    /// The job's flight recorder (`None` for unknown ids) — lets the
    /// service layer record wire-level spans (e.g. an `/events` stream's
    /// lifetime) into the job's own timeline.
    pub fn recorder(&self, id: JobId) -> Option<Arc<FlightRecorder>> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|e| Arc::clone(&e.recorder))
    }

    /// Live event bus of a job (`None` for unknown ids). Subscribing to
    /// a completed job's bus replays its retained event history — always
    /// ending with the terminal `summary` event — and delivers nothing
    /// live (the bus is closed).
    pub fn events(&self, id: JobId) -> Option<Arc<EventBus>> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|e| Arc::clone(&e.events))
    }

    /// Ordered span timeline of a job's flight recorder (`None` for
    /// unknown ids). Available from submission until eviction — completed
    /// jobs keep their timeline until they age out of
    /// [`COMPLETED_RETAIN`].
    pub fn trace(&self, id: JobId) -> Option<Json> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|e| e.recorder.to_json())
    }

    /// In-flight jobs split by class: `(sweep, scenario)`. Feeds the
    /// `service.jobs.in_flight.*` gauges at metrics-scrape time.
    pub fn in_flight_by_class(&self) -> (usize, usize) {
        let jobs = self.shared.jobs.lock().unwrap();
        let mut sweeps = 0;
        let mut scenarios = 0;
        for e in jobs.values().filter(|e| e.status.in_flight()) {
            if e.scenario.is_some() {
                scenarios += 1;
            } else {
                sweeps += 1;
            }
        }
        (sweeps, scenarios)
    }

    /// Point-in-time snapshot of the shared trial executor (queue depth,
    /// busy workers, registered jobs). Feeds the `executor.*` gauges.
    pub fn executor_stats(&self) -> ExecutorStats {
        self.exec.stats()
    }

    /// Block until a sweep job completes; errors for failed, cancelled,
    /// unknown, or scenario jobs.
    pub fn wait(&self, id: JobId) -> anyhow::Result<Arc<SweepResult>> {
        let mut jobs = self.shared.jobs.lock().unwrap();
        loop {
            match jobs.get(&id).map(|e| &e.status) {
                None => anyhow::bail!("unknown job {id}"),
                Some(JobStatus::Done(r)) => return Ok(Arc::clone(r)),
                Some(JobStatus::DoneScenario(_)) => {
                    anyhow::bail!("job {id} is a scenario job; use wait_scenario")
                }
                Some(JobStatus::Cancelled) => anyhow::bail!("job {id} cancelled"),
                Some(JobStatus::Failed(e)) => anyhow::bail!("job {id} failed: {e}"),
                Some(_) => {
                    jobs = self.shared.done.wait(jobs).unwrap();
                }
            }
        }
    }

    /// Block until a scenario job completes; errors for failed,
    /// cancelled, unknown, or sweep jobs.
    pub fn wait_scenario(&self, id: JobId) -> anyhow::Result<Arc<ScenarioOutcome>> {
        let mut jobs = self.shared.jobs.lock().unwrap();
        loop {
            match jobs.get(&id).map(|e| &e.status) {
                None => anyhow::bail!("unknown job {id}"),
                Some(JobStatus::DoneScenario(o)) => return Ok(Arc::clone(o)),
                Some(JobStatus::Done(_)) => {
                    anyhow::bail!("job {id} is a sweep job; use wait")
                }
                Some(JobStatus::Cancelled) => anyhow::bail!("job {id} cancelled"),
                Some(JobStatus::Failed(e)) => anyhow::bail!("job {id} failed: {e}"),
                Some(_) => {
                    jobs = self.shared.done.wait(jobs).unwrap();
                }
            }
        }
    }

    /// Graceful shutdown: stop accepting, finish in-flight work.
    pub fn shutdown(mut self) {
        self.join_drivers();
    }

    fn join_drivers(&mut self) {
        let handles: Vec<_> = self.drivers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ScopingService {
    fn drop(&mut self) {
        self.join_drivers();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![4],
            memvecs: vec![8],
            obs: vec![32],
            trials: 1,
            seed: 2,
            model: "mset2".into(),
            workers: 1,
            ..SweepSpec::default()
        }
    }

    /// A sweep heavy enough to still be in flight milliseconds after
    /// submission (native-backend cost scales with `obs`).
    fn slow_spec() -> SweepSpec {
        SweepSpec {
            obs: vec![4096],
            trials: 3,
            ..tiny_spec()
        }
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let svc = ScopingService::start(Backend::Native, 8);
        let id = svc.submit(tiny_spec()).unwrap();
        let res = svc.wait(id).unwrap();
        assert_eq!(res.cells.len(), 1);
        svc.shutdown();
    }

    #[test]
    fn traced_job_records_ordered_spans_under_callers_id() {
        let svc = ScopingService::start(Backend::Native, 8);
        let ctx = obs::TraceContext {
            trace_id: "req-abc123".into(),
            parent_span: 0x42,
        };
        let id = svc.submit_traced(tiny_spec(), 1.0, Some(ctx)).unwrap();
        svc.wait(id).unwrap();
        let trace = svc.trace(id).expect("trace available after completion");
        assert_eq!(
            trace.get("trace_id").and_then(Json::as_str),
            Some("req-abc123")
        );
        let spans = trace.get("spans").and_then(Json::as_arr).unwrap();
        assert!(!spans.is_empty(), "completed job must have spans");
        let starts: Vec<f64> = spans
            .iter()
            .map(|s| s.get("start_us").and_then(Json::as_f64).unwrap())
            .collect();
        assert!(starts.windows(2).all(|w| w[0] <= w[1]), "timeline ordered");
        // per-trial phases and the job envelope are both present
        let phases: Vec<&str> = spans
            .iter()
            .map(|s| s.get("phase").and_then(Json::as_str).unwrap())
            .collect();
        assert!(phases.contains(&"train"), "{phases:?}");
        assert!(phases.contains(&"surveil"), "{phases:?}");
        assert!(phases.contains(&"run"), "{phases:?}");
        // The envelope span parents under the caller-propagated span id.
        let run = spans
            .iter()
            .find(|s| s.get("phase").and_then(Json::as_str) == Some("run"))
            .unwrap();
        assert_eq!(
            run.get("parent_id").and_then(Json::as_str),
            Some("0000000000000042")
        );
        assert!(svc.trace(999).is_none());
        svc.shutdown();
    }

    #[test]
    fn job_event_bus_ends_with_matching_summary() {
        let svc = ScopingService::start(Backend::Native, 8);
        let id = svc.submit(tiny_spec()).unwrap();
        svc.wait(id).unwrap();
        let bus = svc.events(id).expect("bus available after completion");
        let (replay, live) = bus.subscribe();
        assert!(live.is_none(), "completed job's bus must be closed");
        let last = Json::parse(&replay.last().expect("history non-empty").line).unwrap();
        assert_eq!(last.get("event").and_then(Json::as_str), Some("summary"));
        assert_eq!(last.get("status").and_then(Json::as_str), Some("done"));
        let p = svc.progress(id).unwrap();
        assert_eq!(
            last.get("cells_done").and_then(Json::as_f64),
            Some(p.cells_done as f64)
        );
        // every cell retirement was published ahead of the summary
        let cells = replay
            .iter()
            .filter(|e| {
                Json::parse(&e.line)
                    .ok()
                    .and_then(|j| j.get("event").and_then(Json::as_str).map(str::to_string))
                    .as_deref()
                    == Some("cell")
            })
            .count();
        assert_eq!(cells, p.cells_total);
        assert!(svc.events(999).is_none());
        svc.shutdown();
    }

    #[test]
    fn in_flight_by_class_splits_sweeps_and_scenarios() {
        let svc = ScopingService::start(Backend::Native, 8);
        assert_eq!(svc.in_flight_by_class(), (0, 0));
        let stats = svc.executor_stats();
        assert!(stats.workers >= 1);
        assert_eq!(stats.running, 0);
        let id = svc.submit(tiny_spec()).unwrap();
        svc.wait(id).unwrap();
        assert_eq!(svc.in_flight_by_class(), (0, 0));
        svc.shutdown();
    }

    #[test]
    fn concurrent_jobs_get_distinct_ids_and_complete() {
        let svc = ScopingService::start(Backend::Native, 8);
        let a = svc.submit(tiny_spec()).unwrap();
        let b = svc.submit(tiny_spec()).unwrap();
        assert_ne!(a, b);
        svc.wait(a).unwrap();
        svc.wait(b).unwrap();
        svc.shutdown();
    }

    #[test]
    fn unknown_job_errors() {
        let svc = ScopingService::start(Backend::Native, 8);
        assert!(svc.wait(999).is_err());
        assert!(svc.status(999).is_none());
        assert!(svc.progress(999).is_none());
        assert!(svc.cancel(999).is_none());
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        let svc = ScopingService::start(Backend::Native, 1);
        let id = svc.submit(slow_spec()).unwrap();
        let err = svc.submit(slow_spec()).unwrap_err().to_string();
        assert!(err.contains("saturated"), "{err}");
        svc.wait(id).unwrap();
        // capacity frees once the job completes
        let id2 = svc.submit(tiny_spec()).unwrap();
        svc.wait(id2).unwrap();
        assert_eq!(svc.in_flight(), 0);
        assert_eq!(svc.queue_cap(), 1);
        svc.shutdown();
    }

    #[test]
    fn cached_service_skips_remeasurement() {
        let cache = Arc::new(crate::service::cache::SweepCache::in_memory());
        let svc = ScopingService::start_with_cache(
            Backend::Native,
            8,
            Some(Arc::clone(&cache) as Arc<dyn CellStore>),
        );
        let id = svc.submit(tiny_spec()).unwrap();
        svc.wait(id).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let id2 = svc.submit(tiny_spec()).unwrap();
        svc.wait(id2).unwrap();
        assert_eq!(cache.hits(), 1, "identical request must be cache-served");
        svc.shutdown();
    }

    #[test]
    fn completed_jobs_are_evicted_beyond_retention() {
        let svc = ScopingService::start(Backend::Native, 8);
        // Enough jobs that ids 1..=60 fall out of retention. The gauge
        // assertions below use id 42: high enough that no other test's
        // service (each restarts ids at 1, but submits only a handful of
        // jobs) touches the same global-registry series concurrently.
        let total = COMPLETED_RETAIN + 60;
        let mut last = 0;
        for _ in 0..total {
            last = svc.submit(tiny_spec()).unwrap();
            svc.wait(last).unwrap();
        }
        assert!(svc.status(1).is_none(), "oldest job must be evicted");
        assert!(svc.status(42).is_none(), "job 42 must be evicted");
        assert!(svc.status(last).is_some(), "newest job must be retained");
        // Eviction drops the job's per-job gauges with it; retained jobs
        // keep their final values.
        let reg = Registry::global();
        assert!(
            reg.gauge("service.job.42.trials_done").is_none(),
            "evicted job's gauges must be removed"
        );
        assert!(
            reg.gauge("service.job.42.cells_done").is_none(),
            "evicted job's gauges must be removed"
        );
        assert!(
            reg.gauge(&format!("service.job.{last}.trials_done")).is_some(),
            "retained job's gauges must survive"
        );
        svc.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let svc = ScopingService::start(Backend::Native, 8);
        let bad = SweepSpec {
            model: "no-such-model".into(),
            ..tiny_spec()
        };
        let id = svc.submit(bad).unwrap();
        let err = svc.wait(id).unwrap_err().to_string();
        assert!(err.contains("failed"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn cancelled_job_reports_cancelled_not_failed() {
        let svc = ScopingService::start(Backend::Native, 4);
        let id = svc.submit(slow_spec()).unwrap();
        let seen = svc.cancel(id).expect("job known");
        assert!(seen.in_flight(), "cancel must observe a live job");
        let err = svc.wait(id).unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
        assert!(matches!(svc.status(id), Some(JobStatus::Cancelled)));
        // cancelling a completed job is a no-op
        assert!(matches!(svc.cancel(id), Some(JobStatus::Cancelled)));
        assert_eq!(svc.in_flight(), 0);
        svc.shutdown();
    }

    #[test]
    fn small_job_overtakes_large_one() {
        // Single-worker executor makes the old head-of-line blocking
        // deterministic: under the leader FIFO the small job could never
        // finish first; under fair interleaving it must.
        let svc =
            ScopingService::start_with_scheduler(Backend::Native, 8, None, 1, true);
        let large = svc
            .submit(SweepSpec {
                memvecs: vec![8, 16],
                ..slow_spec()
            })
            .unwrap();
        let small = svc.submit(tiny_spec()).unwrap();
        svc.wait(small).unwrap();
        assert!(
            matches!(svc.status(large), Some(JobStatus::Queued | JobStatus::Running)),
            "small job must complete while the large sweep is still running"
        );
        svc.wait(large).unwrap();
        svc.shutdown();
    }

    #[test]
    fn wal_records_submits_and_retires_terminals() {
        let dir = std::env::temp_dir().join(format!("cs_jobs_wal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let wal = Arc::new(super::super::wal::JobWal::open(&dir).unwrap());
        let svc = ScopingService::start(Backend::Native, 8);
        svc.set_wal(Arc::clone(&wal));
        // While the job runs its submit record is pending, and the
        // journalled payload round-trips the full spec.
        let id = svc.submit(slow_spec()).unwrap();
        let p = wal.pending().unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].kind, "sweep");
        let spec_json = p[0].payload.get("spec").expect("spec journalled");
        let back =
            crate::config::sweep_spec_from_json(&SweepSpec::default(), spec_json).unwrap();
        assert_eq!(back.obs, vec![4096]);
        assert_eq!(back.seed, 2);
        assert_eq!(
            p[0].payload.get("weight").and_then(Json::as_f64),
            Some(1.0)
        );
        svc.wait(id).unwrap();
        // The driver retires the entry just before the terminal summary
        // event; give the record a moment to land.
        let t0 = Instant::now();
        while !wal.pending().unwrap().is_empty() {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "WAL entry never retired after job completion"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // A backpressure rejection retires its own submit record too — a
        // dangling one would replay a job the client saw rejected.
        let svc2 = ScopingService::start(Backend::Native, 1);
        svc2.set_wal(Arc::clone(&wal));
        let a = svc2.submit(slow_spec()).unwrap();
        assert!(svc2.submit(slow_spec()).is_err());
        svc2.wait(a).unwrap();
        let t0 = Instant::now();
        while !wal.pending().unwrap().is_empty() {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "rejected submit left a pending WAL entry"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        svc.shutdown();
        svc2.shutdown();
    }

    fn tiny_scenario() -> ScenarioSpec {
        use crate::scenario::spec::{ArrivalSpec, DemandKind, DemandSpec};
        ScenarioSpec {
            name: "jobs-test".into(),
            epochs: 24,
            arrivals: ArrivalSpec {
                initial: 3,
                rate_per_epoch: 0.2,
                max_tenants: 6,
            },
            demand: DemandSpec {
                base: 0.5,
                growth_per_epoch: 1.02,
                jitter: 0.1,
                kind: DemandKind::Constant,
            },
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn scenario_job_roundtrip_with_progress() {
        let svc = ScopingService::start(Backend::Native, 8);
        let id = svc.submit_scenario(tiny_scenario(), None).unwrap();
        let out = svc.wait_scenario(id).unwrap();
        assert_eq!(out.policies.len(), 3);
        assert!(out.tenants >= 3);
        let p = svc.scenario_progress(id).expect("scenario progress");
        assert_eq!(p.units_done, p.units_total);
        assert_eq!(p.units_total, out.policies.len() * out.tenants);
        // the wrong waiter reports a type mismatch, not a hang
        let err = svc.wait(id).unwrap_err().to_string();
        assert!(err.contains("scenario"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn scenario_jobs_are_distinguishable_from_sweeps() {
        let svc = ScopingService::start(Backend::Native, 8);
        let sweep_id = svc.submit(tiny_spec()).unwrap();
        svc.wait(sweep_id).unwrap();
        assert!(svc.scenario_progress(sweep_id).is_none());
        let err = svc.wait_scenario(sweep_id).unwrap_err().to_string();
        assert!(err.contains("sweep job"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn workload_scenario_needs_sweep_and_runs_with_one() {
        let svc = ScopingService::start(Backend::Native, 8);
        let scenario = ScenarioSpec {
            workload: Some(crate::scenario::spec::WorkloadSpec {
                base: crate::shapes::Workload {
                    n_signals: 2,
                    n_memvec: 8,
                    obs_per_sec: 0.01,
                    train_window: 32,
                },
                drift: Default::default(),
            }),
            ..tiny_scenario()
        };
        // no sweep: a clean submit-time error, not a failed job
        let err = svc
            .submit_scenario(scenario.clone(), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("sweep"), "{err}");
        // a 12-cell oracle sweep makes it run end to end
        let sweep = SweepSpec {
            signals: vec![2, 3],
            memvecs: vec![8, 12, 16],
            obs: vec![16, 32],
            trials: 1,
            seed: 5,
            model: "mset2".into(),
            workers: 2,
            ..SweepSpec::default()
        };
        let id = svc.submit_scenario(scenario, Some(sweep)).unwrap();
        let out = svc.wait_scenario(id).unwrap();
        let oracle = out.oracle.expect("workload mode reports oracle stats");
        assert!(oracle.surface_hits + oracle.memo_hits > 0);
        let p = svc.progress(id).expect("sweep progress present");
        assert_eq!(p.cells_total, 12, "embedded oracle sweep ran");
        svc.shutdown();
    }

    #[test]
    fn invalid_scenario_rejected_at_submit() {
        let svc = ScopingService::start(Backend::Native, 8);
        let bad = ScenarioSpec {
            epochs: 0,
            ..tiny_scenario()
        };
        assert!(svc.submit_scenario(bad, None).is_err());
        assert_eq!(svc.in_flight(), 0, "no slot may leak on rejection");
        svc.shutdown();
    }

    #[test]
    fn progress_is_live_and_monotone() {
        let svc = ScopingService::start(Backend::Native, 4);
        let id = svc.submit(slow_spec()).unwrap();
        let mut last = 0usize;
        loop {
            let p = svc.progress(id).expect("progress available");
            assert!(p.trials_done >= last, "progress went backwards");
            assert!(p.trials_done <= p.trials_planned.max(3));
            last = p.trials_done;
            if matches!(svc.status(id), Some(JobStatus::Done(_))) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let p = svc.progress(id).unwrap();
        assert_eq!(p.trials_done, 3, "3 trials over 1 cell");
        assert_eq!(p.cells_done, p.cells_total);
        svc.wait(id).unwrap();
        svc.shutdown();
    }
}
