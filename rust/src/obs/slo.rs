//! SLO burn-rate engine: declarative per-route latency/error objectives
//! evaluated with Google-SRE multi-window burn-rate math over the
//! process metrics registry.
//!
//! An [`SloObjective`] states, per route class, what fraction of
//! requests must complete under a latency threshold
//! (`latency_target`, e.g. 0.99 under 250 ms) and what fraction must not
//! fail with a 5xx (`error_target`, e.g. 0.999). The complement of a
//! target is the **error budget**; the **burn rate** is how many times
//! faster than budget the service is currently failing
//! (`bad_fraction / (1 - target)`): burn 1 exhausts the budget exactly
//! at the end of the base window, burn 14.4 exhausts it ~14× faster.
//!
//! Alerts use the SRE multi-window shape — a breach requires the burn
//! rate to exceed the threshold over **both** a long window (sustained,
//! not a blip) and a short window (still happening now), scaled from the
//! configured base `window_s`:
//!
//! | severity | burn ≥ | long window | short window |
//! |----------|--------|-------------|--------------|
//! | `page`   | 14.4   | window/12   | window/144   |
//! | `warn`   | 6.0    | window/2    | window/24    |
//!
//! The engine snapshots counter/histogram deltas on a tick (the service
//! ops thread): windowed fractions come from diffing the newest counts
//! against the snapshot nearest the window boundary, so evaluation costs
//! a few histogram clones and no per-request work. Latency "bad"
//! fractions are read from [`Histogram`] cumulative buckets, so the
//! threshold is quantised to a bucket boundary (≤ ~9% relative — the
//! log-bucket width), which is ample for burn-rate alerting.
//!
//! Engine state is surfaced in `GET /v1/slo`, summarized in `/healthz`,
//! and advises the HTTP accept-loop load-shedder: while any objective
//! **pages**, the shedder trips at a quarter of its normal pending-queue
//! depth (breach → shed earlier is one code path, not a parallel limit).

use crate::metrics::{Histogram, Registry};
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Burn-rate threshold for the paging (fast-window) alert.
pub const PAGE_BURN: f64 = 14.4;

/// Burn-rate threshold for the warning (slow-window) alert.
pub const WARN_BURN: f64 = 6.0;

/// Hard cap on retained snapshots (memory bound regardless of window /
/// tick configuration).
const MAX_SNAPS: usize = 4096;

/// One declarative objective for a route class.
#[derive(Clone, Debug, PartialEq)]
pub struct SloObjective {
    /// Route class the objective covers: `"all"` for every request
    /// (`service.http.request_seconds`), otherwise a route class name as
    /// classified by the service (`scope`, `jobs`, `metrics`, …) read
    /// from `service.route.<route>.seconds` / `.errors`.
    pub route: String,
    /// Latency threshold in milliseconds.
    pub latency_ms: f64,
    /// Fraction of requests that must complete within `latency_ms`
    /// (0 < target < 1, e.g. 0.99).
    pub latency_target: f64,
    /// Fraction of requests that must not fail server-side (5xx)
    /// (0 < target < 1, e.g. 0.999).
    pub error_target: f64,
}

impl SloObjective {
    /// Parse one `--slo` flag item: `route:latency_ms:latency_target:error_target`
    /// (e.g. `all:250:0.99:0.999`).
    pub fn parse_flag(spec: &str) -> anyhow::Result<SloObjective> {
        let parts: Vec<&str> = spec.split(':').collect();
        anyhow::ensure!(
            parts.len() == 4,
            "--slo item {spec:?} must be route:latency_ms:latency_target:error_target"
        );
        let num = |what: &str, s: &str| -> anyhow::Result<f64> {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--slo item {spec:?}: bad {what} {s:?}"))
        };
        let o = SloObjective {
            route: parts[0].to_string(),
            latency_ms: num("latency_ms", parts[1])?,
            latency_target: num("latency_target", parts[2])?,
            error_target: num("error_target", parts[3])?,
        };
        o.validate()?;
        Ok(o)
    }

    /// Strict construction from a config-JSON object.
    pub fn from_json(j: &Json) -> anyhow::Result<SloObjective> {
        anyhow::ensure!(j.as_obj().is_some(), "slo objective must be an object");
        let route = j
            .get("route")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("slo objective needs a string `route`"))?
            .to_string();
        let num = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("slo objective {route:?} needs numeric `{key}`"))
        };
        let o = SloObjective {
            route,
            latency_ms: num("latency_ms")?,
            latency_target: num("latency_target")?,
            error_target: num("error_target")?,
        };
        o.validate()?;
        Ok(o)
    }

    /// Config-JSON representation (round-trips through
    /// [`SloObjective::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("route", Json::Str(self.route.clone())),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("latency_target", Json::Num(self.latency_target)),
            ("error_target", Json::Num(self.error_target)),
        ])
    }

    /// Cross-field validation (targets strictly inside (0, 1), positive
    /// finite threshold, plausible route token).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.route.is_empty()
                && self
                    .route
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            "slo route {:?} must be a bare route-class token",
            self.route
        );
        anyhow::ensure!(
            self.latency_ms.is_finite() && self.latency_ms > 0.0,
            "slo route {:?}: latency_ms must be positive",
            self.route
        );
        for (what, v) in [
            ("latency_target", self.latency_target),
            ("error_target", self.error_target),
        ] {
            anyhow::ensure!(
                v.is_finite() && v > 0.0 && v < 1.0,
                "slo route {:?}: {what} must be in (0, 1)",
                self.route
            );
        }
        Ok(())
    }

    /// Metric names this objective reads: `(latency histogram, error
    /// counter, total counter for the error dimension)`.
    fn metric_names(&self) -> (String, String) {
        if self.route == "all" {
            (
                "service.http.request_seconds".to_string(),
                "service.http.responses.5xx".to_string(),
            )
        } else {
            (
                format!("service.route.{}.seconds", self.route),
                format!("service.route.{}.errors", self.route),
            )
        }
    }
}

/// Engine-level settings: the alert window base, the snapshot cadence,
/// and the objectives (empty = SLO tracking disabled).
#[derive(Clone, Debug, PartialEq)]
pub struct SloSettings {
    /// Base alert window in seconds; the four evaluation windows are
    /// scaled from it (see the module docs).
    pub window_s: u64,
    /// Snapshot cadence of the ops tick thread, milliseconds.
    pub tick_ms: u64,
    /// Per-route objectives; empty disables the engine.
    pub objectives: Vec<SloObjective>,
}

impl Default for SloSettings {
    fn default() -> Self {
        SloSettings {
            window_s: 3600,
            tick_ms: 1000,
            objectives: Vec::new(),
        }
    }
}

impl SloSettings {
    /// Whether any objective is configured.
    pub fn enabled(&self) -> bool {
        !self.objectives.is_empty()
    }

    /// Strict parse of the `service.slo` config object. Every present
    /// key must be well-formed; absent keys keep `base`'s values.
    pub fn from_json(base: &SloSettings, j: &Json) -> anyhow::Result<SloSettings> {
        let mut s = base.clone();
        anyhow::ensure!(j.as_obj().is_some(), "service.slo must be an object");
        if let Some(v) = j.get("window_s") {
            s.window_s = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("service.slo.window_s must be a positive integer"))?
                as u64;
        }
        if let Some(v) = j.get("tick_ms") {
            s.tick_ms = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("service.slo.tick_ms must be a positive integer"))?
                as u64;
        }
        if let Some(v) = j.get("objectives") {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("service.slo.objectives must be an array"))?;
            s.objectives = arr
                .iter()
                .map(SloObjective::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        s.validate()?;
        Ok(s)
    }

    /// Config-JSON representation.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window_s", Json::Num(self.window_s as f64)),
            ("tick_ms", Json::Num(self.tick_ms as f64)),
            (
                "objectives",
                Json::Arr(self.objectives.iter().map(SloObjective::to_json).collect()),
            ),
        ])
    }

    /// Cross-field validation.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.window_s >= 1, "slo window_s must be >= 1");
        anyhow::ensure!(self.tick_ms >= 1, "slo tick_ms must be >= 1");
        for o in &self.objectives {
            o.validate()?;
        }
        Ok(())
    }

    /// The four evaluation windows in milliseconds:
    /// `(page_long, page_short, warn_long, warn_short)`, each at least
    /// 1 ms.
    fn windows_ms(&self) -> (u64, u64, u64, u64) {
        let w = self.window_s * 1000;
        (
            (w / 12).max(1),
            (w / 144).max(1),
            (w / 2).max(1),
            (w / 24).max(1),
        )
    }
}

/// Per-objective cumulative counts at one instant.
#[derive(Clone, Copy, Debug, Default)]
struct ObjCounts {
    /// Requests observed (histogram count).
    total: u64,
    /// Requests over the latency threshold.
    slow: u64,
    /// Server-side failures (5xx).
    errors: u64,
}

struct Snap {
    at_ms: u64,
    counts: Vec<ObjCounts>,
}

/// The burn-rate engine: settings, a bounded ring of count snapshots,
/// and the advisory paging flag the load-shedder reads.
pub struct SloEngine {
    settings: SloSettings,
    epoch: Instant,
    snaps: Mutex<VecDeque<Snap>>,
    paging: AtomicBool,
}

impl SloEngine {
    /// Engine over the global metrics registry. Callers should [`tick`]
    /// once right away so evaluation has a baseline snapshot.
    ///
    /// [`tick`]: SloEngine::tick
    pub fn new(settings: SloSettings) -> SloEngine {
        SloEngine {
            settings,
            epoch: Instant::now(),
            snaps: Mutex::new(VecDeque::new()),
            paging: AtomicBool::new(false),
        }
    }

    /// Engine settings.
    pub fn settings(&self) -> &SloSettings {
        &self.settings
    }

    /// Milliseconds since the engine was created.
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Counts for every objective from the live registry.
    fn live_counts(&self) -> Vec<ObjCounts> {
        let reg = Registry::global();
        self.settings
            .objectives
            .iter()
            .map(|o| {
                let (hist_name, err_name) = o.metric_names();
                let (total, slow) = match reg.histogram(&hist_name) {
                    Some(h) => (h.count(), slow_count(&h, o.latency_ms / 1000.0)),
                    None => (0, 0),
                };
                ObjCounts {
                    total,
                    slow,
                    errors: reg.counter(&err_name),
                }
            })
            .collect()
    }

    /// Record one snapshot and refresh the paging flag. Called on the
    /// service ops-tick cadence (`tick_ms`).
    pub fn tick(&self) {
        let counts = self.live_counts();
        let now = self.now_ms();
        self.push_snap(now, counts.clone());
        self.evaluate_at(now, &counts);
    }

    fn push_snap(&self, at_ms: u64, counts: Vec<ObjCounts>) {
        let (_, _, warn_long, _) = self.settings.windows_ms();
        let keep_from = at_ms.saturating_sub(warn_long + 2 * self.settings.tick_ms);
        let mut snaps = self.snaps.lock().unwrap();
        snaps.push_back(Snap { at_ms, counts });
        while snaps.len() > MAX_SNAPS || snaps.front().is_some_and(|s| s.at_ms < keep_from) {
            // keep at least one snapshot older than the longest window
            if snaps.len() >= 2 && snaps[1].at_ms <= keep_from {
                snaps.pop_front();
            } else if snaps.len() > MAX_SNAPS {
                snaps.pop_front();
            } else {
                break;
            }
        }
    }

    /// Whether any objective currently exceeds the paging burn rate —
    /// the advisory input the HTTP load-shedder consults.
    pub fn is_paging(&self) -> bool {
        self.paging.load(Ordering::Relaxed)
    }

    /// Full evaluation against live counts (the `GET /v1/slo` body).
    pub fn evaluate(&self) -> Json {
        self.evaluate_at(self.now_ms(), &self.live_counts())
    }

    /// One-line summary for `/healthz`: overall status plus the routes
    /// currently breaching (warn or page).
    pub fn summary(&self) -> Json {
        let full = self.evaluate();
        let status = full
            .get("status")
            .and_then(Json::as_str)
            .unwrap_or("ok")
            .to_string();
        let breaching: Vec<Json> = full
            .get("objectives")
            .and_then(Json::as_arr)
            .map(|objs| {
                objs.iter()
                    .filter(|o| o.get("status").and_then(Json::as_str) != Some("ok"))
                    .filter_map(|o| o.get("route").and_then(Json::as_str))
                    .map(|r| Json::Str(r.to_string()))
                    .collect()
            })
            .unwrap_or_default();
        Json::obj(vec![
            ("status", Json::Str(status)),
            ("breaching", Json::Arr(breaching)),
            ("shedding", Json::Bool(self.is_paging())),
        ])
    }

    /// Evaluate burn rates of `now` counts against the snapshot history
    /// and update the paging flag. Split from [`SloEngine::evaluate`] so
    /// tests can drive it with synthetic clocks and counts.
    fn evaluate_at(&self, now_ms: u64, now: &[ObjCounts]) -> Json {
        let (page_long, page_short, warn_long, warn_short) = self.settings.windows_ms();
        let snaps = self.snaps.lock().unwrap();
        let mut any_page = false;
        let mut worst = 0u8; // 0 ok, 1 warn, 2 page
        let mut objectives = Vec::with_capacity(self.settings.objectives.len());
        for (i, o) in self.settings.objectives.iter().enumerate() {
            let cur = now.get(i).copied().unwrap_or_default();
            let dim_json = |bad_of: &dyn Fn(&ObjCounts) -> u64, target: f64| -> (u8, Json) {
                let budget = 1.0 - target;
                let frac = |window: u64| -> (f64, u64) {
                    windowed_fraction(&snaps, i, now_ms, cur, window, bad_of)
                };
                let (f_pl, n_pl) = frac(page_long);
                let (f_ps, _) = frac(page_short);
                let (f_wl, _) = frac(warn_long);
                let (f_ws, _) = frac(warn_short);
                let burn = |f: f64| f / budget;
                let page = burn(f_pl) >= PAGE_BURN && burn(f_ps) >= PAGE_BURN;
                let warn = burn(f_wl) >= WARN_BURN && burn(f_ws) >= WARN_BURN;
                let sev: u8 = if page {
                    2
                } else if warn {
                    1
                } else {
                    0
                };
                let status = ["ok", "warn", "page"][sev as usize];
                (
                    sev,
                    Json::obj(vec![
                        ("status", Json::Str(status.to_string())),
                        ("budget", Json::Num(budget)),
                        ("bad_fraction", Json::Num(f_pl)),
                        ("requests", Json::Num(n_pl as f64)),
                        (
                            "burn",
                            Json::obj(vec![
                                ("page_long", Json::Num(burn(f_pl))),
                                ("page_short", Json::Num(burn(f_ps))),
                                ("warn_long", Json::Num(burn(f_wl))),
                                ("warn_short", Json::Num(burn(f_ws))),
                            ]),
                        ),
                    ]),
                )
            };
            let (lat_sev, lat) = dim_json(&|c: &ObjCounts| c.slow, o.latency_target);
            let (err_sev, err) = dim_json(&|c: &ObjCounts| c.errors, o.error_target);
            let sev = lat_sev.max(err_sev);
            worst = worst.max(sev);
            any_page |= sev == 2;
            objectives.push(Json::obj(vec![
                ("route", Json::Str(o.route.clone())),
                ("latency_ms", Json::Num(o.latency_ms)),
                ("latency_target", Json::Num(o.latency_target)),
                ("error_target", Json::Num(o.error_target)),
                ("status", Json::Str(["ok", "warn", "page"][sev as usize].to_string())),
                ("latency", lat),
                ("errors", err),
            ]));
        }
        drop(snaps);
        self.paging.store(any_page, Ordering::Relaxed);
        Json::obj(vec![
            ("enabled", Json::Bool(self.settings.enabled())),
            ("status", Json::Str(["ok", "warn", "page"][worst as usize].to_string())),
            ("window_s", Json::Num(self.settings.window_s as f64)),
            ("tick_ms", Json::Num(self.settings.tick_ms as f64)),
            (
                "windows_ms",
                Json::obj(vec![
                    ("page_long", Json::Num(page_long as f64)),
                    ("page_short", Json::Num(page_short as f64)),
                    ("warn_long", Json::Num(warn_long as f64)),
                    ("warn_short", Json::Num(warn_short as f64)),
                    ("page_burn", Json::Num(PAGE_BURN)),
                    ("warn_burn", Json::Num(WARN_BURN)),
                ]),
            ),
            ("shedding", Json::Bool(any_page)),
            ("objectives", Json::Arr(objectives)),
        ])
    }
}

/// Bad-event fraction of objective `i` over the trailing `window_ms`:
/// deltas between `now` counts and the newest snapshot at least
/// `window_ms` old (or the oldest available while the history is still
/// shorter than the window). Returns `(fraction, request_delta)`; an
/// empty window is a 0.0 fraction.
fn windowed_fraction(
    snaps: &VecDeque<Snap>,
    i: usize,
    now_ms: u64,
    now: ObjCounts,
    window_ms: u64,
    bad_of: &dyn Fn(&ObjCounts) -> u64,
) -> (f64, u64) {
    let cutoff = now_ms.saturating_sub(window_ms);
    let base = snaps
        .iter()
        .rev()
        .find(|s| s.at_ms <= cutoff)
        .or_else(|| snaps.front());
    let Some(base) = base else {
        return (0.0, 0);
    };
    let old = base.counts.get(i).copied().unwrap_or_default();
    let total = now.total.saturating_sub(old.total);
    if total == 0 {
        return (0.0, 0);
    }
    let bad = bad_of(&now).saturating_sub(bad_of(&old));
    (bad.min(total) as f64 / total as f64, total)
}

/// Count of samples above `threshold_s` in a histogram, read from its
/// cumulative buckets (quantised to the bucket boundary at or below the
/// threshold — ≤ one log-bucket of relative error).
fn slow_count(h: &Histogram, threshold_s: f64) -> u64 {
    let mut good = 0;
    for (le, cum) in h.cumulative_buckets() {
        if le <= threshold_s {
            good = cum;
        } else {
            break;
        }
    }
    h.count().saturating_sub(good)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn objective(route: &str) -> SloObjective {
        SloObjective {
            route: route.to_string(),
            latency_ms: 250.0,
            latency_target: 0.99,
            error_target: 0.999,
        }
    }

    fn engine(window_s: u64) -> SloEngine {
        SloEngine::new(SloSettings {
            window_s,
            tick_ms: 100,
            objectives: vec![objective("all")],
        })
    }

    fn counts(total: u64, slow: u64, errors: u64) -> Vec<ObjCounts> {
        vec![ObjCounts { total, slow, errors }]
    }

    #[test]
    fn quiet_service_is_ok() {
        let e = engine(3600);
        e.push_snap(0, counts(0, 0, 0));
        let j = e.evaluate_at(60_000, &counts(1000, 0, 0));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
        assert!(!e.is_paging());
        let obj = &j.get("objectives").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(obj.get("status").and_then(Json::as_str), Some("ok"));
    }

    #[test]
    fn sustained_latency_breach_pages_and_sheds() {
        // window 3600s → page windows 300s / 25s. Saturate both: every
        // request slow across the whole history.
        let e = engine(3600);
        e.push_snap(0, counts(0, 0, 0));
        e.push_snap(300_000, counts(3000, 3000, 0));
        e.push_snap(595_000, counts(5950, 5950, 0));
        let j = e.evaluate_at(600_000, &counts(6000, 6000, 0));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("page"));
        assert_eq!(j.get("shedding"), Some(&Json::Bool(true)));
        assert!(e.is_paging());
        let obj = &j.get("objectives").and_then(Json::as_arr).unwrap()[0];
        let lat = obj.get("latency").unwrap();
        assert_eq!(lat.get("status").and_then(Json::as_str), Some("page"));
        // bad fraction 1.0 against budget 0.01 → burn 100
        let burn = lat.get("burn").unwrap();
        assert!(burn.get("page_long").and_then(Json::as_f64).unwrap() > 99.0);
        assert!(burn.get("page_short").and_then(Json::as_f64).unwrap() > 99.0);
    }

    #[test]
    fn short_blip_does_not_page() {
        // Bad only in the short window; the long window stays healthy →
        // multi-window gating holds the alert back.
        let e = engine(3600);
        e.push_snap(0, counts(0, 0, 0));
        // long window (300s): 100k requests, 10 slow → burn ≈ 0.01
        e.push_snap(575_000, counts(100_000, 10, 0));
        // short window (25s): 100 requests, all slow
        let j = e.evaluate_at(600_000, &counts(100_100, 110, 0));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
        assert!(!e.is_paging());
    }

    #[test]
    fn error_burn_reports_separately_from_latency() {
        let e = engine(3600);
        e.push_snap(0, counts(0, 0, 0));
        e.push_snap(595_000, counts(5950, 0, 5950));
        let j = e.evaluate_at(600_000, &counts(6000, 0, 6000));
        let obj = &j.get("objectives").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(
            obj.get("latency").unwrap().get("status").and_then(Json::as_str),
            Some("ok")
        );
        assert_eq!(
            obj.get("errors").unwrap().get("status").and_then(Json::as_str),
            Some("page")
        );
        assert_eq!(obj.get("status").and_then(Json::as_str), Some("page"));
    }

    #[test]
    fn recovery_clears_paging_flag() {
        let e = engine(1);
        e.push_snap(0, counts(0, 0, 0));
        e.evaluate_at(90, &counts(100, 100, 0));
        assert!(e.is_paging());
        // later: plenty of fresh, fast traffic dilutes every window
        e.push_snap(100, counts(100, 100, 0));
        e.evaluate_at(200, &counts(10_100, 100, 0));
        assert!(!e.is_paging());
    }

    #[test]
    fn snapshot_history_is_bounded() {
        let e = engine(1); // warn_long = 500ms
        for t in 0..10_000u64 {
            e.push_snap(t * 10, counts(t, 0, 0));
        }
        let n = e.snaps.lock().unwrap().len();
        assert!(n <= MAX_SNAPS, "snaps {n}");
        assert!(n < 200, "pruning by window must keep the ring small, got {n}");
    }

    #[test]
    fn slow_count_respects_threshold() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1..100 ms
        }
        let slow = slow_count(&h, 0.050);
        // exact boundary is bucket-quantised: allow one bucket of slack
        assert!((45..=55).contains(&(slow as i64)), "slow {slow}");
        assert_eq!(slow_count(&h, 10.0), 0);
        assert_eq!(slow_count(&h, 1e-9), 100);
    }

    #[test]
    fn flag_and_json_roundtrip() {
        let o = SloObjective::parse_flag("all:250:0.99:0.999").unwrap();
        assert_eq!(o, objective("all"));
        assert_eq!(SloObjective::from_json(&o.to_json()).unwrap(), o);
        for bad in [
            "all:250:0.99",          // missing field
            "all:zero:0.99:0.999",   // bad number
            "all:250:1.5:0.999",     // target out of range
            "all:-1:0.99:0.999",     // negative threshold
            ":250:0.99:0.999",       // empty route
            "a b:250:0.99:0.999",    // bad route token
        ] {
            assert!(SloObjective::parse_flag(bad).is_err(), "{bad:?}");
        }
        let s = SloSettings {
            window_s: 60,
            tick_ms: 50,
            objectives: vec![objective("all"), objective("scope")],
        };
        let parsed = SloSettings::from_json(&SloSettings::default(), &s.to_json()).unwrap();
        assert_eq!(parsed, s);
        let bad = Json::obj(vec![("window_s", Json::Str("x".into()))]);
        assert!(SloSettings::from_json(&SloSettings::default(), &bad).is_err());
    }
}
