"""L1 Pallas kernel: the MSET2 similarity matrix.

This is the paper's computational hot-spot — the "non-linear matrix binary
operation" that the NVIDIA authors decomposed over CUDA grid/block/warp/
thread (paper Fig. 3). The TPU re-think (DESIGN.md §7) replaces the warp-
level dot products with a single **MXU matmul per tile** via the Gram
identity ‖a−b‖² = ‖a‖² + ‖b‖² − 2aᵀb, followed by a VPU element-wise
epilogue evaluating the reciprocal kernel — all fused in one Pallas kernel
so the distance matrix never round-trips to HBM.

Tiling: the output (m × B) is blocked (TM × TB); each grid step loads a
(TM × n) strip of D and a (TB × n) strip of X into VMEM. With the default
TM=128, TB=128 and n ≤ 512 the working set is
  (128·512 + 128·512 + 128·128) · 4 B ≈ 580 KiB « 16 MiB VMEM,
leaving headroom for double buffering. ``interpret=True`` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls; real-TPU numbers are
estimated analytically in EXPERIMENTS.md §Perf.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(size, pref):
    """Largest divisor of ``size`` that is ≤ ``pref`` (grid must divide)."""
    t = math.gcd(size, pref)
    if t == 0:
        return 1
    # gcd may be small for odd sizes; fall back to the full size when the
    # preferred tile does not divide (keeps the kernel correct for any m).
    return t if size % t == 0 and t > 1 else (pref if size % pref == 0 else size)


def _sim_kernel(bw_ref, d_ref, x_ref, o_ref):
    """One (TM × TB) output tile of the similarity matrix."""
    d = d_ref[...]                      # (TM, n) VMEM strip of memory matrix
    x = x_ref[...]                      # (TB, n) VMEM strip of observations
    # MXU: cross = d @ x.T with f32 accumulation.
    cross = jax.lax.dot_general(
        d, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                   # (TM, TB)
    dn = jnp.sum(d * d, axis=1, keepdims=True)   # (TM, 1)
    xn = jnp.sum(x * x, axis=1)[None, :]         # (1, TB)
    d2 = jnp.maximum(dn + xn - 2.0 * cross, 0.0)
    # VPU epilogue: reciprocal similarity, fused — no HBM round-trip for d2.
    o_ref[...] = 1.0 / (1.0 + jnp.sqrt(d2) / bw_ref[0])


@functools.partial(jax.jit, static_argnames=("tm", "tb"))
def sim_pallas(d, x, bw, tm=128, tb=128):
    """Pallas similarity: K[i, b] = s(D[i], X[b]).

    d: (m, n) f32, x: (B, n) f32, bw: (1,) f32 scalar bandwidth.
    Returns (m, B) f32. Matches ``ref.sim_cross`` to f32 rounding.
    """
    m, n = d.shape
    b, n2 = x.shape
    assert n == n2, f"signal mismatch {n} vs {n2}"
    tm = _tile(m, tm)
    tb = _tile(b, tb)
    grid = (m // tm, b // tb)
    return pl.pallas_call(
        _sim_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),            # bw: broadcast
            pl.BlockSpec((tm, n), lambda i, j: (i, 0)),       # D strip
            pl.BlockSpec((tb, n), lambda i, j: (j, 0)),       # X strip
        ],
        out_specs=pl.BlockSpec((tm, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, b), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(bw, d, x)


def vmem_bytes(tm, tb, n, dtype_bytes=4):
    """VMEM working-set estimate for one grid step (perf analysis)."""
    return (tm * n + tb * n + tm * tb + tm + tb) * dtype_bytes


def mxu_flops(m, b, n):
    """FLOPs of the matmul portion (what the MXU executes)."""
    return 2.0 * m * b * n
