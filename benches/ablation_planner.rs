//! **ABL-4**: adaptive sweep planner vs the exhaustive fixed-trials sweep.
//!
//! Runs the seed sweep grid twice on the native backend:
//!
//! 1. **exhaustive** — the paper-faithful nested loop, `trials` per cell,
//!    run twice to demonstrate that the fixed seed reproduces the same
//!    deterministic trial schedule (cells, gaps, per-cell trial counts —
//!    wall-clock timings naturally jitter);
//! 2. **adaptive** — the planner (`ci_target > 0`) with the same per-cell
//!    cap, pilot trials, CI-targeted allocation and surface-model pruning.
//!
//! Asserts the planner completes the grid with **≥30% fewer total trials**
//! while recommending the *same cloud shape* for each reference use case —
//! the equal-output-for-less-work claim of the adaptive sweep.
//!
//! Output: `results/ablation_planner.csv`. `--quick` (or
//! `CS_BENCH_QUICK=1`) shrinks the grid.

use containerstress::bench::figs;
use containerstress::coordinator::{run_sweep, Backend, SweepResult, SweepSpec};
use containerstress::recommend::{recommend_from_sweep, Sla};
use containerstress::report;
use containerstress::shapes::Workload;
use containerstress::util::json::Json;

/// The seed sweep grid (native backend; no artifacts required).
fn seed_grid() -> SweepSpec {
    let quick = figs::quick();
    SweepSpec {
        signals: if quick {
            vec![2, 3, 4]
        } else {
            vec![2, 3, 4, 6]
        },
        memvecs: vec![8, 12, 16, 24],
        obs: if quick {
            vec![64, 128]
        } else {
            vec![64, 128, 256]
        },
        trials: 6,
        seed: 41,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    }
}

fn trial_counts(res: &SweepResult) -> Vec<(String, usize)> {
    res.cells
        .iter()
        .map(|c| {
            (
                format!("{}/{}/{}", c.key.n, c.key.m, c.key.obs),
                c.train.as_ref().map(|s| s.n).unwrap_or(0),
            )
        })
        .collect()
}

fn chosen_shapes(res: &SweepResult, cases: &[(&str, Workload)]) -> Vec<(String, String)> {
    cases
        .iter()
        .map(|(name, wl)| {
            let rec = recommend_from_sweep(res, wl, &Sla::default()).expect("recommend");
            let shape = rec
                .chosen_shape()
                .map(|a| a.shape.name.to_string())
                .unwrap_or_else(|| "<none feasible>".into());
            (name.to_string(), shape)
        })
        .collect()
}

fn main() {
    containerstress::util::logger::init();
    let exhaustive = seed_grid();
    let adaptive = SweepSpec {
        pilot_trials: 2,
        ci_target: 0.5,
        max_trials: exhaustive.trials,
        interpolate: true,
        ..seed_grid()
    };
    let cells = exhaustive.signals.len() * exhaustive.memvecs.len() * exhaustive.obs.len();
    println!(
        "ablation_planner: {} cells, exhaustive {} trials/cell vs adaptive \
         pilot={} ci_target={} max={}",
        cells, exhaustive.trials, adaptive.pilot_trials, adaptive.ci_target, adaptive.max_trials
    );

    // --- exhaustive mode: deterministic schedule under the fixed seed -----
    let t0 = std::time::Instant::now();
    let ex1 = run_sweep(&exhaustive, Backend::Native).expect("exhaustive sweep");
    let wall_ex = t0.elapsed().as_secs_f64();
    let ex2 = run_sweep(&exhaustive, Backend::Native).expect("exhaustive sweep (repeat)");
    assert_eq!(
        ex1.gap_cells(),
        ex2.gap_cells(),
        "fixed seed must reproduce the gap structure"
    );
    assert_eq!(
        trial_counts(&ex1),
        trial_counts(&ex2),
        "fixed seed must reproduce the per-cell trial schedule bit-for-bit"
    );
    assert_eq!(ex1.interpolated_cells(), 0, "exhaustive mode never interpolates");

    // --- adaptive mode ----------------------------------------------------
    let t1 = std::time::Instant::now();
    let ad = run_sweep(&adaptive, Backend::Native).expect("adaptive sweep");
    let wall_ad = t1.elapsed().as_secs_f64();

    let t_ex = ex1.total_trials();
    let t_ad = ad.total_trials();
    let reduction = 1.0 - t_ad as f64 / t_ex as f64;
    println!(
        "{:<12} {:>12} {:>10} {:>14} {:>10}",
        "mode", "total_trials", "wall_s", "interpolated", "measured"
    );
    println!(
        "{:<12} {:>12} {:>10.3} {:>14} {:>10}",
        "exhaustive",
        t_ex,
        wall_ex,
        ex1.interpolated_cells(),
        ex1.measured_cells()
    );
    println!(
        "{:<12} {:>12} {:>10.3} {:>14} {:>10}",
        "adaptive",
        t_ad,
        wall_ad,
        ad.interpolated_cells(),
        ad.measured_cells()
    );
    println!(
        "trial reduction: {:.1}% (wall-clock {:.1}%)",
        reduction * 100.0,
        (1.0 - wall_ad / wall_ex) * 100.0
    );

    // --- equal recommendation output at lower cost ------------------------
    let cases = [
        (
            "aviation (customer A)",
            Workload::customer_a(),
        ),
        (
            "datacenter",
            Workload {
                n_signals: 16,
                n_memvec: 24,
                obs_per_sec: 10.0,
                train_window: 256,
            },
        ),
    ];
    let shapes_ex = chosen_shapes(&ex1, &cases);
    let shapes_ad = chosen_shapes(&ad, &cases);
    for ((name, se), (_, sa)) in shapes_ex.iter().zip(&shapes_ad) {
        println!("use case {name:<22} exhaustive → {se:<18} adaptive → {sa}");
    }
    assert_eq!(
        shapes_ex, shapes_ad,
        "the recommended shape per use case must be unchanged under the planner"
    );
    assert!(
        reduction >= 0.30,
        "adaptive planner must save ≥30% of trials (got {:.1}%: {t_ad}/{t_ex})",
        reduction * 100.0
    );

    let mut csv = String::from("mode,total_trials,wall_s,interpolated_cells,measured_cells\n");
    csv.push_str(&format!(
        "exhaustive,{},{:.6},{},{}\n",
        t_ex,
        wall_ex,
        ex1.interpolated_cells(),
        ex1.measured_cells()
    ));
    csv.push_str(&format!(
        "adaptive,{},{:.6},{},{}\n",
        t_ad,
        wall_ad,
        ad.interpolated_cells(),
        ad.measured_cells()
    ));
    report::write(std::path::Path::new("results"), "ablation_planner.csv", &csv).unwrap();
    let json = Json::obj(vec![
        ("bench", Json::Str("ablation_planner".into())),
        ("exhaustive_trials", Json::Num(t_ex as f64)),
        ("adaptive_trials", Json::Num(t_ad as f64)),
        ("trial_reduction", Json::Num(reduction)),
        ("wall_exhaustive_s", Json::Num(wall_ex)),
        ("wall_adaptive_s", Json::Num(wall_ad)),
        ("interpolated_cells", Json::Num(ad.interpolated_cells() as f64)),
        ("measured_cells", Json::Num(ad.measured_cells() as f64)),
    ]);
    report::write(
        std::path::Path::new("results"),
        "BENCH_planner.json",
        &json.to_pretty(),
    )
    .unwrap();
    println!("ablation_planner done → results/ablation_planner.csv, results/BENCH_planner.json");
}
