//! Property tests for the streaming JSON wire layer
//! (`util::json::stream`), driven by the in-repo `util::prop` harness:
//!
//! - **emitter ≡ batch**: [`StreamEmitter`] output, drained at random
//!   points, is byte-identical to [`Json::to_string`] of the same tree;
//! - **emit → parse roundtrip**: what the emitter writes, both parsers
//!   read back to the original tree;
//! - **chunking invariance**: [`StreamParser`] reassembles the same tree
//!   from any chunking of the serialised bytes, including byte-at-a-time.
//!
//! Replay failures with `CONTAINERSTRESS_PROP_SEED=<seed>`.

use containerstress::util::json::stream::{parse_chunks, Limits, StreamEmitter};
use containerstress::util::json::Json;
use containerstress::util::prop::forall_res;
use containerstress::util::rng::Rng;
use std::collections::BTreeMap;

/// Characters chosen to exercise every escape path: quotes, backslashes,
/// control characters, multi-byte UTF-8, and an astral-plane code point
/// (surrogate-pair escapes on the wire).
const STRING_ALPHABET: &[char] = &[
    'a', 'b', 'z', '0', ' ', '"', '\\', '/', '\n', '\t', '\r', '\u{0}', '\u{1f}', 'é', 'И',
    '中', '😀',
];

fn gen_string(rng: &mut Rng) -> String {
    let len = rng.range_usize(0, 12);
    (0..len)
        .map(|_| STRING_ALPHABET[rng.range_usize(0, STRING_ALPHABET.len())])
        .collect()
}

/// Finite numbers only (JSON has no NaN/Inf); mixes integers, decimals
/// and large/small magnitudes so formatting is exercised broadly.
fn gen_num(rng: &mut Rng) -> f64 {
    match rng.below(5) {
        0 => rng.below(1000) as f64,
        1 => -(rng.below(1000) as f64),
        2 => rng.below(1 << 20) as f64 / 1024.0,
        3 => rng.below(1000) as f64 * 1e12,
        _ => -(rng.below(1_000_000) as f64) * 1e-9,
    }
}

fn gen_json(rng: &mut Rng, depth: usize) -> Json {
    let max = if depth == 0 { 4 } else { 6 };
    match rng.below(max) {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num(gen_num(rng)),
        3 => Json::Str(gen_string(rng)),
        4 => {
            let n = rng.range_usize(0, 5);
            Json::Arr((0..n).map(|_| gen_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.range_usize(0, 5);
            let mut m = BTreeMap::new();
            for _ in 0..n {
                m.insert(gen_string(rng), gen_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

/// Walk `v` through the emitter's structural API, draining the buffer at
/// pseudo-random points to prove drains never corrupt the byte stream.
fn emit_tree(em: &mut StreamEmitter, v: &Json, rng: &mut Rng, out: &mut String) {
    match v {
        Json::Null => em.push_null(),
        Json::Bool(b) => em.push_bool(*b),
        Json::Num(x) => em.push_num(*x),
        Json::Str(s) => em.push_str(s),
        Json::Arr(items) => {
            em.begin_arr();
            for item in items {
                emit_tree(em, item, rng, out);
            }
            em.end_arr();
        }
        Json::Obj(m) => {
            em.begin_obj();
            for (k, val) in m {
                em.key(k);
                emit_tree(em, val, rng, out);
            }
            em.end_obj();
        }
    }
    if rng.below(3) == 0 {
        out.push_str(&em.take());
    }
}

/// Split `bytes` at `cuts` random boundaries (possibly duplicated — empty
/// chunks are legal on the wire and must be no-ops).
fn random_chunks<'a>(bytes: &'a [u8], rng: &mut Rng) -> Vec<&'a [u8]> {
    if bytes.is_empty() {
        return vec![bytes];
    }
    let mut cuts: Vec<usize> = (0..rng.range_usize(0, 8))
        .map(|_| rng.range_usize(0, bytes.len() + 1))
        .collect();
    cuts.push(0);
    cuts.push(bytes.len());
    cuts.sort_unstable();
    cuts.windows(2).map(|w| &bytes[w[0]..w[1]]).collect()
}

#[test]
fn emitter_is_byte_identical_to_batch_serialisation() {
    forall_res(
        "StreamEmitter ≡ Json::to_string",
        300,
        |rng| {
            let tree = gen_json(rng, 4);
            (tree, rng.next_u64())
        },
        |(tree, drain_seed)| {
            let mut em = StreamEmitter::new();
            let mut drains = Rng::new(*drain_seed);
            let mut out = String::new();
            emit_tree(&mut em, tree, &mut drains, &mut out);
            out.push_str(&em.take());
            let batch = tree.to_string();
            if out != batch {
                return Err(format!("emitter: {out:?}\nbatch:   {batch:?}"));
            }
            if em.depth() != 0 || em.buffered() != 0 {
                return Err("emitter not drained/balanced at end".into());
            }
            Ok(())
        },
    );
}

#[test]
fn emit_then_parse_roundtrips() {
    forall_res(
        "emit → parse roundtrip",
        300,
        |rng| gen_json(rng, 4),
        |tree| {
            let wire = tree.to_string();
            let batch = Json::parse(&wire)
                .map_err(|e| format!("batch parser rejected emitter output: {e}"))?;
            if &batch != tree {
                return Err(format!("batch roundtrip changed value: {wire:?}"));
            }
            let streamed = parse_chunks(&[wire.as_bytes()], Limits::lenient())
                .map_err(|e| format!("stream parser rejected emitter output: {e}"))?;
            if &streamed != tree {
                return Err(format!("stream roundtrip changed value: {wire:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn reassembly_is_invariant_under_arbitrary_chunking() {
    forall_res(
        "chunking invariance",
        300,
        |rng| {
            let tree = gen_json(rng, 4);
            (tree, rng.next_u64())
        },
        |(tree, chunk_seed)| {
            let wire = tree.to_string();
            let bytes = wire.as_bytes();
            let mut rng = Rng::new(*chunk_seed);
            let chunks = random_chunks(bytes, &mut rng);
            let got = parse_chunks(&chunks, Limits::lenient())
                .map_err(|e| format!("rejected under chunking {chunks:?}: {e}"))?;
            if &got != tree {
                return Err("random chunking changed the parsed value".into());
            }
            let singles: Vec<&[u8]> = bytes.chunks(1).collect();
            let got = parse_chunks(&singles, Limits::lenient())
                .map_err(|e| format!("rejected byte-at-a-time: {e}"))?;
            if &got != tree {
                return Err("byte-at-a-time chunking changed the parsed value".into());
            }
            Ok(())
        },
    );
}
