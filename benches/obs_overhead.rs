//! **BENCH-obs**: flight-recorder overhead on the native trial hot path.
//!
//! The observability layer must be effectively free: every hot-path
//! instrumentation point is a thread-local read plus a branch when no
//! recorder is installed, and a clock read plus a ring push when one is.
//! Two gates, enforced with asserts so CI catches regressions:
//!
//! 1. **End-to-end overhead** — an instrumented native sweep (recorder
//!    installed on the driving thread, spans recorded per trial phase)
//!    is ≤ 5% slower than a telemetry-disabled twin of the same sweep.
//! 2. **Non-vacuity** — the instrumented twin really records spans (a
//!    timeline with train/surveil phases), so gate 1 measures live
//!    instrumentation, not a dead branch.
//! 3. **Journal overhead** — a third twin with the durable telemetry
//!    journal attached (every retired span serialized + appended,
//!    fsync=never) stays under the same ≤ 5% ceiling.
//!
//! Micro costs (span push, disabled-path probe) are reported unasserted.
//!
//! Output: `results/BENCH_obs.json` + `results/obs_overhead.csv`.
//! `CS_BENCH_QUICK=1` shortens the measuring windows but keeps every
//! asserted point.

use containerstress::bench::{black_box, figs, table, write_csv, Bencher, Measurement};
use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::obs::journal::{Journal, JournalConfig};
use containerstress::obs::{self, FlightRecorder};
use containerstress::report;
use containerstress::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One surveillance-heavy cell, a few trials: seconds-scale per sweep in
/// full mode, tens of milliseconds in quick mode — long enough that the
/// per-trial span pushes (microseconds) are measurable only if they are
/// actually expensive.
fn hotpath_spec(quick: bool) -> SweepSpec {
    SweepSpec {
        signals: vec![8],
        memvecs: vec![32],
        obs: vec![if quick { 1024 } else { 4096 }],
        trials: 2,
        seed: 11,
        workers: 2,
        ..SweepSpec::default()
    }
}

fn main() {
    containerstress::util::logger::init();
    let quick = figs::quick();
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    const MAX_OVERHEAD_RATIO: f64 = 1.05; // instrumented / disabled medians

    let spec = hotpath_spec(quick);

    // Non-vacuity first: one instrumented sweep must produce a real
    // timeline (per-trial train/surveil spans) through the same plumbing
    // the service uses — otherwise the overhead gate measures nothing.
    let probe = Arc::new(FlightRecorder::new("bench-obs"));
    {
        let _g = obs::install(Some(Arc::clone(&probe)));
        run_sweep(&spec, Backend::Native).expect("probe sweep");
    }
    let spans = probe.snapshot();
    assert!(
        spans.iter().any(|s| s.phase == "train") && spans.iter().any(|s| s.phase == "surveil"),
        "instrumented sweep recorded no train/surveil spans — overhead gate would be vacuous"
    );

    // --- the twin sweeps --------------------------------------------------
    let disabled = b.run("sweep_telemetry_disabled", || {
        // No recorder on this thread: every instrumentation point is the
        // thread-local read + branch that plain CLI sweeps pay.
        black_box(run_sweep(&spec, Backend::Native).expect("sweep"))
    });
    let instrumented = b.run("sweep_telemetry_instrumented", || {
        let rec = Arc::new(FlightRecorder::new("bench-obs"));
        let _g = obs::install(Some(rec));
        black_box(run_sweep(&spec, Backend::Native).expect("sweep"))
    });
    let overhead_ratio = instrumented.stats.median / disabled.stats.median;
    println!(
        "native sweep: disabled {:.4}s, instrumented {:.4}s → ratio {overhead_ratio:.4} \
         (ceiling {MAX_OVERHEAD_RATIO})",
        disabled.stats.median, instrumented.stats.median
    );
    assert!(
        overhead_ratio <= MAX_OVERHEAD_RATIO,
        "flight-recorder instrumentation costs {:.1}% on the native trial hot path \
         (budget 5%)",
        (overhead_ratio - 1.0) * 100.0
    );

    // --- journal-enabled twin ---------------------------------------------
    // Same instrumented sweep, but with the global sink's durable journal
    // attached: each retired span is serialized and appended (buffered
    // writes, fsync=never — the production default).
    let jdir = std::env::temp_dir().join(format!("cs-bench-journal-{}", std::process::id()));
    let journal =
        Arc::new(Journal::open(JournalConfig::new(jdir.clone())).expect("open bench journal"));
    obs::sink().set_journal(Some(Arc::clone(&journal)));
    let journal_on = b.run("sweep_telemetry_journaled", || {
        let rec = Arc::new(FlightRecorder::new("bench-obs"));
        let _g = obs::install(Some(rec));
        black_box(run_sweep(&spec, Backend::Native).expect("sweep"))
    });
    obs::sink().set_journal(None);
    journal.flush();
    assert!(
        journal.appended() > 0,
        "journaled twin appended no records — journal gate would be vacuous"
    );
    let _ = std::fs::remove_dir_all(&jdir);
    let journal_ratio = journal_on.stats.median / disabled.stats.median;
    println!(
        "native sweep with journal: {:.4}s → ratio {journal_ratio:.4} \
         (ceiling {MAX_OVERHEAD_RATIO})",
        journal_on.stats.median
    );
    assert!(
        journal_ratio <= MAX_OVERHEAD_RATIO,
        "journal-enabled telemetry costs {:.1}% on the native trial hot path (budget 5%)",
        (journal_ratio - 1.0) * 100.0
    );

    // --- micro costs (reported, not asserted) -----------------------------
    let rec = FlightRecorder::new("micro");
    let t0 = Instant::now();
    let push = b.run_with_units("span_push", 1.0, || {
        rec.push(
            "trial",
            "train",
            t0,
            t0 + Duration::from_micros(5),
            Duration::ZERO,
            String::new(),
        )
    });
    let probe_off = b.run_with_units("current_when_disabled", 1.0, || black_box(obs::current()));

    // --- emit artifacts ---------------------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::Str("obs_overhead".into())),
        ("quick", Json::Bool(quick)),
        (
            "sweep",
            Json::obj(vec![
                ("n", Json::Num(spec.signals[0] as f64)),
                ("m", Json::Num(spec.memvecs[0] as f64)),
                ("obs", Json::Num(spec.obs[0] as f64)),
                ("trials", Json::Num(spec.trials as f64)),
                ("disabled_s", Json::Num(disabled.stats.median)),
                ("instrumented_s", Json::Num(instrumented.stats.median)),
                ("journal_on_s", Json::Num(journal_on.stats.median)),
                ("overhead_ratio", Json::Num(overhead_ratio)),
                ("journal_overhead_ratio", Json::Num(journal_ratio)),
            ]),
        ),
        (
            "micro",
            Json::obj(vec![
                ("span_push_s", Json::Num(push.stats.median)),
                ("current_probe_s", Json::Num(probe_off.stats.median)),
                ("probe_spans_recorded", Json::Num(spans.len() as f64)),
            ]),
        ),
        (
            "asserted",
            Json::obj(vec![
                ("max_overhead_ratio", Json::Num(MAX_OVERHEAD_RATIO)),
                ("overhead_ratio", Json::Num(overhead_ratio)),
                ("journal_on", Json::Num(journal_ratio)),
            ]),
        ),
    ]);
    let ms: Vec<Measurement> = vec![disabled, instrumented, journal_on, push, probe_off];
    let dir = std::path::Path::new("results");
    report::write(dir, "BENCH_obs.json", &json.to_pretty()).unwrap();
    println!("{}", table(&ms));
    write_csv("results/obs_overhead.csv", &ms).unwrap();
    println!("obs_overhead done → results/BENCH_obs.json, results/obs_overhead.csv");
}
