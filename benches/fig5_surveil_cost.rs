//! **Fig. 5 (a)–(d)**: 3-D surveillance compute-cost contours vs (number of
//! memory vectors × number of streamed observations), one panel per signal
//! count. Expected shape: cost scales ~linearly with `n_obs` and strongly
//! with signals/memvecs — the paper's §III.A surveillance conclusion.
//!
//! Output: `results/fig5_surveil_cost/`.

use containerstress::bench::figs;
use containerstress::report;
use containerstress::surface::{ResponseSurface, Sample, SurfaceGrid};
use std::path::Path;

fn main() {
    containerstress::util::logger::init();
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (signals, memvecs) = figs::available_axes(&handle);
    let trials = if figs::quick() { 1 } else { 3 };
    let obs_axis: Vec<usize> = if figs::quick() {
        vec![128, 512]
    } else {
        vec![128, 512, 2048, 8192]
    };
    let out = Path::new("results/fig5_surveil_cost");
    println!(
        "fig5: panels(signals)={signals:?}, memvecs={memvecs:?}, obs={obs_axis:?}, {trials} trials"
    );

    let mut samples = Vec::new();
    for (pi, &n) in signals.iter().enumerate() {
        let mut grid = SurfaceGrid::new(
            "n_memvec",
            "n_obs",
            memvecs.iter().map(|&v| v as f64).collect(),
            obs_axis.iter().map(|&v| v as f64).collect(),
        );
        for (r, &m) in memvecs.iter().enumerate() {
            if m < 2 * n {
                continue;
            }
            for (c, &obs) in obs_axis.iter().enumerate() {
                let ts = figs::measure_surveil(&handle, n, m, obs, trials);
                let med = figs::median(&ts);
                grid.set(r, c, med);
                samples.push(Sample {
                    n_signals: n,
                    n_memvec: m,
                    n_obs: obs,
                    cost: med,
                });
            }
        }
        let panel = (b'a' + pi as u8) as char;
        let ascii = report::emit_figure(
            out,
            &format!("fig5{panel}_n{n}"),
            &format!("Fig5({panel}): surveillance cost, {n} signals"),
            &grid,
            "surveil_cost_s",
            false,
        )
        .expect("emit");
        println!("{ascii}");
    }

    let surf = ResponseSurface::fit(&samples).expect("fit");
    let e = surf.exponents();
    println!(
        "surveillance-cost surface: r²={:.3}, exponents (n, m, obs) = {:?}",
        surf.r2,
        e.map(|x| (x * 1000.0).round() / 1000.0)
    );
    assert!(
        e[2] > 0.5,
        "paper conclusion: surveillance cost must scale with n_obs (exp {})",
        e[2]
    );
    println!("fig5 done → {}", out.display());
}
