//! **SENS-T / SENS-S** (§III.A conclusions): sensitivity of compute cost
//! to the three ML design parameters, measured through the device path.
//!
//! Paper: "the compute cost of Training process primarily depends very
//! sensitively on the number of memory vectors and number of signals";
//! "the compute cost of streaming surveillance primarily depends on the
//! number of observations and signals". Both are asserted here from the
//! fitted response-surface exponents.
//!
//! Output: `results/sensitivity/`.

use containerstress::bench::figs;
use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::report;
use containerstress::surface::ResponseSurface;
use std::path::Path;

fn main() {
    containerstress::util::logger::init();
    let server = figs::device_or_exit();
    let (signals, memvecs) = figs::available_axes(&server.handle());
    let trials = if figs::quick() { 1 } else { 3 };
    let spec = SweepSpec {
        signals,
        memvecs,
        obs: if figs::quick() {
            vec![128, 512]
        } else {
            vec![128, 512, 2048]
        },
        trials,
        seed: 99,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    };
    let result = run_sweep(&spec, Backend::Device(server.handle())).expect("sweep");
    let out = Path::new("results/sensitivity");
    report::write(out, "sweep.csv", &report::sweep_csv(&result)).unwrap();

    let train = ResponseSurface::fit(&result.samples("train")).expect("train fit");
    let surveil = ResponseSurface::fit(&result.samples("surveil")).expect("surveil fit");
    for (phase, surf) in [("train", &train), ("surveil", &surveil)] {
        let table = report::sensitivity_table(&result, phase).unwrap();
        report::write(out, &format!("{phase}.txt"), &table).unwrap();
        println!("{table}");
        println!("  r²={:.3} exponents={:?}", surf.r2, surf.exponents());
    }

    // SENS-T: training — memvecs dominate; near-flat in n_obs (the n and
    // obs exponents are both ≈0 at this scale, so their mutual order is
    // noise — see fig4 bench note).
    let t_rank = train.ranking();
    assert_eq!(
        t_rank[0].0, "n_memvec",
        "training must be dominated by memvecs: {t_rank:?}"
    );
    assert!(
        train.exponents()[2].abs() < 0.3,
        "training must be near-flat in n_obs: {:?}",
        train.exponents()
    );
    // SENS-S: surveillance — n_obs must be a dominant driver (≈ linear).
    let s_exp = surveil.exponents();
    assert!(
        s_exp[2] > 0.5,
        "surveillance must scale with n_obs: exponents {s_exp:?}"
    );
    println!("sensitivity conclusions reproduced ✓ → {}", out.display());
}
