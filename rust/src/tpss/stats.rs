//! Statistical estimators used to validate synthesized telemetry against
//! its specification (the properties the paper says matter: serial
//! correlation, cross-correlation, variance/skewness/kurtosis).

/// First four sample moments.
#[derive(Clone, Copy, Debug)]
pub struct Moments {
    /// Sample mean.
    pub mean: f64,
    /// Sample variance.
    pub var: f64,
    /// Standardised third moment.
    pub skewness: f64,
    /// Standardised fourth moment (normal = 3).
    pub kurtosis: f64,
}

/// First four standardised moments of a sample (n ≥ 2).
pub fn moments(xs: &[f64]) -> Moments {
    let n = xs.len() as f64;
    assert!(n >= 2.0);
    let mean = xs.iter().sum::<f64>() / n;
    let (mut m2, mut m3, mut m4) = (0.0, 0.0, 0.0);
    for &x in xs {
        let d = x - mean;
        m2 += d * d;
        m3 += d * d * d;
        m4 += d * d * d * d;
    }
    m2 /= n;
    m3 /= n;
    m4 /= n;
    let sd = m2.sqrt();
    Moments {
        mean,
        var: m2,
        skewness: if sd > 0.0 { m3 / (sd * sd * sd) } else { 0.0 },
        kurtosis: if m2 > 0.0 { m4 / (m2 * m2) } else { 0.0 },
    }
}

/// Lag-`k` sample autocorrelation.
pub fn autocorr(xs: &[f64], k: usize) -> f64 {
    assert!(k < xs.len());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let denom: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
    if denom == 0.0 {
        return 0.0;
    }
    let num: f64 = (0..n - k)
        .map(|i| (xs[i] - mean) * (xs[i + k] - mean))
        .sum();
    num / denom
}

/// Pearson correlation between two equal-length series.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut sab, mut saa, mut sbb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        let dx = x - ma;
        let dy = y - mb;
        sab += dx * dy;
        saa += dx * dx;
        sbb += dy * dy;
    }
    if saa == 0.0 || sbb == 0.0 {
        0.0
    } else {
        sab / (saa * sbb).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn moments_of_standard_normal_sample() {
        let mut rng = Rng::new(17);
        let xs: Vec<f64> = (0..100_000).map(|_| rng.gauss()).collect();
        let m = moments(&xs);
        assert!(m.mean.abs() < 0.02);
        assert!((m.var - 1.0).abs() < 0.03);
        assert!(m.skewness.abs() < 0.05);
        assert!((m.kurtosis - 3.0).abs() < 0.1);
    }

    #[test]
    fn autocorr_of_white_noise_near_zero() {
        let mut rng = Rng::new(23);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.gauss()).collect();
        assert!(autocorr(&xs, 1).abs() < 0.02);
        assert!(autocorr(&xs, 5).abs() < 0.02);
    }

    #[test]
    fn autocorr_lag0_is_one() {
        let xs = vec![1.0, 3.0, 2.0, 5.0, 4.0];
        assert!((autocorr(&xs, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b: Vec<f64> = a.iter().map(|x| 2.0 * x + 1.0).collect();
        let c: Vec<f64> = a.iter().map(|x| -x).collect();
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }
}
