//! Cloud container **shape catalog** and per-shape performance/cost model.
//!
//! The paper scopes workloads "across the range of cloud CPU-GPU *Shapes*
//! (configurations of CPUs and/or GPUs in Cloud containers available to end
//! customers)". No cloud is reachable from this environment, so the catalog
//! below plays that role (DESIGN.md §5): an OCI-2019-era set of VM/BM
//! shapes with public core counts, memory sizes and list prices, plus a
//! parametric performance model that rescales costs *measured on the local
//! testbed* to any shape.
//!
//! The model is deliberately simple and monotone — the quantity the scoping
//! framework needs is relative capacity, not cycle-accurate simulation:
//!
//! ```text
//! t_shape = t_measured · (eff_local / eff_shape)
//! eff_shape = cores · clock_ghz · flops_per_cycle · parallel_eff(cores)
//! ```
//!
//! GPU shapes add a V100 term through [`crate::accel`].

pub mod elastic;

/// Processor generation parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CpuSpec {
    /// Physical core count.
    pub cores: usize,
    /// Base clock (GHz).
    pub clock_ghz: f64,
    /// Sustained f32 FLOPs per cycle per core (SIMD-aware, derated).
    pub flops_per_cycle: f64,
}

/// One cloud shape ("container configuration").
#[derive(Clone, Debug, PartialEq)]
pub struct Shape {
    /// Catalog name (OCI-style shape id).
    pub name: &'static str,
    /// CPU complement.
    pub cpu: CpuSpec,
    /// Memory capacity (GB).
    pub mem_gb: f64,
    /// V100-class GPUs attached.
    pub gpus: usize,
    /// USD per hour (2019-era list price).
    pub usd_per_hour: f64,
}

impl Shape {
    /// Effective sustained CPU throughput in FLOP/s, with a sublinear
    /// parallel-efficiency derating (memory-bandwidth sharing).
    pub fn cpu_eff_flops(&self) -> f64 {
        let c = self.cpu.cores as f64;
        let parallel_eff = c.powf(0.9) / c; // 90%-scaling rule of thumb
        c * parallel_eff * self.cpu.clock_ghz * 1e9 * self.cpu.flops_per_cycle
    }

    /// Whether the shape carries GPUs.
    pub fn has_gpu(&self) -> bool {
        self.gpus > 0
    }
}

/// 2019-era Oracle-cloud-like catalog (Intel Xeon Platinum "Standard2"
/// CPU shapes; "GPU3" = V100 shapes). Built once and cached in a
/// [`std::sync::OnceLock`]: the catalog is consulted from per-trial hot
/// paths (capacity lookups, recommendation assessment, elasticity
/// simulation), where rebuilding the `Vec` on every call was pure waste.
pub fn catalog() -> &'static [Shape] {
    static CATALOG: std::sync::OnceLock<Vec<Shape>> = std::sync::OnceLock::new();
    CATALOG.get_or_init(|| {
        let xeon = |cores| CpuSpec {
            cores,
            clock_ghz: 2.0,
            // AVX-512 peak is 64 f32 FLOP/cycle; sustained dense-kernel
            // reality is far lower — 8 keeps the model honest for mixed
            // workloads.
            flops_per_cycle: 8.0,
        };
        vec![
            Shape { name: "VM.Standard2.1",  cpu: xeon(1),  mem_gb: 15.0,  gpus: 0, usd_per_hour: 0.0638 },
            Shape { name: "VM.Standard2.2",  cpu: xeon(2),  mem_gb: 30.0,  gpus: 0, usd_per_hour: 0.1276 },
            Shape { name: "VM.Standard2.4",  cpu: xeon(4),  mem_gb: 60.0,  gpus: 0, usd_per_hour: 0.2552 },
            Shape { name: "VM.Standard2.8",  cpu: xeon(8),  mem_gb: 120.0, gpus: 0, usd_per_hour: 0.5104 },
            Shape { name: "VM.Standard2.16", cpu: xeon(16), mem_gb: 240.0, gpus: 0, usd_per_hour: 1.0208 },
            Shape { name: "VM.Standard2.24", cpu: xeon(24), mem_gb: 320.0, gpus: 0, usd_per_hour: 1.5312 },
            Shape { name: "BM.Standard2.52", cpu: xeon(52), mem_gb: 768.0, gpus: 0, usd_per_hour: 3.3176 },
            Shape { name: "VM.GPU3.1", cpu: xeon(6),  mem_gb: 90.0,  gpus: 1, usd_per_hour: 2.95 },
            Shape { name: "VM.GPU3.2", cpu: xeon(12), mem_gb: 180.0, gpus: 2, usd_per_hour: 5.90 },
            Shape { name: "VM.GPU3.4", cpu: xeon(24), mem_gb: 360.0, gpus: 4, usd_per_hour: 11.80 },
            Shape { name: "BM.GPU3.8", cpu: xeon(52), mem_gb: 768.0, gpus: 8, usd_per_hour: 23.60 },
        ]
    })
}

/// Find a shape by name.
pub fn by_name(name: &str) -> Option<Shape> {
    catalog().iter().find(|s| s.name == name).cloned()
}

/// Capacity of a shape in core-equivalents, relative to the catalog's
/// 1-core reference shape — the demand unit of the elasticity and fleet
/// scenario simulators.
pub fn capacity_core_eq(shape: &Shape) -> f64 {
    let base = catalog()[0].cpu_eff_flops();
    shape.cpu_eff_flops() / base
}

/// CPU-only shape ladder sorted by capacity ascending — the migration
/// path autoscaling policies climb. Cached like [`catalog`].
pub fn cpu_ladder() -> &'static [Shape] {
    static LADDER: std::sync::OnceLock<Vec<Shape>> = std::sync::OnceLock::new();
    LADDER.get_or_init(|| {
        let mut v: Vec<Shape> = catalog().iter().filter(|s| !s.has_gpu()).cloned().collect();
        v.sort_by(|a, b| capacity_core_eq(a).partial_cmp(&capacity_core_eq(b)).unwrap());
        v
    })
}

/// MSET2 container memory-footprint model (bytes): memory matrix D, trained
/// inverse G, per-chunk buffers, plus the training window held during
/// training. This gates which shapes a use case fits on.
pub fn mset_footprint_bytes(n: usize, m: usize, chunk: usize, train_window: usize) -> usize {
    let f = 4usize; // f32 device tensors
    let d = m * n * f;
    let g = m * m * f;
    let sim = m * m * f; // similarity scratch during training
    let chunk_bufs = 3 * chunk * n * f + m * chunk * f;
    let window = train_window * n * f;
    // ×2 head-room for allocator slack and the runtime itself
    2 * (d + g + sim + chunk_bufs + window)
}

/// Workload definition used for shape scoping (engineering units).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Number of telemetry signals.
    pub n_signals: usize,
    /// Memory vectors the model will be sized with.
    pub n_memvec: usize,
    /// Observations per second arriving for surveillance.
    pub obs_per_sec: f64,
    /// Training-window length (observations).
    pub train_window: usize,
}

impl Workload {
    /// Paper example: "Customer A … 20 signals, sampled once per hour".
    pub fn customer_a() -> Workload {
        Workload {
            n_signals: 20,
            n_memvec: 64,
            obs_per_sec: 1.0 / 3600.0,
            train_window: 2048,
        }
    }

    /// Paper example: "Customer B … Airbus 320 fleet, 75 000 sensors at
    /// 1 Hz per plane" — scoped per plane partition of 1024-signal groups.
    pub fn customer_b_partition() -> Workload {
        Workload {
            n_signals: 1024,
            n_memvec: 4096,
            obs_per_sec: 1.0,
            train_window: 16384,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_consistent() {
        let shapes = catalog();
        assert!(shapes.len() >= 10);
        for s in shapes {
            assert!(s.cpu.cores > 0 && s.mem_gb > 0.0 && s.usd_per_hour > 0.0);
        }
        // price strictly increases with cores within the Standard2 family
        let std2: Vec<&Shape> = shapes
            .iter()
            .filter(|s| s.name.contains("Standard2"))
            .collect();
        for w in std2.windows(2) {
            assert!(w[1].cpu.cores > w[0].cpu.cores);
            assert!(w[1].usd_per_hour > w[0].usd_per_hour);
        }
    }

    #[test]
    fn eff_flops_monotone_but_sublinear() {
        let s1 = by_name("VM.Standard2.1").unwrap();
        let s16 = by_name("VM.Standard2.16").unwrap();
        let r = s16.cpu_eff_flops() / s1.cpu_eff_flops();
        assert!(r > 8.0 && r < 16.0, "16-core speedup {r} should be sublinear");
    }

    #[test]
    fn footprint_scales_with_m_squared() {
        let small = mset_footprint_bytes(32, 128, 64, 4096);
        let big = mset_footprint_bytes(32, 256, 64, 4096);
        assert!(big > small);
        // G + sim dominate: quadrupling m² terms
        let g_small = 2 * 2 * 128usize.pow(2) * 4;
        let g_big = 2 * 2 * 256usize.pow(2) * 4;
        assert!(big - small >= (g_big - g_small) / 2);
    }

    #[test]
    fn customer_extremes_span_catalog() {
        let a = Workload::customer_a();
        let b = Workload::customer_b_partition();
        let small = mset_footprint_bytes(a.n_signals, a.n_memvec, 64, a.train_window);
        let large = mset_footprint_bytes(b.n_signals, b.n_memvec, 64, b.train_window);
        assert!(small < 100 * 1024 * 1024, "customer A fits in a tiny shape");
        assert!(large > small * 100, "customer B is orders of magnitude bigger");
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("BM.GPU3.8").unwrap().has_gpu());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn catalog_is_cached_static() {
        // OnceLock: repeated calls hand out the same allocation — the
        // per-trial hot paths must not rebuild the catalog.
        assert!(std::ptr::eq(catalog(), catalog()));
        assert!(std::ptr::eq(cpu_ladder(), cpu_ladder()));
    }

    #[test]
    fn ladder_is_cpu_only_and_sorted() {
        let ladder = cpu_ladder();
        assert!(ladder.len() >= 5);
        assert!(ladder.iter().all(|s| !s.has_gpu()));
        assert!((capacity_core_eq(&ladder[0]) - 1.0).abs() < 1e-12);
        for w in ladder.windows(2) {
            assert!(capacity_core_eq(&w[1]) > capacity_core_eq(&w[0]));
            assert!(w[1].usd_per_hour > w[0].usd_per_hour, "price follows capacity");
        }
    }
}
