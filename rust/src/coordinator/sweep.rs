//! Nested-loop Monte Carlo sweep engine.
//!
//! For each grid cell `(n_signals, n_memvec, n_obs)`:
//!
//! 1. the MSET training constraint `m ≥ 2n` is checked — violating cells
//!    become *gaps* (the missing surface regions of paper Fig. 6);
//! 2. `trials` independent trials run, each on a fresh TPSS synthesis
//!    (deterministically seeded per cell/trial, so results are independent
//!    of scheduling order);
//! 3. each trial measures the **training cost** (memory selection + the
//!    training executable) and the **surveillance cost** (streaming
//!    `n_obs` observations through the surveillance executable);
//! 4. per-cell costs are aggregated into robust summaries.
//!
//! Trials are fanned out over the thread pool; device executions serialise
//! on the dedicated PJRT thread (see `runtime`), so measured execution
//! times stay contention-free.

use crate::linalg::Mat;
use crate::metrics::Registry;
use crate::models;
use crate::mset;
use crate::runtime::mset::{DeviceAakr, DeviceMset};
use crate::runtime::DeviceHandle;
use crate::surface::{Sample, SurfaceGrid};
use crate::tpss::{synthesize, TpssConfig};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;
use crate::util::Summary;
use std::collections::HashMap;
use std::time::Instant;

/// Per-trial measured costs of one cell (seconds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CellCosts {
    pub train_s: Vec<f64>,
    pub surveil_s: Vec<f64>,
}

/// A store of per-cell measurements the sweep engine can consult before
/// scheduling trials. Implemented by [`crate::service::cache::SweepCache`];
/// the coordinator only sees this trait, keeping the service a layer above
/// it rather than a dependency of it.
pub trait CellStore: Send + Sync {
    /// Measurements for `cell` under an identical `(spec, backend)`
    /// context, if present.
    fn fetch(&self, cell: CellKey, spec: &SweepSpec, backend: &str) -> Option<CellCosts>;
    /// Record freshly measured trial costs for `cell`.
    fn store(&self, cell: CellKey, spec: &SweepSpec, backend: &str, costs: CellCosts);
}

/// Where trials execute.
#[derive(Clone)]
pub enum Backend {
    /// AOT artifacts through the PJRT device thread (production path).
    Device(DeviceHandle),
    /// Native Rust implementation (comparator / no-artifact fallback).
    Native,
}

impl Backend {
    /// Stable tag used in cache keys and logs.
    pub fn tag(&self) -> &'static str {
        match self {
            Backend::Device(_) => "device",
            Backend::Native => "native",
        }
    }
}

/// Sweep specification (the outer loops of paper Fig. 1).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub signals: Vec<usize>,
    pub memvecs: Vec<usize>,
    pub obs: Vec<usize>,
    /// Monte Carlo trials per cell.
    pub trials: usize,
    pub seed: u64,
    /// Pluggable model: `mset2` | `aakr` | `ridge`.
    pub model: String,
    /// Worker threads for trial fan-out (0 = auto).
    pub workers: usize,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            signals: vec![8, 16],
            memvecs: vec![32, 64],
            obs: vec![256],
            trials: 3,
            seed: 7,
            model: "mset2".into(),
            workers: 0,
        }
    }
}

impl SweepSpec {
    /// Reject specs that cannot run: unknown model, zero trials, or empty
    /// sweep axes (e.g. `"signals": []` in a config file or service
    /// request) — callers get a clean error instead of a downstream panic.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(
                self.model.as_str(),
                "mset2" | "aakr" | "ridge" | "mlp" | "svr"
            ),
            "model must be mset2|aakr|ridge|mlp|svr, got '{}'",
            self.model
        );
        anyhow::ensure!(self.trials >= 1, "trials must be ≥ 1");
        anyhow::ensure!(
            !self.signals.is_empty() && !self.memvecs.is_empty() && !self.obs.is_empty(),
            "sweep axes must be non-empty"
        );
        Ok(())
    }

    /// Whether a cell is a constraint gap (`m < 2n` under MSET training).
    fn is_gap(&self, key: CellKey) -> bool {
        key.m < 2 * key.n && self.model == "mset2"
    }
}

/// One grid-cell coordinate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CellKey {
    pub n: usize,
    pub m: usize,
    pub obs: usize,
}

/// Aggregated measurements for one cell.
#[derive(Clone, Debug)]
pub struct CellMeasure {
    pub key: CellKey,
    /// `None` when the training constraint `m ≥ 2n` is violated (gap).
    pub train: Option<Summary>,
    pub surveil: Option<Summary>,
    pub violated: bool,
}

/// Complete sweep output.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub spec: SweepSpec,
    pub cells: Vec<CellMeasure>,
}

/// Per-trial raw timings.
#[derive(Clone, Copy, Debug)]
struct TrialCost {
    train_s: f64,
    surveil_s: f64,
}

fn run_trial(
    backend: &Backend,
    model_name: &str,
    key: CellKey,
    seed: u64,
) -> anyhow::Result<TrialCost> {
    let CellKey { n, m, obs } = key;
    // Training window: the paper's "number of observations in the training
    // process" is the obs axis for the training phase.
    let train_rows = obs.max(m); // need at least m candidates to select from
    let train_ds = synthesize(&TpssConfig::sized(n, train_rows), seed);
    let probe_ds = synthesize(&TpssConfig::sized(n, obs), seed ^ 0x5EED);

    match backend {
        Backend::Device(handle) => {
            // Selection + scaling are part of the measured training phase
            // (they are training work), then the device executes.
            let t0 = Instant::now();
            let scaler = mset::Scaler::fit(&train_ds.data);
            let xs = scaler.transform(&train_ds.data);
            let idx = mset::select_memory(&xs, m);
            let mut d = Mat::zeros(m, n);
            for (r, &i) in idx.iter().enumerate() {
                d.row_mut(r).copy_from_slice(xs.row(i));
            }
            let prep_s = t0.elapsed().as_secs_f64();
            let probe_scaled = scaler.transform(&probe_ds.data);

            match model_name {
                "mset2" => {
                    let mut sess = DeviceMset::new(handle.clone(), &d)?;
                    let (_, tcost) = sess.train()?;
                    Registry::global().inc("sweep.device.train_calls");
                    let (_, _, scost) = sess.surveil(&probe_scaled)?;
                    Registry::global().add("sweep.device.surveil_calls", scost.calls as u64);
                    Ok(TrialCost {
                        train_s: prep_s + tcost.exec.as_secs_f64(),
                        surveil_s: scost.exec.as_secs_f64(),
                    })
                }
                "aakr" => {
                    let sess = DeviceAakr::new(handle.clone(), &d)?;
                    let (_, _, scost) = sess.surveil(&probe_scaled)?;
                    Ok(TrialCost {
                        train_s: prep_s, // AAKR "training" = selection only
                        surveil_s: scost.exec.as_secs_f64(),
                    })
                }
                other => anyhow::bail!(
                    "model '{other}' has no device artifacts; use --backend native"
                ),
            }
        }
        Backend::Native => {
            let mut plugin = models::by_name(model_name)?;
            let t0 = Instant::now();
            plugin.fit(&train_ds.data, m)?;
            let train_s = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let _est = plugin.estimate(&probe_ds.data);
            let surveil_s = t1.elapsed().as_secs_f64();
            Ok(TrialCost { train_s, surveil_s })
        }
    }
}

/// Trial-seed tag derived from the cell *content*, not its grid position,
/// so a cell's measurements are identical no matter which request's grid it
/// appears in — the property that makes the sweep cache content-addressed.
fn cell_tag(key: CellKey) -> u64 {
    crate::util::fnv1a(format!("{}/{}/{}", key.n, key.m, key.obs).as_bytes())
}

/// Run the full nested-loop Monte Carlo sweep.
pub fn run_sweep(spec: &SweepSpec, backend: Backend) -> anyhow::Result<SweepResult> {
    run_sweep_cached(spec, backend, None)
}

/// [`run_sweep`] with an optional cell-level cache: cells already measured
/// under an identical `(cell, model, seed, backend, trials)` context are
/// reused without scheduling any trials; freshly measured cells are
/// inserted for future requests.
pub fn run_sweep_cached(
    spec: &SweepSpec,
    backend: Backend,
    cache: Option<&dyn CellStore>,
) -> anyhow::Result<SweepResult> {
    spec.validate()?;
    // Duplicate axis values would create duplicate cells (double-counted
    // trials, cache entries violating the trials-per-cell invariant) —
    // measure each distinct cell once.
    let mut keys = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for &n in &spec.signals {
        for &m in &spec.memvecs {
            for &obs in &spec.obs {
                let key = CellKey { n, m, obs };
                if seen.insert(key) {
                    keys.push(key);
                }
            }
        }
    }
    let workers = if spec.workers == 0 {
        crate::util::threadpool::default_workers()
    } else {
        spec.workers
    };
    let root = Rng::new(spec.seed);

    // Probe the cache, then fan out (cell, trial) pairs for the rest;
    // trial seeds are forked from the root per cell tag so results are
    // independent of both scheduling and grid composition.
    let mut cached: HashMap<CellKey, CellCosts> = HashMap::new();
    let mut work = Vec::new();
    for &key in &keys {
        if spec.is_gap(key) {
            continue; // constraint gap — never scheduled
        }
        if let Some(c) = cache {
            if let Some(costs) = c.fetch(key, spec, backend.tag()) {
                cached.insert(key, costs);
                continue;
            }
        }
        for t in 0..spec.trials {
            let seed = root
                .fork(cell_tag(key).wrapping_add(t as u64))
                .next_u64_seed();
            work.push((key, seed));
        }
    }
    log::info!(
        "sweep: {} cells ({} cached) × {} trials, model={}, backend={}, workers={workers}",
        keys.len(),
        cached.len(),
        spec.trials,
        spec.model,
        backend.tag()
    );
    let results = parallel_map(workers, &work, |_, &(key, seed)| {
        let r = run_trial(&backend, &spec.model, key, seed);
        Registry::global().inc("sweep.trials");
        (key, r)
    });

    // Aggregate per cell.
    let mut cells = Vec::new();
    for &key in &keys {
        if spec.is_gap(key) {
            cells.push(CellMeasure {
                key,
                train: None,
                surveil: None,
                violated: true,
            });
            Registry::global().inc("sweep.gap_cells");
            continue;
        }
        if let Some(costs) = cached.get(&key) {
            cells.push(CellMeasure {
                key,
                train: Some(Summary::of(&costs.train_s)),
                surveil: Some(Summary::of(&costs.surveil_s)),
                violated: false,
            });
            continue;
        }
        let mut train_ts = Vec::new();
        let mut surveil_ts = Vec::new();
        for (k, r) in &results {
            if *k == key {
                let c = r
                    .as_ref()
                    .map_err(|e| anyhow::anyhow!("cell {key:?}: {e}"))?;
                train_ts.push(c.train_s);
                surveil_ts.push(c.surveil_s);
            }
        }
        anyhow::ensure!(!train_ts.is_empty(), "no trials completed for {key:?}");
        if let Some(c) = cache {
            c.store(
                key,
                spec,
                backend.tag(),
                CellCosts {
                    train_s: train_ts.clone(),
                    surveil_s: surveil_ts.clone(),
                },
            );
        }
        cells.push(CellMeasure {
            key,
            train: Some(Summary::of(&train_ts)),
            surveil: Some(Summary::of(&surveil_ts)),
            violated: false,
        });
    }
    Ok(SweepResult {
        spec: spec.clone(),
        cells,
    })
}

// Seed helper: Rng → one u64 (keeps fork semantics out of sweep logic).
trait SeedExt {
    fn next_u64_seed(self) -> u64;
}
impl SeedExt for Rng {
    fn next_u64_seed(mut self) -> u64 {
        self.next_u64()
    }
}

impl SweepResult {
    /// Measured cells as response-surface samples for a phase
    /// (`"train"` or `"surveil"`), using median cost.
    pub fn samples(&self, phase: &str) -> Vec<Sample> {
        self.cells
            .iter()
            .filter_map(|c| {
                let s = match phase {
                    "train" => c.train.as_ref(),
                    "surveil" => c.surveil.as_ref(),
                    _ => None,
                }?;
                Some(Sample {
                    n_signals: c.key.n,
                    n_memvec: c.key.m,
                    n_obs: c.key.obs,
                    cost: s.median.max(1e-9),
                })
            })
            .collect()
    }

    /// Paper-panel grid: fix `n_signals`, rows = memvecs, cols = obs.
    pub fn panel(&self, phase: &str, n_fixed: usize) -> SurfaceGrid {
        let rows: Vec<usize> = dedup_sorted(self.cells.iter().map(|c| c.key.m));
        let cols: Vec<usize> = dedup_sorted(self.cells.iter().map(|c| c.key.obs));
        let mut grid = SurfaceGrid::new(
            "n_memvec",
            "n_obs",
            rows.iter().map(|&v| v as f64).collect(),
            cols.iter().map(|&v| v as f64).collect(),
        );
        for c in &self.cells {
            if c.key.n != n_fixed || c.violated {
                continue;
            }
            let v = match phase {
                "train" => c.train.as_ref(),
                "surveil" => c.surveil.as_ref(),
                _ => None,
            };
            if let Some(s) = v {
                let r = rows.iter().position(|&m| m == c.key.m).unwrap();
                let col = cols.iter().position(|&o| o == c.key.obs).unwrap();
                grid.set(r, col, s.median);
            }
        }
        grid
    }

    /// Cells that were skipped due to the training constraint.
    pub fn gap_cells(&self) -> Vec<CellKey> {
        self.cells
            .iter()
            .filter(|c| c.violated)
            .map(|c| c.key)
            .collect()
    }
}

fn dedup_sorted(it: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut v: Vec<usize> = it.collect();
    v.sort_unstable();
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::cache::SweepCache;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![4, 8],
            memvecs: vec![8, 16],
            obs: vec![32, 64],
            trials: 2,
            seed: 1,
            model: "mset2".into(),
            workers: 2,
        }
    }

    #[test]
    fn native_sweep_covers_grid_with_gaps() {
        let res = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        assert_eq!(res.cells.len(), 8);
        // n=8, m=8: 8 < 16 → gap
        let gaps = res.gap_cells();
        assert!(gaps.iter().all(|k| k.m < 2 * k.n));
        assert_eq!(gaps.len(), 2); // (8,8,32), (8,8,64)
        for c in &res.cells {
            if !c.violated {
                let t = c.train.as_ref().unwrap();
                assert_eq!(t.n, 2);
                assert!(t.median > 0.0);
            }
        }
    }

    #[test]
    fn sweep_is_deterministic_in_seed() {
        // Measured times differ run-to-run, but the grid structure, gap
        // cells and trial counts must be identical.
        let a = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        let b = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        assert_eq!(a.gap_cells(), b.gap_cells());
        assert_eq!(a.cells.len(), b.cells.len());
    }

    #[test]
    fn samples_exclude_gaps() {
        let res = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        let s = res.samples("train");
        assert_eq!(s.len(), 6); // 8 cells − 2 gaps
        assert!(s.iter().all(|x| x.cost > 0.0));
    }

    #[test]
    fn panel_extraction() {
        let res = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        let g = res.panel("surveil", 4);
        // rows = memvecs {8,16}, cols = obs {32,64}; n=4 has no gaps
        assert_eq!(g.row_vals, vec![8.0, 16.0]);
        assert_eq!(g.col_vals, vec![32.0, 64.0]);
        assert!((g.coverage() - 1.0).abs() < 1e-12);
        let g8 = res.panel("train", 8);
        assert!(g8.coverage() < 1.0, "n=8 must show constraint gaps");
    }

    #[test]
    fn all_native_pluggable_models_sweep() {
        for model in ["aakr", "ridge", "mlp", "svr"] {
            let spec = SweepSpec {
                model: model.into(),
                signals: vec![4],
                memvecs: vec![16],
                obs: vec![32],
                trials: 1,
                ..tiny_spec()
            };
            let res = run_sweep(&spec, Backend::Native).unwrap();
            assert_eq!(res.cells.len(), 1);
            assert!(!res.cells[0].violated);
        }
    }

    #[test]
    fn duplicate_axis_values_measure_once() {
        let spec = SweepSpec {
            signals: vec![4, 4],
            memvecs: vec![16],
            obs: vec![32],
            trials: 2,
            ..tiny_spec()
        };
        let res = run_sweep(&spec, Backend::Native).unwrap();
        assert_eq!(res.cells.len(), 1, "duplicate cells must be deduplicated");
        assert_eq!(res.cells[0].train.as_ref().unwrap().n, 2);
    }

    #[test]
    fn empty_axes_error_cleanly() {
        let bad = SweepSpec {
            signals: vec![],
            ..tiny_spec()
        };
        let err = run_sweep(&bad, Backend::Native).unwrap_err().to_string();
        assert!(err.contains("non-empty"), "{err}");
    }

    #[test]
    fn cached_sweep_reuses_cells_across_grids() {
        let cache = SweepCache::in_memory();
        let a = run_sweep_cached(&tiny_spec(), Backend::Native, Some(&cache)).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 6)); // 8 cells − 2 gaps
        assert_eq!(cache.len(), 6);

        // Identical request: every measurable cell served from the cache,
        // with bit-identical summaries (same stored trial costs).
        let b = run_sweep_cached(&tiny_spec(), Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 6);
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.key, cb.key);
            assert_eq!(ca.violated, cb.violated);
            if !ca.violated {
                assert_eq!(
                    ca.train.as_ref().unwrap().median,
                    cb.train.as_ref().unwrap().median
                );
                assert_eq!(
                    ca.surveil.as_ref().unwrap().median,
                    cb.surveil.as_ref().unwrap().median
                );
            }
        }

        // A differently-shaped grid still reuses its shared cells — seeds
        // are content-derived, so cell identity survives re-gridding.
        let sub = SweepSpec {
            signals: vec![4],
            memvecs: vec![8, 16],
            obs: vec![32],
            ..tiny_spec()
        };
        run_sweep_cached(&sub, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 8, "both sub-grid cells must be reused");
    }

    #[test]
    fn cache_misses_on_different_seed_or_trials() {
        let cache = SweepCache::in_memory();
        run_sweep_cached(&tiny_spec(), Backend::Native, Some(&cache)).unwrap();
        let reseeded = SweepSpec {
            seed: 99,
            ..tiny_spec()
        };
        run_sweep_cached(&reseeded, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 0, "different seed must not share cells");
        assert_eq!(cache.len(), 12);
    }

    #[test]
    fn surveil_cost_scales_with_obs_native() {
        let spec = SweepSpec {
            signals: vec![8],
            memvecs: vec![64],
            obs: vec![64, 2048],
            trials: 3,
            ..tiny_spec()
        };
        let res = run_sweep(&spec, Backend::Native).unwrap();
        let small = res.cells[0].surveil.as_ref().unwrap().median;
        let large = res.cells[1].surveil.as_ref().unwrap().median;
        assert!(
            large > 4.0 * small,
            "32× more observations must cost ≫ more: {small} vs {large}"
        );
    }
}
