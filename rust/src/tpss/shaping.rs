//! Distribution shaping for synthesized telemetry.
//!
//! TPSS (refs [7–9] of the paper) synthesizes signals that match real
//! telemetry "in all statistical characteristics important to ML
//! prognostics", including *stochastic content* — variance, skewness and
//! kurtosis. We realise that with the **Fleishman power method**: a cubic
//! transform `y = a + b·z + c·z² + d·z³` of a standard normal `z` whose
//! coefficients are solved (Newton iteration) to hit target skewness and
//! kurtosis, then rescaled to the target variance.

/// Coefficients of the Fleishman cubic.
#[derive(Clone, Copy, Debug)]
pub struct Fleishman {
    /// Johnson γ location parameter.
    pub a: f64,
    /// Johnson δ shape parameter.
    pub b: f64,
    /// Johnson ξ translation parameter.
    pub c: f64,
    /// Johnson λ scale parameter.
    pub d: f64,
}

/// Moments of `y = a + bz + cz² + dz³`, z ~ N(0,1), as functions of (b,c,d),
/// with `a = −c` so the mean is zero. Returns (var, skew, kurt).
fn cubic_moments(b: f64, c: f64, d: f64) -> (f64, f64, f64) {
    let b2 = b * b;
    let c2 = c * c;
    let d2 = d * d;
    let var = b2 + 6.0 * b * d + 2.0 * c2 + 15.0 * d2;
    let skew = 2.0 * c * (b2 + 24.0 * b * d + 105.0 * d2 + 2.0);
    let kurt = 24.0
        * (b * d + c2 * (1.0 + b2 + 28.0 * b * d)
            + d2 * (12.0 + 48.0 * b * d + 141.0 * c2 + 225.0 * d2))
        + 3.0 * var * var;
    (var, skew, kurt)
}

/// Solve for Fleishman coefficients hitting (skewness, kurtosis) with unit
/// variance and zero mean. `kurtosis` is the *raw* standardised fourth
/// moment (normal = 3). Feasible region requires
/// `kurtosis ≥ 1.64 + 1.77·skewness²` approximately; infeasible targets are
/// clamped toward the boundary. Returns `None` only if Newton fails.
pub fn fleishman(skewness: f64, kurtosis: f64) -> Option<Fleishman> {
    // Feasibility clamp (Fleishman's empirical boundary).
    let min_kurt = 1.64 + 1.77 * skewness * skewness + 0.05;
    let kurt = kurtosis.max(min_kurt);

    // Newton iteration on f(b,c,d) = (var−1, skew−s, kurt−k).
    let (mut b, mut c, mut d) = (1.0f64, 0.05 * skewness.signum().max(0.0) + 0.01, 0.01);
    if skewness == 0.0 {
        c = 0.0;
    }
    for _ in 0..200 {
        let (v, s, k) = cubic_moments(b, c, d);
        let f = [v - 1.0, s - skewness, k - kurt];
        let err = f.iter().map(|x| x.abs()).fold(0.0, f64::max);
        if err < 1e-10 {
            return Some(Fleishman { a: -c, b, c, d });
        }
        // numerical Jacobian
        let h = 1e-7;
        let mut jac = [[0.0; 3]; 3];
        for (j, &(db, dc, dd)) in [(h, 0.0, 0.0), (0.0, h, 0.0), (0.0, 0.0, h)]
            .iter()
            .enumerate()
        {
            let (v2, s2, k2) = cubic_moments(b + db, c + dc, d + dd);
            jac[0][j] = (v2 - v) / h;
            jac[1][j] = (s2 - s) / h;
            jac[2][j] = (k2 - k) / h;
        }
        // solve 3x3 system jac * delta = f (Cramer)
        let det = det3(&jac);
        if det.abs() < 1e-14 {
            return None;
        }
        let dx = solve3(&jac, &f, det);
        // damped update
        let step = 0.8;
        b -= step * dx[0];
        c -= step * dx[1];
        d -= step * dx[2];
    }
    let (v, s, k) = cubic_moments(b, c, d);
    let ok = (v - 1.0).abs() < 1e-5 && (s - skewness).abs() < 1e-4 && (k - kurt).abs() < 1e-3;
    if ok {
        Some(Fleishman { a: -c, b, c, d })
    } else {
        None
    }
}

fn det3(m: &[[f64; 3]; 3]) -> f64 {
    m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
        - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
        + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
}

fn solve3(m: &[[f64; 3]; 3], f: &[f64; 3], det: f64) -> [f64; 3] {
    let mut out = [0.0; 3];
    for col in 0..3 {
        let mut mm = *m;
        for r in 0..3 {
            mm[r][col] = f[r];
        }
        out[col] = det3(&mm) / det;
    }
    out
}

impl Fleishman {
    /// Transform a standard-normal draw.
    #[inline]
    pub fn apply(&self, z: f64) -> f64 {
        self.a + z * (self.b + z * (self.c + z * self.d))
    }

    /// Identity transform (Gaussian targets).
    pub fn identity() -> Fleishman {
        Fleishman {
            a: 0.0,
            b: 1.0,
            c: 0.0,
            d: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpss::stats::moments;
    use crate::util::rng::Rng;

    #[test]
    fn gaussian_target_is_identityish() {
        let f = fleishman(0.0, 3.0).unwrap();
        assert!(f.c.abs() < 1e-6);
        assert!((f.b - 1.0).abs() < 0.05 || f.d.abs() < 0.05);
        let (v, s, k) = cubic_moments(f.b, f.c, f.d);
        assert!((v - 1.0).abs() < 1e-6 && s.abs() < 1e-6 && (k - 3.0).abs() < 1e-4);
    }

    #[test]
    fn skewed_heavy_tailed_sample_moments() {
        let f = fleishman(0.8, 4.5).expect("solvable");
        let mut rng = Rng::new(31);
        let ys: Vec<f64> = (0..400_000).map(|_| f.apply(rng.gauss())).collect();
        let m = moments(&ys);
        assert!(m.mean.abs() < 0.02, "mean={}", m.mean);
        assert!((m.var - 1.0).abs() < 0.05, "var={}", m.var);
        assert!((m.skewness - 0.8).abs() < 0.1, "skew={}", m.skewness);
        assert!((m.kurtosis - 4.5).abs() < 0.4, "kurt={}", m.kurtosis);
    }

    #[test]
    fn negative_skew() {
        let f = fleishman(-0.5, 3.5).expect("solvable");
        let mut rng = Rng::new(37);
        let ys: Vec<f64> = (0..200_000).map(|_| f.apply(rng.gauss())).collect();
        let m = moments(&ys);
        assert!((m.skewness + 0.5).abs() < 0.1, "skew={}", m.skewness);
    }

    #[test]
    fn infeasible_kurtosis_clamped_not_crash() {
        // kurtosis below the boundary for this skewness
        let f = fleishman(1.5, 2.0);
        assert!(f.is_some());
    }
}
