//! GPU-acceleration model: reproduces the paper's **speedup factor**
//! evaluation (Figs. 6–8) without physical GPUs.
//!
//! The paper benchmarks MSET2 on Intel Xeon Platinum vs NVIDIA Tesla V100
//! and reports speedup factors of 200×–1500× (training) and up to
//! 5000×–9000× (surveillance). This environment has no GPU (repro band 0),
//! so per DESIGN.md §5 we substitute an **analytic roofline model**:
//!
//! - each MSET2 phase is decomposed into routines (similarity GEMM,
//!   eigendecomposition/inverse, element-wise epilogues) with exact FLOP
//!   and byte counts — the same decomposition as paper Fig. 3;
//! - GPU time per routine = launch overhead + flops / attainable, where
//!   attainable = min(peak·util, AI·bandwidth) is the classic roofline;
//! - CPU reference time = flops / effective-FLOPs of the paper-era
//!   single-socket reference implementation.
//!
//! The two free efficiency constants are **calibrated once against the
//! paper's published anchors** (≈200× at the smallest training cell,
//! ≈1500× at the largest; ≈5000× surveillance at 64 signals, ≈9000× at
//! 1024) and then *held fixed* across the whole grid — the figures are
//! reproduced by the model's structure, not per-cell fitting.
//!
//! ## Measured CPU calibration
//!
//! The analytic [`CpuRef`] is the documented *fallback*. When
//! `benches/kernel_hotpath.rs` has emitted per-backend calibration rows
//! (measured MSET train/surveil throughput on this testbed, keyed by the
//! kernel-backend ISA label), [`measured_cpu_ref`] loads the row matching
//! the *active* kernel backend from `BENCH_kernel.json` (path overridable
//! via [`CALIBRATION_ENV`]) and the recommendation engine substitutes it
//! for the paper-era reference — so quoted CPU-vs-GPU speedups and
//! dollars-per-trial reflect what this machine actually sustains, with
//! provenance reported alongside. [`calibrate_cpu_eff`] fits the
//! effective rate from raw `(flops, seconds)` pairs.

/// Routine classes with distinct attainable-efficiency behaviour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutineClass {
    /// Dense matmul-like (similarity Gram term, weight solve, estimate).
    Gemm,
    /// Eigendecomposition / iterative inverse (cuSOLVER-like, low util).
    Solver,
    /// Element-wise epilogue (bandwidth bound).
    Elementwise,
}

/// One kernel in the decomposition.
#[derive(Clone, Copy, Debug)]
pub struct Routine {
    /// Which attainable-efficiency class the kernel belongs to.
    pub class: RoutineClass,
    /// Floating-point operations executed.
    pub flops: f64,
    /// Bytes moved to/from memory (for arithmetic intensity).
    pub bytes: f64,
}

/// GPU device model (defaults = Tesla V100 SXM2, per the paper).
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// Peak f32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM bandwidth (B/s).
    pub mem_bw: f64,
    /// Per-kernel launch overhead (s).
    pub launch_s: f64,
    /// Utilisation of peak for GEMM-class kernels as a function of the
    /// signal count (deeper contractions feed the tensor units better);
    /// `util = min(gemm_util_log2 · log2(n), gemm_util_max)`.
    pub gemm_util_log2: f64,
    /// Cap on GEMM utilisation of peak.
    pub gemm_util_max: f64,
    /// Utilisation of peak for solver-class kernels (cuSOLVER eigh).
    pub solver_util: f64,
}

impl GpuSpec {
    /// Tesla V100 SXM2 (the paper's GPU), anchors calibrated per DESIGN.md §5.
    pub fn v100() -> GpuSpec {
        GpuSpec {
            peak_flops: 15.7e12,
            mem_bw: 900e9,
            launch_s: 10e-6,
            gemm_util_log2: 0.085,
            gemm_util_max: 0.92,
            solver_util: 0.15,
        }
    }

    /// Roofline-attainable throughput for a routine at signal count `n`.
    pub fn attainable(&self, r: &Routine, n: usize) -> f64 {
        let ai = r.flops / r.bytes.max(1.0);
        let util = match r.class {
            RoutineClass::Gemm => {
                (self.gemm_util_log2 * (n.max(2) as f64).log2()).min(self.gemm_util_max)
            }
            RoutineClass::Solver => self.solver_util,
            RoutineClass::Elementwise => 1.0,
        };
        (self.peak_flops * util).min(ai * self.mem_bw)
    }

    /// Time to run a set of routines, `launches` kernel launches total.
    pub fn time(&self, routines: &[Routine], launches: usize, n: usize) -> f64 {
        let compute: f64 = routines
            .iter()
            .map(|r| r.flops / self.attainable(r, n))
            .sum();
        compute + launches as f64 * self.launch_s
    }
}

/// Paper-era CPU reference (single-socket Xeon Platinum running the vendor
/// MSET implementation). Effective FLOP/s differ per phase: the training
/// path is LAPACK-blocked (cache-friendly); the streaming path processes
/// observation vectors as they arrive.
#[derive(Clone, Copy, Debug)]
pub struct CpuRef {
    /// Effective FLOP/s of the reference training path.
    pub train_eff_flops: f64,
    /// Effective FLOP/s of the reference streaming path.
    pub surveil_eff_flops: f64,
}

impl CpuRef {
    /// Paper-era single-socket Xeon Platinum reference.
    pub fn xeon_platinum() -> CpuRef {
        CpuRef {
            train_eff_flops: 2.0e9,
            surveil_eff_flops: 1.5e9,
        }
    }
}

// ------------------------------------------------------------ decomposition

/// FLOP/byte decomposition of MSET2 **training** at (n signals, m memvecs).
pub fn train_routines(n: usize, m: usize) -> Vec<Routine> {
    let (nf, mf) = (n as f64, m as f64);
    vec![
        // similarity matrix: Gram GEMM  2·n·m²  + epilogue 6·m²
        Routine {
            class: RoutineClass::Gemm,
            flops: 2.0 * nf * mf * mf,
            bytes: (mf * nf + mf * mf) * 4.0,
        },
        Routine {
            class: RoutineClass::Elementwise,
            flops: 6.0 * mf * mf,
            bytes: 2.0 * mf * mf * 4.0,
        },
        // regularised inverse via eigendecomposition (paper: cuSOLVER):
        // reduction + QR iteration + back-transform ≈ 9·m³, plus the
        // reconstruction V·diag·Vᵀ ≈ 2·m³.
        Routine {
            class: RoutineClass::Solver,
            flops: 11.0 * mf * mf * mf,
            bytes: 10.0 * mf * mf * 4.0,
        },
    ]
}

/// Kernel launches in one training run (similarity, epilogue, solver).
pub const TRAIN_LAUNCHES: usize = 3;

/// FLOP/byte decomposition of **surveillance** of `n_obs` observations in
/// device chunks of `chunk` (weights + estimate per chunk).
pub fn surveil_routines(n: usize, m: usize, n_obs: usize, chunk: usize) -> Vec<Routine> {
    let (nf, mf, of) = (n as f64, m as f64, n_obs as f64);
    let chunks = n_obs.div_ceil(chunk.max(1)) as f64;
    vec![
        // similarity of each observation against D
        Routine {
            class: RoutineClass::Gemm,
            flops: 2.0 * nf * mf * of,
            bytes: (chunks * mf * nf + of * (nf + mf)) * 4.0,
        },
        Routine {
            class: RoutineClass::Elementwise,
            flops: 6.0 * mf * of,
            bytes: 2.0 * mf * of * 4.0,
        },
        // weight solve G·K re-reads G every chunk
        Routine {
            class: RoutineClass::Gemm,
            flops: 2.0 * mf * mf * of,
            bytes: (chunks * mf * mf + 2.0 * of * mf) * 4.0,
        },
        // estimate + residual
        Routine {
            class: RoutineClass::Gemm,
            flops: 2.0 * mf * nf * of + 2.0 * nf * of,
            bytes: (chunks * mf * nf + 3.0 * of * nf) * 4.0,
        },
    ]
}

/// Kernel launches for surveillance (3 kernels per device chunk).
pub fn surveil_launches(n_obs: usize, chunk: usize) -> usize {
    3 * n_obs.div_ceil(chunk.max(1))
}

/// GPU observation-chunk size (device batch; V100 has HBM for large ones).
pub const GPU_CHUNK: usize = 4096;

// ----------------------------------------------------------------- speedup

/// Total FLOPs of a routine set.
pub fn total_flops(routines: &[Routine]) -> f64 {
    routines.iter().map(|r| r.flops).sum()
}

/// Training speedup factor (paper Fig. 6) for a (n, m) cell.
pub fn speedup_train(n: usize, m: usize, gpu: &GpuSpec, cpu: &CpuRef) -> f64 {
    let routines = train_routines(n, m);
    let t_cpu = total_flops(&routines) / cpu.train_eff_flops;
    let t_gpu = gpu.time(&routines, TRAIN_LAUNCHES, n);
    t_cpu / t_gpu
}

/// Surveillance speedup factor (paper Figs. 7–8) for (n, m, n_obs).
pub fn speedup_surveil(n: usize, m: usize, n_obs: usize, gpu: &GpuSpec, cpu: &CpuRef) -> f64 {
    let routines = surveil_routines(n, m, n_obs, GPU_CHUNK);
    let t_cpu = total_flops(&routines) / cpu.surveil_eff_flops;
    let t_gpu = gpu.time(&routines, surveil_launches(n_obs, GPU_CHUNK), n);
    t_cpu / t_gpu
}

/// Fit an effective CPU FLOP rate from measured (flops, seconds) pairs —
/// the median ratio. Lets benches anchor the CPU term to *this* testbed
/// instead of the paper-era reference.
///
/// Returns `None` when no usable pair remains — empty input, non-positive
/// flops or seconds, or non-finite ratios (all of which used to panic via
/// an out-of-bounds index or `partial_cmp().unwrap()`) — so callers fall
/// back to the paper-anchored analytic model instead of crashing.
pub fn calibrate_cpu_eff(measured: &[(f64, f64)]) -> Option<f64> {
    let mut ratios: Vec<f64> = measured
        .iter()
        .filter(|&&(f, s)| f > 0.0 && s > 0.0)
        .map(|&(f, s)| f / s)
        .filter(|r| r.is_finite())
        .collect();
    if ratios.is_empty() {
        return None;
    }
    ratios.sort_by(|a, b| a.total_cmp(b));
    Some(ratios[ratios.len() / 2])
}

// ------------------------------------------------------- measured CpuRef

/// Env var overriding where [`measured_cpu_ref`] looks for calibration
/// rows (default: `results/BENCH_kernel.json` under the working dir).
pub const CALIBRATION_ENV: &str = "CONTAINERSTRESS_CALIBRATION";

/// Provenance of the [`CpuRef`] a recommendation's cost figures used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpuRefSource {
    /// The paper-anchored analytic reference ([`CpuRef::xeon_platinum`]).
    PaperAnalytic,
    /// Calibrated from this testbed's measured kernel throughput rows,
    /// tagged with the kernel-backend ISA label they were measured under.
    Measured(&'static str),
}

impl CpuRefSource {
    /// Human-readable provenance label: `"paper-analytic"` or
    /// `"measured:<backend>"`.
    pub fn label(self) -> String {
        match self {
            Self::PaperAnalytic => "paper-analytic".to_string(),
            Self::Measured(b) => format!("measured:{b}"),
        }
    }
}

/// A [`CpuRef`] calibrated from this testbed's measured throughput.
#[derive(Debug, Clone)]
pub struct MeasuredCpu {
    /// The calibrated reference rates.
    pub cpu: CpuRef,
    /// Kernel backend the rows were measured under.
    pub backend: &'static str,
    /// File the calibration rows were read from.
    pub path: std::path::PathBuf,
}

/// Intern a backend label from parsed JSON so provenance stays `Copy`.
fn intern_backend(s: &str) -> &'static str {
    match s {
        "scalar" => "scalar",
        "avx2_fma" => "avx2_fma",
        "neon" => "neon",
        _ => "measured",
    }
}

/// Parse measured per-backend calibration rows from a `BENCH_kernel.json`
/// trajectory file: a top-level `"calibration"` array of
/// `{"backend", "train_eff_flops", "surveil_eff_flops"}` objects. Picks
/// the entry matching `prefer_isa`, falling back to the `"scalar"` entry
/// (a scalar measurement is still a real measurement of this machine).
/// Returns `None` — never an error — when the file is missing,
/// unparsable, or holds no finite positive rates, so callers degrade to
/// the analytic model.
pub fn load_calibration(path: &std::path::Path, prefer_isa: &str) -> Option<MeasuredCpu> {
    let text = std::fs::read_to_string(path).ok()?;
    let json = crate::util::json::Json::parse(&text).ok()?;
    let rows = json.get("calibration")?.as_arr()?;
    let pick = |isa: &str| -> Option<CpuRef> {
        rows.iter().find_map(|row| {
            if row.get("backend")?.as_str()? != isa {
                return None;
            }
            let train = row.get("train_eff_flops")?.as_f64()?;
            let surveil = row.get("surveil_eff_flops")?.as_f64()?;
            (train.is_finite() && train > 0.0 && surveil.is_finite() && surveil > 0.0).then_some(
                CpuRef {
                    train_eff_flops: train,
                    surveil_eff_flops: surveil,
                },
            )
        })
    };
    let (cpu, backend) = pick(prefer_isa)
        .map(|c| (c, prefer_isa))
        .or_else(|| pick("scalar").map(|c| (c, "scalar")))?;
    Some(MeasuredCpu {
        cpu,
        backend: intern_backend(backend),
        path: path.to_path_buf(),
    })
}

/// The measured CPU reference for the **active** kernel backend, if
/// calibration rows exist: honours [`CALIBRATION_ENV`] when set, else
/// reads `results/BENCH_kernel.json` relative to the working directory.
/// `None` means "use the paper-anchored analytic model".
pub fn measured_cpu_ref() -> Option<MeasuredCpu> {
    let path = match std::env::var(CALIBRATION_ENV) {
        Ok(p) if !p.trim().is_empty() => std::path::PathBuf::from(p),
        _ => std::path::PathBuf::from("results/BENCH_kernel.json"),
    };
    let isa = crate::linalg::simd::active().isa();
    load_calibration(&path, isa)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn models() -> (GpuSpec, CpuRef) {
        (GpuSpec::v100(), CpuRef::xeon_platinum())
    }

    #[test]
    fn train_speedup_matches_paper_anchors() {
        let (gpu, cpu) = models();
        // Fig. 6: "speedup factor starts from 200x and can reach up to
        // 1500x" over n ∈ [2⁵, 2¹⁰], m ∈ [2⁷, 2¹³] with m ≥ 2n.
        let lo = speedup_train(32, 128, &gpu, &cpu);
        let hi = speedup_train(1024, 8192, &gpu, &cpu);
        assert!((100.0..800.0).contains(&lo), "smallest-cell speedup {lo}");
        assert!((900.0..2500.0).contains(&hi), "largest-cell speedup {hi}");
        assert!(hi > 2.0 * lo, "speedup must grow across the grid");
    }

    #[test]
    fn surveil_speedup_matches_paper_anchors() {
        let (gpu, cpu) = models();
        // Fig. 7: 64 signals, "can exceed 5000x".
        let s64 = speedup_surveil(64, 8192, 1 << 20, &gpu, &cpu);
        assert!((3500.0..8000.0).contains(&s64), "64-signal speedup {s64}");
        // Fig. 8: 1024 signals, "can exceed 9000x".
        let s1024 = speedup_surveil(1024, 8192, 1 << 20, &gpu, &cpu);
        assert!(s1024 > 8000.0, "1024-signal speedup {s1024}");
        assert!(s1024 > s64, "speedup grows with signal count");
    }

    #[test]
    fn surveil_speedup_grows_with_n_obs_then_saturates() {
        let (gpu, cpu) = models();
        let mut prev = 0.0;
        let mut vals = Vec::new();
        for k in [8, 12, 16, 20, 24] {
            let s = speedup_surveil(64, 1024, 1 << k, &gpu, &cpu);
            assert!(s >= prev * 0.999, "non-monotone at 2^{k}: {s} < {prev}");
            prev = s;
            vals.push(s);
        }
        // saturation: the last doubling gains little
        let gain_last = vals[4] / vals[3];
        let gain_first = vals[1] / vals[0];
        assert!(gain_first > gain_last, "no saturation: {vals:?}");
    }

    #[test]
    fn launch_overhead_dominates_tiny_workloads() {
        let (gpu, cpu) = models();
        // A single observation is overhead-bound: speedup far below peak.
        let tiny = speedup_surveil(8, 32, 1, &gpu, &cpu);
        let big = speedup_surveil(8, 32, 1 << 20, &gpu, &cpu);
        assert!(tiny < big / 10.0, "tiny {tiny} vs big {big}");
    }

    #[test]
    fn roofline_bandwidth_bound_for_elementwise() {
        let gpu = GpuSpec::v100();
        let r = Routine {
            class: RoutineClass::Elementwise,
            flops: 1e9,
            bytes: 4e9, // AI = 0.25 → bw-bound
        };
        let att = gpu.attainable(&r, 64);
        assert!((att - 0.25 * gpu.mem_bw).abs() / att < 1e-9);
    }

    #[test]
    fn flop_counts_match_plugin_model() {
        // accel's decomposition must agree (to leading order) with
        // models::MsetPlugin's flop model used for scoping.
        use crate::models::{MsetPlugin, PrognosticModel};
        let p = MsetPlugin::default();
        for (n, m) in [(16, 64), (64, 512)] {
            let a = total_flops(&train_routines(n, m));
            let b = p.train_flops(n, m);
            let ratio = a / b;
            assert!((0.5..2.0).contains(&ratio), "train flops ratio {ratio}");
            let a = total_flops(&surveil_routines(n, m, 1000, GPU_CHUNK));
            let b = 1000.0 * p.surveil_flops_per_obs(n, m);
            let ratio = a / b;
            assert!((0.5..2.0).contains(&ratio), "surveil flops ratio {ratio}");
        }
    }

    #[test]
    fn calibration_recovers_known_rate() {
        let eff = 3.0e9;
        let measured: Vec<(f64, f64)> = (1..10)
            .map(|i| {
                let f = i as f64 * 1e8;
                (f, f / eff)
            })
            .collect();
        let got = calibrate_cpu_eff(&measured).expect("valid pairs calibrate");
        assert!((got - eff).abs() / eff < 1e-9);
    }

    #[test]
    fn calibration_empty_input_is_none_not_panic() {
        // used to index ratios[0] out of bounds
        assert_eq!(calibrate_cpu_eff(&[]), None);
        // all pairs filtered (zero/negative time or flops) — same regression
        assert_eq!(calibrate_cpu_eff(&[(1e9, 0.0), (0.0, 1.0), (-1.0, 2.0)]), None);
    }

    #[test]
    fn calibration_filters_non_finite_ratios() {
        // used to panic in partial_cmp(..).unwrap() when a NaN ratio
        // reached the sort
        let nan = f64::NAN;
        let got = calibrate_cpu_eff(&[(nan, 1.0), (f64::INFINITY, 1.0), (2.0e9, 1.0)]);
        assert_eq!(got, Some(2.0e9));
        assert_eq!(calibrate_cpu_eff(&[(nan, 1.0), (f64::INFINITY, 1.0)]), None);
    }

    #[test]
    fn load_calibration_prefers_isa_then_scalar_then_analytic() {
        let dir = std::env::temp_dir().join(format!("cs-accel-cal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_kernel.json");
        std::fs::write(
            &path,
            r#"{"calibration": [
                {"backend": "scalar", "train_eff_flops": 6.0e9, "surveil_eff_flops": 5.5e9},
                {"backend": "avx2_fma", "train_eff_flops": 1.8e10, "surveil_eff_flops": 1.6e10},
                {"backend": "broken", "train_eff_flops": -1.0, "surveil_eff_flops": 0.0}
            ]}"#,
        )
        .unwrap();
        let got = load_calibration(&path, "avx2_fma").expect("avx2 row present");
        assert_eq!(got.backend, "avx2_fma");
        assert!((got.cpu.train_eff_flops - 1.8e10).abs() < 1.0);
        // unmeasured ISA falls back to the scalar row
        let got = load_calibration(&path, "neon").expect("scalar fallback");
        assert_eq!(got.backend, "scalar");
        assert!((got.cpu.surveil_eff_flops - 5.5e9).abs() < 1.0);
        // invalid rows never calibrate; missing files degrade to None
        std::fs::write(&path, r#"{"calibration": [{"backend": "scalar"}]}"#).unwrap();
        assert!(load_calibration(&path, "scalar").is_none());
        assert!(load_calibration(&dir.join("absent.json"), "scalar").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
