//! The **ContainerStress coordinator** — the paper's system contribution.
//!
//! A nested-loop Monte Carlo sweep (paper Fig. 1) over the three ML design
//! parameters (signals × memory vectors × observations): every valid grid
//! cell is measured `trials` times on freshly synthesized TPSS telemetry,
//! through either the AOT/PJRT device path or the native comparator, and
//! aggregated into compute-cost summaries that the [`crate::surface`]
//! layer turns into the paper's 3-D response surfaces.
//!
//! - [`sweep`]   — grid construction, streaming trial execution,
//!   per-cell retirement and aggregation;
//! - [`planner`] — adaptive trial allocation (CI-width priority heap) +
//!   surface-model cell pruning;
//! - [`jobs`]    — the multi-job service front over the shared
//!   [`crate::util::threadpool::TrialExecutor`] (fair scheduling, live
//!   progress, cancellation); carries both sweep jobs and
//!   [`crate::scenario`] fleet-replay jobs;
//! - [`wal`]     — durable job recovery: submissions are journalled
//!   (write-ahead, fsync-always) so a crashed server replays unfinished
//!   jobs on a `--resume` restart.

pub mod jobs;
pub mod planner;
pub mod sweep;
pub mod wal;

pub use sweep::{
    run_sweep, run_sweep_cached, run_sweep_executor, Backend, Cancelled, CellCosts, CellKey,
    CellMeasure, CellStore, ProgressSnapshot, SweepProgress, SweepResult, SweepSpec,
};
