//! The **multi-tenant scoping service** — `containerstress serve`.
//!
//! The paper's framework exists to "autonomously scale any size customer ML
//! use case"; this module is the network surface that makes the coordinator
//! operable as such a service rather than a one-shot CLI. It is built
//! entirely from in-repo substrates (std `TcpListener`,
//! [`crate::util::threadpool`], [`crate::util::json`]) — no external web
//! stack is available offline:
//!
//! - [`http`]   — minimal HTTP/1.1 server core (parse, dispatch, respond);
//! - [`routes`] — the JSON API: submit scope jobs and fleet scenarios,
//!   poll status + live progress, cancel jobs, fetch recommendations,
//!   shape catalog, health, metrics;
//! - [`cache`]  — the content-addressed **cell-level sweep cache**:
//!   identical grid cells across customer requests are measured once, so a
//!   repeat scoping request costs a surface fit + recommend instead of a
//!   full Monte Carlo sweep.

pub mod cache;
pub mod http;
pub mod routes;

pub use cache::{CacheKey, CellCosts, SweepCache};
pub use http::{Handler, HttpOptions, HttpServer, Request, Response};
pub use routes::ServiceState;

use crate::config::Config;
use crate::coordinator::jobs::ScopingService;
use crate::coordinator::wal::JobWal;
use crate::coordinator::{Backend, CellStore};
use crate::metrics::Registry;
use crate::obs::journal::{Journal, JournalConfig};
use crate::obs::slo::SloEngine;
use crate::scenario::ScenarioSpec;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Connection-handler pool size. Handlers only parse/serialize JSON and
/// enqueue jobs (sweep compute runs on the shared trial executor), so a
/// small, fixed pool suffices.
const HTTP_WORKERS: usize = 4;

/// The ops-plane background thread: ticks the SLO engine on its snapshot
/// cadence and journals periodic `metrics`/`slo` frames. Stops (and
/// detaches the global journal) on drop, so every `Server` teardown path
/// cleans up.
struct OpsTick {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    journal: Option<Arc<Journal>>,
}

impl Drop for OpsTick {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let sink = crate::obs::sink();
        sink.set_journal(None);
        sink.enable_stream(false);
        if let Some(j) = &self.journal {
            j.flush();
        }
    }
}

/// A running service instance: HTTP front + scoping queue + sweep cache.
pub struct Server {
    http: HttpServer,
    state: Arc<ServiceState>,
    // Dropped after `http`, stopping the tick thread and flushing the
    // journal once no handler can touch them.
    _ops: OpsTick,
}

/// Start the ops-tick thread: SLO snapshots every `slo_tick_ms`,
/// journal `metrics` + `slo` frames every `snapshot_ms`. With neither an
/// engine nor a journal the thread is not spawned at all.
fn spawn_ops_tick(
    slo: Option<Arc<SloEngine>>,
    journal: Option<Arc<Journal>>,
    slo_tick_ms: u64,
    snapshot_ms: u64,
) -> OpsTick {
    let stop = Arc::new(AtomicBool::new(false));
    if slo.is_none() && journal.is_none() {
        return OpsTick {
            stop,
            handle: None,
            journal,
        };
    }
    let stop2 = Arc::clone(&stop);
    let slo2 = slo.clone();
    let journal2 = journal.clone();
    let handle = std::thread::Builder::new()
        .name("ops-tick".into())
        .spawn(move || {
            let step = Duration::from_millis(slo_tick_ms.min(snapshot_ms).clamp(10, 250));
            let mut last_slo = Duration::ZERO;
            let mut last_snap = Duration::ZERO;
            let started = std::time::Instant::now();
            while !stop2.load(Ordering::SeqCst) {
                std::thread::sleep(step);
                let elapsed = started.elapsed();
                if let Some(engine) = &slo2 {
                    if (elapsed - last_slo).as_millis() as u64 >= slo_tick_ms {
                        last_slo = elapsed;
                        engine.tick();
                    }
                }
                if journal2.is_some()
                    && (elapsed - last_snap).as_millis() as u64 >= snapshot_ms
                {
                    last_snap = elapsed;
                    let ts_ms = SystemTime::now()
                        .duration_since(UNIX_EPOCH)
                        .unwrap_or_default()
                        .as_millis() as u64;
                    let sink = crate::obs::sink();
                    sink.journal_event(&Json::obj(vec![
                        ("kind", Json::Str("metrics".to_string())),
                        ("ts_ms", Json::Num(ts_ms as f64)),
                        ("metrics", Registry::global().to_json()),
                    ]));
                    if let Some(engine) = &slo2 {
                        sink.journal_event(&Json::obj(vec![
                            ("kind", Json::Str("slo".to_string())),
                            ("ts_ms", Json::Num(ts_ms as f64)),
                            ("slo", engine.evaluate()),
                        ]));
                    }
                }
            }
        })
        .expect("spawn ops-tick thread");
    OpsTick {
        stop,
        handle: Some(handle),
        journal,
    }
}

/// Replay every WAL submission that never reached a terminal state. Each
/// pending entry is retired with a `resumed` terminal record and handed to
/// a fresh durable submission (which journals its own submit under a new
/// WAL id), so a crash *during* resume still loses nothing: either the old
/// record is still pending, or the new one is. Returns the number of jobs
/// resubmitted; malformed or unresubmittable records are logged, counted
/// under `wal.resume.skipped`, and skipped — one bad record must not keep
/// the service from booting.
fn resume_pending(state: &Arc<ServiceState>, wal: &Arc<JobWal>, cfg: &Config) -> usize {
    let pending = match wal.pending() {
        Ok(p) => p,
        Err(e) => {
            log::warn!("wal: could not scan pending jobs: {e:#}");
            return 0;
        }
    };
    let mut resumed = 0usize;
    for job in pending {
        wal.log_terminal(job.wal_id, "resumed");
        let outcome = match job.kind.as_str() {
            "scenario" => resume_scenario(state, &job.payload),
            _ => resume_sweep(state, &job.payload, cfg),
        };
        match outcome {
            Ok(id) => {
                log::info!(
                    "wal: resumed {} submission wal_id={} as job {id}",
                    job.kind,
                    job.wal_id
                );
                resumed += 1;
            }
            Err(e) => {
                Registry::global().inc("wal.resume.skipped");
                log::warn!(
                    "wal: skipping unresumable {} submission wal_id={}: {e:#}",
                    job.kind,
                    job.wal_id
                );
            }
        }
    }
    resumed
}

/// Resubmit one journalled sweep job. The payload's `spec` is a full
/// [`crate::config::sweep_spec_to_json`] rendering, so overlaying it on
/// any base reproduces the original spec exactly — replay is
/// bit-identical. The optional `extra` (workload/SLA context from the
/// HTTP layer) is restored so `/jobs/{id}/recommendation` works as it
/// did for the original job.
fn resume_sweep(
    state: &Arc<ServiceState>,
    payload: &Json,
    cfg: &Config,
) -> anyhow::Result<crate::coordinator::jobs::JobId> {
    let spec_json = payload
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("submit payload has no spec"))?;
    let spec = crate::config::sweep_spec_from_json(&cfg.sweep, spec_json)?;
    let weight = payload.get("weight").and_then(Json::as_f64).unwrap_or(1.0);
    let extra = payload.get("extra").cloned();
    let id = state
        .service()
        .submit_traced_durable(spec, weight, None, extra.clone())?;
    if let Some(extra) = &extra {
        state.restore_context_json(id, extra)?;
    }
    Ok(id)
}

/// Resubmit one journalled scenario job from its `scenario` + optional
/// `sweep` + `weight` payload.
fn resume_scenario(
    state: &Arc<ServiceState>,
    payload: &Json,
) -> anyhow::Result<crate::coordinator::jobs::JobId> {
    let scenario_json = payload
        .get("scenario")
        .ok_or_else(|| anyhow::anyhow!("submit payload has no scenario"))?;
    let scenario = ScenarioSpec::from_json(scenario_json)?;
    let sweep = match payload.get("sweep") {
        None | Some(Json::Null) => None,
        Some(j) => {
            // The journalled sweep rendering is complete, so any base works.
            Some(crate::config::sweep_spec_from_json(
                &crate::coordinator::SweepSpec::default(),
                j,
            )?)
        }
    };
    let weight = payload.get("weight").and_then(Json::as_f64).unwrap_or(1.0);
    state
        .service()
        .submit_scenario_traced(scenario, sweep, weight, None)
}

impl Server {
    /// Start serving on `cfg.service.host:port` (port 0 picks an ephemeral
    /// port — use [`Server::addr`] for the real one). The sweep cache is
    /// disk-backed at `cfg.service.cache_dir`, or memory-only when `None`.
    pub fn start(cfg: &Config, backend: Backend) -> anyhow::Result<Server> {
        crate::obs::touch_process_start();
        crate::obs::set_access_log(cfg.service.access_log);
        let cache = match &cfg.service.cache_dir {
            Some(dir) => Arc::new(SweepCache::open(dir)?),
            None => Arc::new(SweepCache::in_memory()),
        };
        let svc = ScopingService::start_with_scheduler(
            backend,
            cfg.service.queue_cap,
            Some(Arc::clone(&cache) as Arc<dyn CellStore>),
            cfg.service.executor_workers,
            cfg.service.fair_share,
        );
        // Durable job recovery: journal every accepted submission so a
        // crashed server can replay unfinished jobs on `--resume`.
        let wal = match &cfg.service.wal_dir {
            Some(dir) => {
                let wal = Arc::new(JobWal::open(dir)?);
                svc.set_wal(Arc::clone(&wal));
                Some(wal)
            }
            None => None,
        };
        // Ops plane: live span firehose, optional durable journal,
        // optional SLO burn-rate engine.
        let sink = crate::obs::sink();
        sink.enable_stream(true);
        let journal = match &cfg.service.journal_dir {
            Some(dir) => {
                let jcfg = JournalConfig {
                    max_file_bytes: cfg.service.journal_max_file_bytes,
                    max_total_bytes: cfg.service.journal_max_total_bytes,
                    fsync: cfg.service.journal_fsync,
                    ..JournalConfig::new(dir.clone())
                };
                let j = Arc::new(Journal::open(jcfg)?);
                sink.set_journal(Some(Arc::clone(&j)));
                Some(j)
            }
            None => None,
        };
        let slo = cfg.service.slo.enabled().then(|| {
            let engine = Arc::new(SloEngine::new(cfg.service.slo.clone()));
            engine.tick(); // baseline snapshot so windows evaluate immediately
            engine
        });

        let mut state = ServiceState::new(svc, cache, cfg.sweep.clone()).with_stream_heartbeat(
            std::time::Duration::from_millis(cfg.service.stream_heartbeat_ms),
        );
        if let Some(engine) = &slo {
            state = state.with_slo(Arc::clone(engine));
        }
        let state = Arc::new(state);
        if cfg.service.resume {
            if let Some(wal) = &wal {
                let resumed = resume_pending(&state, wal, cfg);
                log::info!("resumed {resumed} unfinished job(s) from the WAL");
            }
        }
        let handler_state = Arc::clone(&state);
        let handler: Handler = Arc::new(move |req| handler_state.handle(req));
        let addr = format!("{}:{}", cfg.service.host, cfg.service.port);
        let opts = HttpOptions {
            keep_alive: cfg.service.keep_alive,
            max_requests_per_conn: cfg.service.keep_alive_max_requests,
            shed_advisor: slo.as_ref().map(|engine| {
                let engine = Arc::clone(engine);
                Arc::new(move || engine.is_paging()) as Arc<dyn Fn() -> bool + Send + Sync>
            }),
        };
        let ops = spawn_ops_tick(
            slo.clone(),
            journal.clone(),
            cfg.service.slo.tick_ms,
            cfg.service.journal_snapshot_ms,
        );
        let http = HttpServer::bind_with(&addr, HTTP_WORKERS, handler, opts)?;
        log::info!("scoping service listening on http://{}", http.addr());
        Ok(Server {
            http,
            state,
            _ops: ops,
        })
    }

    /// The bound socket address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr()
    }

    /// The SLO engine, when objectives are configured.
    pub fn slo(&self) -> Option<Arc<SloEngine>> {
        self.state.slo()
    }

    /// Shared route state (job queue + cache) — tests and embedders.
    pub fn state(&self) -> &ServiceState {
        &self.state
    }

    /// Serve until the process is killed (the `serve` subcommand).
    pub fn join(self) {
        self.http.join();
    }

    /// Stop accepting and drain in-flight connections.
    pub fn shutdown(self) {
        self.http.shutdown();
    }

    /// Graceful-drain shutdown (the serve loop's SIGTERM path): stop
    /// accepting connections, then wait up to `deadline` for in-flight
    /// jobs to retire their WAL records. Returns the number of jobs still
    /// running when the deadline hit — those keep their pending WAL
    /// submits and are replayed by the next `serve --resume`.
    pub fn drain(self, deadline: Duration) -> usize {
        let Server { http, state, _ops } = self;
        // Closing the HTTP front first: no new submissions can arrive
        // while we wait, and in-flight request handlers finish inside
        // `shutdown()`'s pool drain.
        http.shutdown();
        let started = std::time::Instant::now();
        loop {
            let in_flight = state.service().in_flight();
            if in_flight == 0 || started.elapsed() >= deadline {
                if let Some(wal) = state.service().wal() {
                    wal.flush();
                }
                if in_flight > 0 {
                    log::warn!(
                        "drain deadline hit with {in_flight} job(s) in flight; \
                         their WAL records stay pending for --resume"
                    );
                }
                return in_flight;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }
}
