//! Foundational substrates that would normally come from crates.io but are
//! unavailable in the offline build environment (see DESIGN.md §3):
//! RNG (`rand`), JSON (`serde_json`), CLI (`clap`), thread pool
//! (`tokio`/`rayon`), logger (`env_logger`), property testing (`proptest`),
//! plus ASCII surface plotting.

pub mod cli;
pub mod failpoint;
pub mod json;
pub mod logger;
pub mod plot;
pub mod prop;
pub mod rng;
pub mod threadpool;

/// FNV-1a 64-bit hash — stable across runs and platforms (cache file
/// names and content-derived seeds depend on that stability).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Robust summary statistics over a sample of measurements (seconds, etc.).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest observation.
    pub min: f64,
    /// 25th percentile (linear interpolation).
    pub p25: f64,
    /// 50th percentile.
    pub median: f64,
    /// 75th percentile.
    pub p75: f64,
    /// Largest observation.
    pub max: f64,
}

impl Summary {
    /// Summarise a non-empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| -> f64 {
            let idx = p * (n - 1) as f64;
            let lo = idx.floor() as usize;
            let hi = idx.ceil() as usize;
            let frac = idx - lo as f64;
            v[lo] * (1.0 - frac) + v[hi] * frac
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p25: q(0.25),
            median: q(0.5),
            p75: q(0.75),
            max: v[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p25, 2.0);
        assert_eq!(s.p75, 4.0);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[2.5]);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn fnv1a_stable_and_distinct() {
        // Known FNV-1a vectors; file names on disk depend on these.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a(b"4/8/32"), fnv1a(b"4/8/33"));
    }
}
