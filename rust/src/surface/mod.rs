//! **Response-surface methodology** — the paper's §III.A presentation layer.
//!
//! The sweep engine produces compute-cost measurements over the 3-D grid of
//! ML design parameters; this module fits the parametric cost function the
//! paper visualises as 3-D response surfaces, computes sensitivity
//! (which parameter dominates each phase — the paper's stated conclusion
//! for Figs. 4/5), and exports surfaces as CSV/ASCII/gnuplot.
//!
//! The fit is a full quadratic in **log space**:
//!
//! ```text
//! log t = c₀ + Σᵢ aᵢ·log pᵢ + Σᵢ≤ⱼ bᵢⱼ·log pᵢ·log pⱼ
//! ```
//!
//! which captures power-law cost functions t ∝ nᵃ·mᵇ·Nᶜ exactly and their
//! curvature; the fitted *main-effect exponents* aᵢ (evaluated at the grid
//! centre) are the sensitivity indices.

use crate::linalg::{lstsq, Mat};

/// Names of the three ML design parameters (fixed order everywhere).
pub const PARAMS: [&str; 3] = ["n_signals", "n_memvec", "n_obs"];

/// One measured grid cell.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Signal count of the cell.
    pub n_signals: usize,
    /// Memory-vector count of the cell.
    pub n_memvec: usize,
    /// Observation count of the cell.
    pub n_obs: usize,
    /// Measured compute cost (seconds); must be > 0.
    pub cost: f64,
}

impl Sample {
    fn logs(&self) -> [f64; 3] {
        [
            (self.n_signals as f64).ln(),
            (self.n_memvec as f64).ln(),
            (self.n_obs as f64).ln(),
        ]
    }
}

/// Fitted quadratic response surface in log space.
#[derive(Clone, Debug)]
pub struct ResponseSurface {
    /// 10 coefficients: 1, l0, l1, l2, l0², l0l1, l0l2, l1², l1l2, l2².
    pub coef: Vec<f64>,
    /// Centre of the design (mean of logs) for sensitivity evaluation.
    pub centre: [f64; 3],
    /// Coefficient of determination on the training samples.
    pub r2: f64,
}

fn features(l: &[f64; 3]) -> [f64; 10] {
    [
        1.0,
        l[0],
        l[1],
        l[2],
        l[0] * l[0],
        l[0] * l[1],
        l[0] * l[2],
        l[1] * l[1],
        l[1] * l[2],
        l[2] * l[2],
    ]
}

impl ResponseSurface {
    /// Fit from measured samples (needs ≥ 10 well-spread cells).
    pub fn fit(samples: &[Sample]) -> anyhow::Result<ResponseSurface> {
        Self::fit_inner(samples, false)
    }

    /// Pure power-law fit (`log t` linear in `log p`, quadratic terms
    /// forced to zero). Slightly worse interpolation, but **safe for
    /// extrapolation** far outside the measured grid (the quadratic's
    /// curvature can bend predictions toward zero out there) — use this
    /// when scoping workloads much larger than the sweep, e.g. the
    /// paper's Customer-B extreme.
    pub fn fit_power_law(samples: &[Sample]) -> anyhow::Result<ResponseSurface> {
        Self::fit_inner(samples, true)
    }

    fn fit_inner(samples: &[Sample], linear_only: bool) -> anyhow::Result<ResponseSurface> {
        anyhow::ensure!(samples.len() >= 10, "need ≥10 samples, got {}", samples.len());
        anyhow::ensure!(
            samples.iter().all(|s| s.cost > 0.0),
            "costs must be positive"
        );
        let rows: Vec<Vec<f64>> = samples
            .iter()
            .map(|s| {
                let f = features(&s.logs());
                if linear_only {
                    f[..4].to_vec()
                } else {
                    f.to_vec()
                }
            })
            .collect();
        let a = Mat::from_rows(rows);
        let y: Vec<f64> = samples.iter().map(|s| s.cost.ln()).collect();
        let mut coef = lstsq(&a, &y);
        let pred = a.matvec(&coef);
        coef.resize(10, 0.0); // linear-only fits: quadratic coeffs = 0
        // centre
        let mut centre = [0.0; 3];
        for s in samples {
            let l = s.logs();
            for k in 0..3 {
                centre[k] += l[k];
            }
        }
        for c in centre.iter_mut() {
            *c /= samples.len() as f64;
        }
        // r²
        let mean_y = y.iter().sum::<f64>() / y.len() as f64;
        let ss_tot: f64 = y.iter().map(|v| (v - mean_y).powi(2)).sum();
        let ss_res: f64 = y
            .iter()
            .zip(&pred)
            .map(|(v, p)| (v - p) * (v - p))
            .sum();
        let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
        Ok(ResponseSurface { coef, centre, r2 })
    }

    /// Predicted cost (seconds) at a parameter point.
    pub fn predict(&self, n_signals: usize, n_memvec: usize, n_obs: usize) -> f64 {
        let l = [
            (n_signals as f64).ln(),
            (n_memvec as f64).ln(),
            (n_obs as f64).ln(),
        ];
        let f = features(&l);
        let log_t: f64 = f.iter().zip(&self.coef).map(|(a, b)| a * b).sum();
        log_t.exp()
    }

    /// Main-effect exponents ∂log t / ∂log pᵢ at the design centre — the
    /// local power-law exponent of each parameter. Larger |exponent| =
    /// stronger influence (the paper's sensitivity conclusion).
    pub fn exponents(&self) -> [f64; 3] {
        let c = &self.coef;
        let l = &self.centre;
        [
            c[1] + 2.0 * c[4] * l[0] + c[5] * l[1] + c[6] * l[2],
            c[2] + c[5] * l[0] + 2.0 * c[7] * l[1] + c[8] * l[2],
            c[3] + c[6] * l[0] + c[8] * l[1] + 2.0 * c[9] * l[2],
        ]
    }

    /// Parameters ranked by influence (descending |exponent|).
    pub fn ranking(&self) -> Vec<(&'static str, f64)> {
        let e = self.exponents();
        let mut v: Vec<(&'static str, f64)> =
            PARAMS.iter().copied().zip(e.iter().copied()).collect();
        v.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
        v
    }
}

/// A 2-D slice of measurements for rendering one paper panel: rows =
/// memvec axis, cols = second axis, `None` = constraint gap.
#[derive(Clone, Debug)]
pub struct SurfaceGrid {
    /// Label of the row axis.
    pub row_name: String,
    /// Label of the column axis.
    pub col_name: String,
    /// Row-axis tick values.
    pub row_vals: Vec<f64>,
    /// Column-axis tick values.
    pub col_vals: Vec<f64>,
    /// Cell values; `None` marks a constraint gap.
    pub cells: Vec<Vec<Option<f64>>>,
}

impl SurfaceGrid {
    /// Empty grid (all gaps) over the given axes.
    pub fn new(
        row_name: &str,
        col_name: &str,
        row_vals: Vec<f64>,
        col_vals: Vec<f64>,
    ) -> SurfaceGrid {
        let cells = vec![vec![None; col_vals.len()]; row_vals.len()];
        SurfaceGrid {
            row_name: row_name.to_string(),
            col_name: col_name.to_string(),
            row_vals,
            col_vals,
            cells,
        }
    }

    /// Fill one cell.
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.cells[r][c] = Some(v);
    }

    /// Fraction of cells filled (1.0 − gap fraction).
    pub fn coverage(&self) -> f64 {
        let total = self.row_vals.len() * self.col_vals.len();
        let filled = self
            .cells
            .iter()
            .flat_map(|r| r.iter())
            .filter(|c| c.is_some())
            .count();
        filled as f64 / total.max(1) as f64
    }

    /// ASCII heat-map (paper-style blue→red becomes glyph density).
    pub fn ascii(&self, title: &str, log_scale: bool) -> String {
        let row_ticks: Vec<String> = self.row_vals.iter().map(|v| format!("{v}")).collect();
        let col_ticks: Vec<String> = self.col_vals.iter().map(|v| format!("{v}")).collect();
        crate::util::plot::heatmap(
            title,
            &self.row_name,
            &self.col_name,
            &row_ticks,
            &col_ticks,
            &self.cells,
            log_scale,
        )
    }

    /// Long-format CSV.
    pub fn csv(&self, value_name: &str) -> String {
        crate::util::plot::grid_csv(
            &self.row_name,
            &self.col_name,
            value_name,
            &self.row_vals,
            &self.col_vals,
            &self.cells,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic cost law t = 3e-9 · n^1.1 · m^2.05 · N^0.1 (training-like).
    fn synth_samples(noise: f64, seed: u64) -> Vec<Sample> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &n in &[8usize, 16, 32, 64] {
            for &m in &[32usize, 64, 128, 256] {
                for &obs in &[256usize, 1024, 4096] {
                    let t = 3e-9
                        * (n as f64).powf(1.1)
                        * (m as f64).powf(2.05)
                        * (obs as f64).powf(0.1);
                    let t = t * (1.0 + noise * rng.gauss()).max(0.1);
                    out.push(Sample {
                        n_signals: n,
                        n_memvec: m,
                        n_obs: obs,
                        cost: t,
                    });
                }
            }
        }
        out
    }

    #[test]
    fn fit_recovers_power_law() {
        let surf = ResponseSurface::fit(&synth_samples(0.0, 1)).unwrap();
        assert!(surf.r2 > 0.9999, "r2={}", surf.r2);
        let e = surf.exponents();
        assert!((e[0] - 1.1).abs() < 0.05, "n exponent {e:?}");
        assert!((e[1] - 2.05).abs() < 0.05, "m exponent {e:?}");
        assert!((e[2] - 0.1).abs() < 0.05, "obs exponent {e:?}");
    }

    #[test]
    fn fit_robust_to_noise() {
        let surf = ResponseSurface::fit(&synth_samples(0.1, 2)).unwrap();
        assert!(surf.r2 > 0.95, "r2={}", surf.r2);
        let e = surf.exponents();
        assert!((e[1] - 2.05).abs() < 0.2, "m exponent under noise {e:?}");
    }

    #[test]
    fn ranking_identifies_dominant_parameter() {
        let surf = ResponseSurface::fit(&synth_samples(0.05, 3)).unwrap();
        let rank = surf.ranking();
        // m (exponent ≈2) must rank first, n (≈1.1) second — the paper's
        // training-phase sensitivity conclusion.
        assert_eq!(rank[0].0, "n_memvec");
        assert_eq!(rank[1].0, "n_signals");
        assert_eq!(rank[2].0, "n_obs");
    }

    #[test]
    fn predict_interpolates() {
        let surf = ResponseSurface::fit(&synth_samples(0.0, 4)).unwrap();
        let truth = 3e-9 * 24f64.powf(1.1) * 96f64.powf(2.05) * 512f64.powf(0.1);
        let pred = surf.predict(24, 96, 512);
        assert!((pred - truth).abs() / truth < 0.05, "pred {pred} truth {truth}");
    }

    #[test]
    fn power_law_fit_extrapolates_sanely() {
        let samples = synth_samples(0.05, 8);
        let surf = ResponseSurface::fit_power_law(&samples).unwrap();
        assert!(surf.r2 > 0.95, "r2={}", surf.r2);
        // Extrapolate 64× beyond the grid in m: prediction must follow the
        // power law (×64^2.05 per doubling chain), not collapse.
        let base = surf.predict(32, 256, 1024);
        let far = surf.predict(32, 16384, 1024);
        let ratio = far / base;
        let expect = 64f64.powf(2.05);
        assert!(
            (ratio / expect).ln().abs() < 0.5,
            "extrapolation ratio {ratio:.1} vs power-law {expect:.1}"
        );
        // exponents equal the global power law
        let e = surf.exponents();
        assert!((e[1] - 2.05).abs() < 0.1, "{e:?}");
    }

    #[test]
    fn fit_rejects_degenerate_input() {
        assert!(ResponseSurface::fit(&[]).is_err());
        let bad = vec![
            Sample {
                n_signals: 8,
                n_memvec: 32,
                n_obs: 100,
                cost: -1.0,
            };
            12
        ];
        assert!(ResponseSurface::fit(&bad).is_err());
    }

    #[test]
    fn grid_coverage_and_render() {
        let mut g = SurfaceGrid::new("m", "N", vec![32.0, 64.0], vec![100.0, 200.0]);
        g.set(0, 0, 1.0);
        g.set(1, 1, 4.0);
        assert!((g.coverage() - 0.5).abs() < 1e-12);
        let a = g.ascii("test", true);
        assert!(a.contains("test"));
        let csv = g.csv("cost");
        assert!(csv.contains("m,N,cost"));
        assert!(csv.lines().count() == 5);
    }
}
