"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

This is the CORE correctness signal for the device hot path: hypothesis
sweeps shapes (including non-divisible tile edge cases) and checks the
Pallas similarity and fused-estimate kernels against `ref.py`, plus the
mathematical properties the Rust side relies on (padding invariance,
symmetry, boundedness).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.estimate import estimate_pallas
from compile.kernels.similarity import sim_pallas, vmem_bytes

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(shape, seed, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape), dtype)


def bw_of(n):
    return jnp.asarray([ref.bandwidth(n)], jnp.float32)


# ------------------------------------------------------------- similarity --


@given(
    m=st.integers(1, 96),
    b=st.integers(1, 48),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_sim_pallas_matches_ref_any_shape(m, b, n, seed):
    d = rand((m, n), seed)
    x = rand((b, n), seed + 1)
    bw = bw_of(n)
    got = sim_pallas(d, x, bw)
    want = ref.sim_cross(d, x, bw)
    # atol bound: for near-duplicate vectors the Gram-trick d² differs by
    # O(eps_f32) between accumulation orders, and √ amplifies that to
    # O(√eps) ≈ 3.5e-4 near d=0 — the analytically correct tolerance.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-5)


@pytest.mark.parametrize("m,b,n", [(32, 32, 8), (64, 32, 16), (128, 64, 8)])
def test_sim_pallas_bucket_shapes(m, b, n):
    """The exact bucket shapes the AOT pipeline ships."""
    d = rand((m, n), 7)
    x = rand((b, n), 8)
    bw = bw_of(n)
    got = sim_pallas(d, x, bw)
    want = ref.sim_cross(d, x, bw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@given(tm=st.sampled_from([8, 16, 32, 128]), tb=st.sampled_from([8, 64, 128]))
def test_sim_pallas_tiling_invariance(tm, tb):
    """Result must not depend on the tile decomposition."""
    d = rand((64, 8), 3)
    x = rand((32, 8), 4)
    bw = bw_of(8)
    base = sim_pallas(d, x, bw)
    tiled = sim_pallas(d, x, bw, tm=tm, tb=tb)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled), atol=1e-6)


def test_sim_self_similarity_is_one():
    # Gram-trick rounding: ‖a‖²+‖a‖²−2aᵀa ≈ 1e-6 ≠ 0 in f32, so the diagonal
    # carries ~√eps noise. Training pins it to exactly 1 downstream
    # (ref.masked_similarity); here we only require the f32 bound.
    d = rand((16, 4), 5)
    k = sim_pallas(d, d, bw_of(4))
    np.testing.assert_allclose(np.asarray(jnp.diag(k)), 1.0, atol=2e-3)


def test_sim_bounded_unit_interval():
    d = 10.0 * rand((32, 8), 6)
    x = 10.0 * rand((16, 8), 7)
    k = np.asarray(sim_pallas(d, x, bw_of(8)))
    assert (k > 0).all() and (k <= 1.0 + 1e-7).all()


def test_sim_padding_invariance():
    """Zero-padding the signal dimension (bw fixed at n_real) must not
    change similarities — the bucket-router contract."""
    n_real, n_pad = 5, 16
    d = rand((24, n_real), 9)
    x = rand((12, n_real), 10)
    dp = jnp.pad(d, ((0, 0), (0, n_pad - n_real)))
    xp = jnp.pad(x, ((0, 0), (0, n_pad - n_real)))
    bw = bw_of(n_real)
    np.testing.assert_allclose(
        np.asarray(sim_pallas(d, x, bw)),
        np.asarray(sim_pallas(dp, xp, bw)),
        atol=1e-6,
    )


def test_sim_dtype_is_f32():
    k = sim_pallas(rand((8, 4), 1), rand((8, 4), 2), bw_of(4))
    assert k.dtype == jnp.float32


# --------------------------------------------------------------- estimate --


@given(
    m=st.integers(1, 64),
    b=st.integers(1, 32),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_estimate_pallas_matches_ref(m, b, n, seed):
    g = rand((m, m), seed)
    k = rand((m, b), seed + 1)
    d = rand((m, n), seed + 2)
    x = rand((b, n), seed + 3)
    xhat, resid = estimate_pallas(g, k, d, x)
    xhat_r, resid_r = ref.estimate(g, k, d, x)
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(xhat_r), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(resid_r), atol=2e-5, rtol=1e-4)


def test_estimate_residual_identity():
    """resid == x − xhat exactly (same kernel, same rounding)."""
    m, b, n = 32, 16, 8
    xhat, resid = estimate_pallas(
        rand((m, m), 1), rand((m, b), 2), rand((m, n), 3), rand((b, n), 4)
    )
    x = rand((b, n), 4)
    np.testing.assert_allclose(np.asarray(x - xhat), np.asarray(resid), atol=1e-7)


def test_estimate_tiling_invariance():
    m, b, n = 32, 64, 8
    args = (rand((m, m), 5), rand((m, b), 6), rand((m, n), 7), rand((b, n), 8))
    a1, r1 = estimate_pallas(*args, tb=64)
    a2, r2 = estimate_pallas(*args, tb=16)
    # f32 accumulation order differs across tilings; bound, don't bit-match.
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-4)


# ------------------------------------------------------------- vmem model --


def test_vmem_estimate_fits_tpu_budget():
    """The shipped tile configuration must fit a 16 MiB VMEM budget with
    double-buffering headroom (perf contract recorded in EXPERIMENTS.md)."""
    for n in [8, 16, 32, 64, 128, 512]:
        assert 2 * vmem_bytes(128, 128, n) < 16 * 2**20
