//! **ABL-1**: similarity-kernel formulation ablation.
//!
//! The paper's GPU contribution hinges on reformulating the similarity
//! operator for the device (CUDA block/warp/thread decomposition; here the
//! MXU Gram-trick, DESIGN.md §7). This bench quantifies that choice on CPU:
//!
//! - `direct`  — naive per-pair Euclidean loop (the pre-GPU formulation);
//! - `gram`    — ‖a‖²+‖b‖²−2aᵀb via matmul (the kernel's formulation);
//! - `blocked` — the same expansion fused into the blocked
//!   `linalg::kernel` core (the production `sim_cross` path; see
//!   `benches/kernel_hotpath.rs` for its gated speedups);
//! - `device`  — the full AOT surveillance graph through PJRT (includes
//!   the same formulation compiled by XLA).
//!
//! Output: `results/ablation_kernel.csv`.

use containerstress::bench::{figs, table, write_csv, Bencher};
use containerstress::linalg::Mat;
use containerstress::mset::{sim_cross, sim_cross_gram, sim_cross_ref};
use containerstress::util::rng::Rng;

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut m = Mat::zeros(rows, cols);
    rng.fill_gauss(&mut m.data);
    m
}

fn main() {
    containerstress::util::logger::init();
    let b = if figs::quick() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let mut ms = Vec::new();
    for &(m, n, bsz) in &[(64usize, 8usize, 64usize), (256, 32, 64), (512, 64, 64)] {
        let d = random_mat(m, n, 1);
        let x = random_mat(bsz, n, 2);
        let units = (m * bsz) as f64;
        let m1 = b.run_with_units(&format!("direct_m{m}_n{n}"), units, || {
            sim_cross_ref(&d, &x)
        });
        let m2 = b.run_with_units(&format!("gram_m{m}_n{n}"), units, || {
            sim_cross_gram(&d, &x)
        });
        let m3 = b.run_with_units(&format!("blocked_m{m}_n{n}"), units, || {
            sim_cross(&d, &x)
        });
        println!(
            "m={m} n={n}: gram is {:.2}×, blocked is {:.2}× the direct formulation",
            m1.stats.median / m2.stats.median,
            m1.stats.median / m3.stats.median
        );
        ms.push(m1);
        ms.push(m2);
        ms.push(m3);
    }

    // device path at matching bucket shapes (if artifacts present)
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (sigs, mems) = figs::available_axes(&handle);
    let n = *sigs.last().unwrap();
    let m = *mems.last().unwrap();
    let mut sess = figs::session_for(&handle, n, m, 3);
    sess.train().expect("train");
    let probe = random_mat(64, n, 4);
    let md = b.run_with_units(&format!("device_m{m}_n{n}"), (m * 64) as f64, || {
        sess.surveil(&probe).expect("surveil")
    });
    ms.push(md);

    println!("{}", table(&ms));
    write_csv("results/ablation_kernel.csv", &ms).unwrap();
    println!("ablation_kernel done → results/ablation_kernel.csv");
}
