//! The **fleet simulation engine**: replay a scenario against pluggable
//! placement/scaling policies.
//!
//! The single-tenant loops here are the generalisation (and new home) of
//! `shapes/elastic.rs`'s simulator — that module now delegates to
//! [`run_fixed`]/[`run_reactive`], so the degenerate one-tenant scenario
//! reproduces the paper's reactive-vs-pre-scoped crossover bit for bit.
//! On top of them the engine adds:
//!
//! - a **predictive policy** ([`run_predictive`]): a what-if simulation
//!   knows each tenant's future demand, so an oracle-driven scaler can
//!   migrate *before* demand crosses capacity — near-elastic cost at
//!   near-pre-scoped SLA;
//! - **fleet replay** ([`run_scenario_executor`]): every
//!   `(policy, tenant)` simulation is a task on the shared
//!   [`crate::util::threadpool::TrialExecutor`], interleaving fairly with
//!   sweep jobs, reporting live [`ScenarioProgress`], and honouring
//!   cooperative cancellation exactly like a sweep;
//! - a **Pareto comparison** over (total cost, SLA violations) through
//!   [`crate::recommend::pareto_front`], plus a recommended policy.
//!
//! Demand is resolved on the driving thread *before* the fan-out (surface
//! oracle queries may enqueue backstop trials on the same executor job;
//! doing that from a worker would deadlock a 1-worker executor), so the
//! fanned-out simulations are pure arithmetic.

use crate::coordinator::sweep::Cancelled;
use crate::metrics::Registry;
use crate::recommend::{pareto_front, recommend_policy, PolicyPoint};
use crate::scenario::oracle::{MeasureCtx, SurfaceOracle};
use crate::scenario::spec::{PolicySpec, ScenarioSpec};
use crate::scenario::trace::{build_tenants, drifted_params};
use crate::shapes::elastic::{ElasticOutcome, ElasticPolicy, GrowthTrace};
use crate::shapes::{capacity_core_eq, cpu_ladder, Shape};
use crate::util::json::Json;
use crate::util::threadpool::{JobTicket, TrialExecutor};
use crate::obs::EventBus;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock};
use std::time::{Duration, Instant};

/// Headroom the historical `shapes::elastic::compare` used to pre-scope a
/// shape against the trace peak (`capacity ≥ peak / 0.8`).
pub const PRESCOPE_HEADROOM: f64 = 0.8;

/// Predictive oracle-driven scaling policy: consults the demand trace
/// `horizon_epochs` ahead and migrates early enough that the provisioning
/// lag completes before demand arrives.
#[derive(Clone, Copy, Debug)]
pub struct PredictivePolicy {
    /// Epochs of lookahead (≥ the lag to avoid violations entirely).
    pub horizon_epochs: usize,
    /// Target peak utilisation after a move (like `scale_up_at`).
    pub headroom: f64,
    /// Scale down when the *forecast* utilisation drops below this.
    pub scale_down_at: f64,
    /// Provisioning lag in epochs (same mechanics as the reactive policy).
    pub scale_lag_epochs: usize,
    /// One-off cost per migration (USD).
    pub migration_usd: f64,
}

impl Default for PredictivePolicy {
    fn default() -> Self {
        PredictivePolicy {
            horizon_epochs: 3,
            headroom: 0.8,
            scale_down_at: 0.3,
            scale_lag_epochs: 2,
            migration_usd: 5.0,
        }
    }
}

/// One tenant × one policy simulation result: the classic
/// [`ElasticOutcome`] plus per-epoch series for fleet aggregation.
#[derive(Clone, Debug)]
pub struct TenantRun {
    /// Totals in the single-tenant simulator's own terms.
    pub outcome: ElasticOutcome,
    /// USD accrued per epoch (migration fees included at completion).
    pub usd_per_epoch: Vec<f64>,
    /// Whether demand exceeded capacity in each epoch.
    pub violations_per_epoch: Vec<bool>,
}

/// The cheapest ladder shape whose capacity covers the trace peak at the
/// given headroom (largest shape when nothing does) — the
/// ContainerStress pre-scoping rule.
pub fn prescope_shape(trace: &GrowthTrace, headroom: f64) -> &'static Shape {
    let peak = trace.peak();
    let ladder = cpu_ladder();
    ladder
        .iter()
        .find(|s| capacity_core_eq(s) >= peak / headroom)
        .unwrap_or_else(|| ladder.last().unwrap())
}

/// Simulate a fixed, pre-scoped shape over a demand trace.
///
/// The total is the single product the original `simulate_fixed` used
/// (`usd/hr × hours × epochs`), not a per-epoch summation — keeping the
/// delegating `shapes::elastic::simulate_fixed` bit-identical to its
/// pre-refactor output. The per-epoch series reconciles with it to
/// rounding (the fleet props allow 1e-9 relative).
pub fn run_fixed(shape: &Shape, trace: &GrowthTrace) -> TenantRun {
    let cap = capacity_core_eq(shape);
    let epoch_usd = shape.usd_per_hour * trace.hours_per_epoch();
    let mut violations = 0;
    let mut usd_per_epoch = Vec::with_capacity(trace.epochs());
    let mut violations_per_epoch = Vec::with_capacity(trace.epochs());
    for &d in trace.demand() {
        let violated = d > cap;
        if violated {
            violations += 1;
        }
        usd_per_epoch.push(epoch_usd);
        violations_per_epoch.push(violated);
    }
    TenantRun {
        outcome: ElasticOutcome {
            total_usd: epoch_usd * trace.epochs() as f64,
            violation_epochs: violations,
            migrations: 0,
            shape_trace: vec![shape.name; trace.epochs()],
        },
        usd_per_epoch,
        violations_per_epoch,
    }
}

/// Simulate the reactive threshold autoscaler over a demand trace
/// (the loop absorbed verbatim from `shapes::elastic::simulate_elastic`).
pub fn run_reactive(policy: &ElasticPolicy, trace: &GrowthTrace) -> TenantRun {
    let ladder = cpu_ladder();
    let mut level = 0usize;
    let mut pending: Option<(usize, usize)> = None; // (target level, ready epoch)
    let mut total = 0.0;
    let mut violations = 0;
    let mut migrations = 0;
    let mut shape_trace = Vec::with_capacity(trace.epochs());
    let mut usd_per_epoch = Vec::with_capacity(trace.epochs());
    let mut violations_per_epoch = Vec::with_capacity(trace.epochs());
    for (t, &d) in trace.demand().iter().enumerate() {
        let mut epoch_usd = 0.0;
        // complete a pending migration
        if let Some((target, ready)) = pending {
            if t >= ready {
                level = target;
                migrations += 1;
                total += policy.migration_usd;
                epoch_usd += policy.migration_usd;
                pending = None;
            }
        }
        let shape = &ladder[level];
        let cap = capacity_core_eq(shape);
        let util = d / cap;
        let violated = util > 1.0;
        if violated {
            violations += 1;
        }
        // policy decisions (only when no migration is in flight)
        if pending.is_none() {
            if util > policy.scale_up_at && level + 1 < ladder.len() {
                // pick the smallest level with headroom
                let target = (level + 1..ladder.len())
                    .find(|&l| d / capacity_core_eq(&ladder[l]) <= policy.scale_up_at)
                    .unwrap_or(ladder.len() - 1);
                pending = Some((target, t + policy.scale_lag_epochs));
            } else if util < policy.scale_down_at && level > 0 {
                let target = (0..level)
                    .find(|&l| d / capacity_core_eq(&ladder[l]) <= policy.scale_up_at)
                    .unwrap_or(level - 1);
                pending = Some((target, t + 1)); // scale-down is fast
            }
        }
        total += shape.usd_per_hour * trace.hours_per_epoch();
        epoch_usd += shape.usd_per_hour * trace.hours_per_epoch();
        shape_trace.push(shape.name);
        usd_per_epoch.push(epoch_usd);
        violations_per_epoch.push(violated);
    }
    TenantRun {
        outcome: ElasticOutcome {
            total_usd: total,
            violation_epochs: violations,
            migrations,
            shape_trace,
        },
        usd_per_epoch,
        violations_per_epoch,
    }
}

/// Simulate the predictive scaler: same migration mechanics as the
/// reactive policy, but decisions are driven by the demand *forecast*
/// (`max` over the lookahead window) instead of current utilisation.
pub fn run_predictive(policy: &PredictivePolicy, trace: &GrowthTrace) -> TenantRun {
    let ladder = cpu_ladder();
    let demand = trace.demand();
    let mut level = 0usize;
    let mut pending: Option<(usize, usize)> = None;
    let mut total = 0.0;
    let mut violations = 0;
    let mut migrations = 0;
    let mut shape_trace = Vec::with_capacity(trace.epochs());
    let mut usd_per_epoch = Vec::with_capacity(trace.epochs());
    let mut violations_per_epoch = Vec::with_capacity(trace.epochs());
    for (t, &d) in demand.iter().enumerate() {
        let mut epoch_usd = 0.0;
        if let Some((target, ready)) = pending {
            if t >= ready {
                level = target;
                migrations += 1;
                total += policy.migration_usd;
                epoch_usd += policy.migration_usd;
                pending = None;
            }
        }
        let shape = &ladder[level];
        let cap = capacity_core_eq(shape);
        let violated = d / cap > 1.0;
        if violated {
            violations += 1;
        }
        if pending.is_none() {
            let end = (t + 1 + policy.horizon_epochs).min(demand.len());
            let d_ahead = demand[t..end].iter().cloned().fold(0.0, f64::max);
            let fits =
                |l: usize| d_ahead / capacity_core_eq(&ladder[l]) <= policy.headroom;
            if !fits(level) && level + 1 < ladder.len() {
                let target = (level + 1..ladder.len())
                    .find(|&l| fits(l))
                    .unwrap_or(ladder.len() - 1);
                pending = Some((target, t + policy.scale_lag_epochs));
            } else if level > 0 && d_ahead / cap < policy.scale_down_at {
                let target = (0..level).find(|&l| fits(l)).unwrap_or(level - 1);
                pending = Some((target, t + 1));
            }
        }
        total += shape.usd_per_hour * trace.hours_per_epoch();
        epoch_usd += shape.usd_per_hour * trace.hours_per_epoch();
        shape_trace.push(shape.name);
        usd_per_epoch.push(epoch_usd);
        violations_per_epoch.push(violated);
    }
    TenantRun {
        outcome: ElasticOutcome {
            total_usd: total,
            violation_epochs: violations,
            migrations,
            shape_trace,
        },
        usd_per_epoch,
        violations_per_epoch,
    }
}

/// Live progress of one scenario job, updated atomically from executor
/// workers; every counter is monotone non-decreasing.
#[derive(Debug, Default)]
pub struct ScenarioProgress {
    /// Tenants synthesized for the scenario.
    pub tenants: AtomicUsize,
    /// `(policy, tenant)` simulations planned.
    pub units_total: AtomicUsize,
    /// Simulations completed.
    pub units_done: AtomicUsize,
    /// Live event sink for `/events` streams; attached once by the job
    /// layer (absent for library callers).
    events: OnceLock<Arc<EventBus>>,
}

impl ScenarioProgress {
    /// Attach the live event bus unit completions publish to. At most one
    /// bus per progress; later calls are no-ops.
    pub fn attach_events(&self, bus: Arc<EventBus>) {
        let _ = self.events.set(bus);
    }

    /// The attached live event bus, if any.
    pub fn event_bus(&self) -> Option<&Arc<EventBus>> {
        self.events.get()
    }

    /// Publish a `(policy, tenant)` unit-completion event to the attached
    /// bus (no-op without one). `epochs` is the simulated epoch count the
    /// unit replayed.
    pub fn emit_unit(&self, policy: &str, tenant: usize, epochs: usize) {
        if let Some(bus) = self.events.get() {
            bus.publish_json(&Json::obj(vec![
                ("event", Json::Str("unit".to_string())),
                ("policy", Json::Str(policy.to_string())),
                ("tenant", Json::Num(tenant as f64)),
                ("epochs", Json::Num(epochs as f64)),
                (
                    "units_done",
                    Json::Num(self.units_done.load(Ordering::SeqCst) as f64),
                ),
                (
                    "units_total",
                    Json::Num(self.units_total.load(Ordering::SeqCst) as f64),
                ),
            ]));
        }
    }

    /// Plain-value copy for status reporting.
    pub fn snapshot(&self) -> ScenarioSnapshot {
        ScenarioSnapshot {
            tenants: self.tenants.load(Ordering::SeqCst),
            units_total: self.units_total.load(Ordering::SeqCst),
            units_done: self.units_done.load(Ordering::SeqCst),
        }
    }
}

/// Plain-value snapshot of a [`ScenarioProgress`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScenarioSnapshot {
    /// Tenants synthesized.
    pub tenants: usize,
    /// `(policy, tenant)` simulations planned.
    pub units_total: usize,
    /// Simulations completed.
    pub units_done: usize,
}

/// Fleet-level result of one policy over the whole scenario.
#[derive(Clone, Debug)]
pub struct PolicyOutcome {
    /// Policy label (see [`PolicySpec::label`]).
    pub label: String,
    /// Fleet total spend (USD).
    pub total_usd: f64,
    /// Tenant-epochs in which demand exceeded capacity.
    pub violation_epochs: usize,
    /// Shape migrations across the fleet.
    pub migrations: usize,
    /// Fleet USD accrued per epoch.
    pub usd_per_epoch: Vec<f64>,
    /// Number of violating tenants per epoch.
    pub violations_per_epoch: Vec<usize>,
}

/// Complete scenario replay output.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    /// Scenario name.
    pub name: String,
    /// Simulated epochs.
    pub epochs: usize,
    /// Hours per epoch.
    pub hours_per_epoch: f64,
    /// Fleet size.
    pub tenants: usize,
    /// One entry per policy, in spec order.
    pub policies: Vec<PolicyOutcome>,
    /// Indices of Pareto-optimal policies (cost vs violations).
    pub pareto: Vec<usize>,
    /// Recommended policy: cheapest with zero violations, else fewest
    /// violations (cheapest on ties).
    pub recommended: Option<usize>,
    /// Oracle answer-source counters (workload mode only).
    pub oracle: Option<crate::scenario::oracle::OracleSnapshot>,
}

impl ScenarioOutcome {
    /// The per-policy cost/violation points (Pareto inputs).
    pub fn policy_points(&self) -> Vec<PolicyPoint> {
        self.policies
            .iter()
            .map(|p| PolicyPoint {
                label: p.label.clone(),
                total_usd: p.total_usd,
                violation_epochs: p.violation_epochs,
                migrations: p.migrations,
            })
            .collect()
    }

    /// JSON rendering (the service's scenario result payload).
    pub fn to_json(&self) -> Json {
        let policies: Vec<Json> = self
            .policies
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("policy", Json::Str(p.label.clone())),
                    ("total_usd", Json::Num(p.total_usd)),
                    ("violation_epochs", Json::Num(p.violation_epochs as f64)),
                    ("migrations", Json::Num(p.migrations as f64)),
                    ("usd_per_epoch", Json::arr_f64(&p.usd_per_epoch)),
                    (
                        "violations_per_epoch",
                        Json::arr_f64(
                            &p.violations_per_epoch
                                .iter()
                                .map(|&v| v as f64)
                                .collect::<Vec<_>>(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("epochs", Json::Num(self.epochs as f64)),
            ("hours_per_epoch", Json::Num(self.hours_per_epoch)),
            ("tenants", Json::Num(self.tenants as f64)),
            ("policies", Json::Arr(policies)),
            (
                "pareto",
                Json::arr_f64(&self.pareto.iter().map(|&i| i as f64).collect::<Vec<_>>()),
            ),
            (
                "recommended",
                match self.recommended {
                    Some(i) => Json::Str(self.policies[i].label.clone()),
                    None => Json::Null,
                },
            ),
            (
                "oracle",
                match &self.oracle {
                    Some(o) => o.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Render the policy comparison table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "Scenario '{}': {} tenants × {} epochs ({}h each)\n",
            self.name, self.tenants, self.epochs, self.hours_per_epoch
        );
        out.push_str(&format!(
            "{:<32} {:>12} {:>11} {:>11} {:>7}\n",
            "policy", "total_usd", "violations", "migrations", "pareto"
        ));
        for (i, p) in self.policies.iter().enumerate() {
            out.push_str(&format!(
                "{:<32} {:>12.2} {:>11} {:>11} {:>7}{}\n",
                p.label,
                p.total_usd,
                p.violation_epochs,
                p.migrations,
                if self.pareto.contains(&i) { "*" } else { "" },
                if self.recommended == Some(i) {
                    " ← recommended"
                } else {
                    ""
                }
            ));
        }
        if let Some(o) = &self.oracle {
            out.push_str(&format!(
                "Oracle: {} surface + {} memo answers, {} cells measured \
                 ({} fresh trials), {} extrapolated\n",
                o.surface_hits, o.memo_hits, o.measured_cells, o.fresh_trials, o.extrapolated
            ));
        }
        out
    }
}

/// Retry budget for one `(policy, tenant)` simulation unit. The sims are
/// pure arithmetic, so retries only ever matter under the deterministic
/// `scenario.unit.run` failpoint (or a genuine panic in a policy loop) —
/// no backoff sleep is needed, just a varied failpoint tag per attempt.
const UNIT_MAX_RETRIES: u64 = 2;

/// Run one `(policy, tenant)` simulation with panic containment and
/// bounded retries. Injected faults and panics are converted to classified
/// errors (the failpoint message survives the chain) so the aggregation
/// loop can fail the scenario cleanly instead of hanging on a lost slot.
fn run_unit(
    policy: &PolicySpec,
    trace: &GrowthTrace,
    unit_tag: u64,
) -> anyhow::Result<TenantRun> {
    let mut attempt: u64 = 0;
    loop {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::util::failpoint::hit(
                "scenario.unit.run",
                unit_tag.wrapping_add(attempt),
            )?;
            Ok(match *policy {
                PolicySpec::PreScoped { headroom } => {
                    run_fixed(prescope_shape(trace, headroom), trace)
                }
                PolicySpec::Reactive(p) => run_reactive(&p, trace),
                PolicySpec::Predictive(p) => run_predictive(&p, trace),
            })
        }))
        .unwrap_or_else(|p| {
            Err(anyhow::anyhow!(
                "scenario unit panicked: {}",
                crate::coordinator::sweep::panic_text(&*p)
            ))
        });
        match r {
            Ok(run) => return Ok(run),
            Err(e) if attempt >= UNIT_MAX_RETRIES => {
                Registry::global().inc("scenario.unit.failed");
                return Err(e);
            }
            Err(_) => {
                attempt += 1;
                Registry::global().inc("scenario.unit.retries");
            }
        }
    }
}

/// Resolve every tenant's demand trace (core-equivalents). Runs on the
/// driving thread: in workload mode each epoch consults the surface
/// oracle, whose out-of-domain backstop may block on executor trials.
fn resolve_demand(
    spec: &ScenarioSpec,
    oracle: Option<&SurfaceOracle>,
    ctx: Option<&MeasureCtx<'_>>,
    cancel: &crate::util::threadpool::CancelToken,
) -> anyhow::Result<Vec<(usize, GrowthTrace)>> {
    let tenants = build_tenants(spec);
    let mut out = Vec::with_capacity(tenants.len());
    for tenant in tenants {
        if cancel.is_cancelled() {
            return Err(Cancelled.into());
        }
        let demand: Vec<f64> = match (&spec.workload, oracle) {
            (None, _) => tenant.series,
            (Some(w), Some(oracle)) => {
                let mut v = Vec::with_capacity(tenant.series.len());
                for (t, &mult) in tenant.series.iter().enumerate() {
                    let (n, m) = drifted_params(w, t);
                    let rate = w.base.obs_per_sec * mult;
                    v.push(oracle.demand_core_eq(n, m, rate, ctx)?);
                }
                v
            }
            (Some(_), None) => anyhow::bail!(
                "workload-mode scenario '{}' needs a fitted surface oracle \
                 (run a sweep first)",
                spec.name
            ),
        };
        let trace = GrowthTrace::new(demand, spec.hours_per_epoch)
            .map_err(|e| anyhow::anyhow!("tenant {}: {e}", tenant.id))?;
        out.push((tenant.arrival_epoch, trace));
    }
    Ok(out)
}

/// Replay a scenario on a caller-provided executor job: every
/// `(policy, tenant)` simulation is a task interleaved fairly with other
/// jobs' work; `progress` updates live; cancelling the ticket's token
/// reclaims queued simulations and returns
/// [`Cancelled`](crate::coordinator::Cancelled).
pub fn run_scenario_executor(
    spec: &ScenarioSpec,
    oracle: Option<&SurfaceOracle>,
    ctx: Option<&MeasureCtx<'_>>,
    ticket: &JobTicket,
    progress: &Arc<ScenarioProgress>,
) -> anyhow::Result<ScenarioOutcome> {
    spec.validate()?;
    let cancel = ticket.cancel_token();
    if cancel.is_cancelled() {
        return Err(Cancelled.into());
    }
    Registry::global().inc("scenario.runs");
    // Runs on the job's driver thread, so the thread-local recorder (if
    // any) is this job's; clones of the Arc ride into unit closures below.
    let recorder = crate::obs::current();

    // Phase 1 (this thread): tenant synthesis + oracle demand resolution.
    let resolve_t0 = Instant::now();
    let tenants = Arc::new(resolve_demand(spec, oracle, ctx, &cancel)?);
    if let Some(rec) = &recorder {
        rec.push(
            "scenario",
            "resolve",
            resolve_t0,
            Instant::now(),
            Duration::ZERO,
            format!("tenants={} epochs={}", tenants.len(), spec.epochs),
        );
    }
    let policies = Arc::new(spec.policies.clone());
    let (np, nt) = (policies.len(), tenants.len());
    progress.tenants.store(nt, Ordering::SeqCst);
    progress.units_total.store(np * nt, Ordering::SeqCst);
    Registry::global().add("scenario.tenant_sims", (np * nt) as u64);
    log::info!(
        "scenario '{}': {} tenants × {} epochs × {} policies",
        spec.name,
        nt,
        spec.epochs,
        np
    );

    // Phase 2: fan (policy, tenant) simulations over the shared executor.
    let (tx, rx) = mpsc::channel::<(usize, usize, anyhow::Result<TenantRun>)>();
    for pi in 0..np {
        for ti in 0..nt {
            let tx = tx.clone();
            let tenants = Arc::clone(&tenants);
            let policies = Arc::clone(&policies);
            let progress = Arc::clone(progress);
            let cancel = cancel.clone();
            let recorder = recorder.clone();
            let enqueued = Instant::now();
            let unit_tag = (pi * nt + ti) as u64;
            ticket.submit(move || {
                if cancel.is_cancelled() {
                    return;
                }
                let started = Instant::now();
                let queue_wait = started.saturating_duration_since(enqueued);
                let (_, trace) = &tenants[ti];
                let run = run_unit(&policies[pi], trace, unit_tag);
                if let Some(rec) = &recorder {
                    let meta = format!(
                        "policy={} tenant={ti} epochs={}",
                        policies[pi].label(),
                        trace.epochs()
                    );
                    rec.push("scenario", "unit", started, Instant::now(), queue_wait, meta);
                }
                progress.units_done.fetch_add(1, Ordering::SeqCst);
                progress.emit_unit(&policies[pi].label(), ti, trace.epochs());
                let _ = tx.send((pi, ti, run));
            });
        }
    }
    drop(tx);

    let mut slots: Vec<Vec<Option<anyhow::Result<TenantRun>>>> =
        (0..np).map(|_| vec![None; nt]).collect();
    loop {
        match rx.recv_timeout(std::time::Duration::from_millis(50)) {
            Ok((pi, ti, run)) => slots[pi][ti] = Some(run),
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if cancel.is_cancelled() && ticket.pending() == (0, 0) {
                    while let Ok((pi, ti, run)) = rx.try_recv() {
                        slots[pi][ti] = Some(run);
                    }
                    break;
                }
            }
        }
    }
    if cancel.is_cancelled() {
        return Err(Cancelled.into());
    }

    // Aggregate in deterministic (policy, tenant) order so fleet totals
    // replay bit-identically under any executor interleaving.
    let mut outcomes = Vec::with_capacity(np);
    for (pi, runs) in slots.into_iter().enumerate() {
        let mut total = 0.0;
        let mut violations = 0;
        let mut migrations = 0;
        let mut usd = vec![0.0; spec.epochs];
        let mut viol = vec![0usize; spec.epochs];
        for (ti, run) in runs.into_iter().enumerate() {
            let Some(run) = run else {
                anyhow::bail!("scenario lost simulation results (task reclaimed without cancel?)");
            };
            let run = run.map_err(|e| {
                anyhow::anyhow!(
                    "scenario unit (policy {}, tenant {ti}) failed after \
                     {UNIT_MAX_RETRIES} retries: {e:#}",
                    policies[pi].label()
                )
            })?;
            let arrival = tenants[ti].0;
            total += run.outcome.total_usd;
            violations += run.outcome.violation_epochs;
            migrations += run.outcome.migrations;
            for (t, &c) in run.usd_per_epoch.iter().enumerate() {
                usd[arrival + t] += c;
            }
            for (t, &v) in run.violations_per_epoch.iter().enumerate() {
                viol[arrival + t] += v as usize;
            }
        }
        outcomes.push(PolicyOutcome {
            label: policies[pi].label(),
            total_usd: total,
            violation_epochs: violations,
            migrations,
            usd_per_epoch: usd,
            violations_per_epoch: viol,
        });
    }

    let points: Vec<PolicyPoint> = outcomes
        .iter()
        .map(|p| PolicyPoint {
            label: p.label.clone(),
            total_usd: p.total_usd,
            violation_epochs: p.violation_epochs,
            migrations: p.migrations,
        })
        .collect();
    let pareto = pareto_front(&points);
    let recommended = recommend_policy(&points, 0);
    Ok(ScenarioOutcome {
        name: spec.name.clone(),
        epochs: spec.epochs,
        hours_per_epoch: spec.hours_per_epoch,
        tenants: nt,
        policies: outcomes,
        pareto,
        recommended,
        oracle: oracle.map(|o| o.stats()),
    })
}

/// Standalone entry point: spins up a private executor for the fan-out
/// (the CLI and benches). Services sharing one executor across jobs call
/// [`run_scenario_executor`] with their own ticket instead.
pub fn run_scenario(
    spec: &ScenarioSpec,
    oracle: Option<&SurfaceOracle>,
    backstop: Option<&crate::scenario::oracle::Backstop<'_>>,
) -> anyhow::Result<ScenarioOutcome> {
    let exec = TrialExecutor::new(crate::util::threadpool::default_workers(), true);
    let ticket = exec.register(1.0);
    let progress = Arc::new(ScenarioProgress::default());
    let ctx = backstop.map(|b| MeasureCtx {
        spec: b.spec,
        backend: b.backend,
        cache: b.cache,
        ticket: &ticket,
    });
    run_scenario_executor(spec, oracle, ctx.as_ref(), &ticket, &progress)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{ArrivalSpec, DemandKind, DemandSpec};

    fn steps_trace() -> GrowthTrace {
        GrowthTrace::steps(0.5, &[12, 24, 36], 48, 24.0).unwrap()
    }

    #[test]
    fn predictive_avoids_lag_violations_at_sub_prescoped_cost() {
        let trace = steps_trace();
        let reactive = run_reactive(&ElasticPolicy::default(), &trace);
        let predictive = run_predictive(
            &PredictivePolicy {
                horizon_epochs: 4,
                ..PredictivePolicy::default()
            },
            &trace,
        );
        let fixed = run_fixed(prescope_shape(&trace, PRESCOPE_HEADROOM), &trace);
        assert!(reactive.outcome.violation_epochs > 0, "reactive must lag");
        assert_eq!(
            predictive.outcome.violation_epochs, 0,
            "lookahead ≥ lag must migrate before demand arrives"
        );
        assert!(predictive.outcome.migrations >= 3);
        assert!(
            predictive.outcome.total_usd < fixed.outcome.total_usd,
            "predictive {:.2} must undercut pre-scoped {:.2}",
            predictive.outcome.total_usd,
            fixed.outcome.total_usd
        );
    }

    #[test]
    fn per_epoch_series_sum_to_totals() {
        let trace = steps_trace();
        for run in [
            run_fixed(prescope_shape(&trace, 0.8), &trace),
            run_reactive(&ElasticPolicy::default(), &trace),
            run_predictive(&PredictivePolicy::default(), &trace),
        ] {
            assert_eq!(run.usd_per_epoch.len(), trace.epochs());
            let sum: f64 = run.usd_per_epoch.iter().sum();
            assert!(
                (sum - run.outcome.total_usd).abs() < 1e-9 * run.outcome.total_usd.max(1.0),
                "epoch series must reconcile with the total"
            );
            let v = run.violations_per_epoch.iter().filter(|&&x| x).count();
            assert_eq!(v, run.outcome.violation_epochs);
        }
    }

    fn tiny_scenario() -> ScenarioSpec {
        ScenarioSpec {
            name: "tiny".into(),
            epochs: 30,
            arrivals: ArrivalSpec {
                initial: 4,
                rate_per_epoch: 0.3,
                max_tenants: 8,
            },
            demand: DemandSpec {
                base: 0.5,
                growth_per_epoch: 1.02,
                jitter: 0.2,
                kind: DemandKind::Diurnal {
                    amplitude: 0.3,
                    period: 7,
                },
            },
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn scenario_replay_structure_and_pareto() {
        let spec = tiny_scenario();
        let out = run_scenario(&spec, None, None).unwrap();
        assert_eq!(out.policies.len(), spec.policies.len());
        assert!(out.tenants >= 4);
        for p in &out.policies {
            assert_eq!(p.usd_per_epoch.len(), spec.epochs);
            assert!(p.total_usd > 0.0);
            let sum: f64 = p.usd_per_epoch.iter().sum();
            assert!((sum - p.total_usd).abs() < 1e-9 * p.total_usd);
        }
        assert!(!out.pareto.is_empty(), "some policy must be non-dominated");
        assert!(out.recommended.is_some());
        assert!(out.oracle.is_none(), "direct mode has no oracle");
        // render + JSON round out without panicking
        assert!(out.render().contains("policy"));
        assert!(out.to_json().get("pareto").is_some());
    }

    #[test]
    fn cancelled_token_aborts_cleanly() {
        let exec = TrialExecutor::new(2, true);
        let ticket = exec.register(1.0);
        ticket.cancel_token().cancel();
        let progress = Arc::new(ScenarioProgress::default());
        let err = run_scenario_executor(&tiny_scenario(), None, None, &ticket, &progress)
            .unwrap_err();
        assert!(err.is::<Cancelled>(), "{err}");
    }

    #[test]
    fn scenario_unit_faults_surface_as_classified_errors() {
        use crate::util::failpoint;
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        failpoint::arm_from_str("scenario.unit.run:1:panic:7").unwrap();
        let err = run_scenario(&tiny_scenario(), None, None).unwrap_err();
        failpoint::disarm_all();
        assert!(failpoint::is_injected(&err), "{err:#}");
        let text = format!("{err:#}");
        assert!(text.contains("failed after"), "{text}");
        // a sub-certain rate either retries through to the bit-identical
        // fault-free outcome (sims are pure) or fails classified — never
        // a third state
        let clean = run_scenario(&tiny_scenario(), None, None).unwrap();
        failpoint::arm_from_str("scenario.unit.run:0.4:error:7").unwrap();
        let chaotic = run_scenario(&tiny_scenario(), None, None);
        failpoint::disarm_all();
        match chaotic {
            Ok(out) => {
                for (a, b) in clean.policies.iter().zip(&out.policies) {
                    assert_eq!(a.total_usd, b.total_usd, "policy {}", a.label);
                    assert_eq!(a.violation_epochs, b.violation_epochs);
                }
            }
            Err(e) => assert!(failpoint::is_injected(&e), "{e:#}"),
        }
    }

    #[test]
    fn workload_mode_without_oracle_errors() {
        let spec = ScenarioSpec {
            workload: Some(crate::scenario::spec::WorkloadSpec {
                base: crate::shapes::Workload::customer_a(),
                drift: Default::default(),
            }),
            ..tiny_scenario()
        };
        let err = run_scenario(&spec, None, None).unwrap_err().to_string();
        assert!(err.contains("oracle"), "{err}");
    }
}
