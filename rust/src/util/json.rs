//! Minimal JSON model, parser and writer.
//!
//! `serde`/`serde_json` are not available in the offline build environment,
//! so this module provides the small JSON surface ContainerStress needs:
//! the artifact manifest written by `python/compile/aot.py`, config files,
//! and metrics/report export.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve key order via `BTreeMap` (sorted), which
/// is sufficient for manifests and keeps output deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always an f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub offset: usize,
    /// Human-readable description.
    pub msg: String,
}

impl Json {
    // ---- constructors -------------------------------------------------

    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array of strings.
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- accessors -----------------------------------------------------

    /// Object member lookup (`None` for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with the key name — manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key: {key}"))
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as a non-negative integer (rejects fractions).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- parsing -------------------------------------------------------

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    pub(crate) fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

pub(crate) fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant writers.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

pub(crate) fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs not needed for our manifests;
                            // replace lone surrogates with U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Event-based incremental JSON parsing and emission.
///
/// The batch [`Json::parse`] / [`Json::to_string`] pair materialises whole
/// documents; this submodule provides the streaming counterparts the HTTP
/// layer feeds straight from the socket: a push [`StreamParser`] that
/// consumes input split at arbitrary chunk boundaries and emits structural
/// [`Event`]s with bounded per-connection memory, a [`ValueBuilder`] that
/// reassembles those events into a [`Json`] tree (equivalent to the batch
/// parser on every input — fuzzed in `tests/json_fuzz.rs`), and a
/// [`StreamEmitter`] whose concatenated output is byte-identical to
/// [`Json::to_string`] without ever holding the full document.
pub mod stream {
    use super::{write_num, write_str, Json, JsonError};
    use std::collections::BTreeMap;

    /// One structural event produced by [`StreamParser`].
    #[derive(Clone, Debug, PartialEq)]
    pub enum Event {
        /// `null`.
        Null,
        /// `true` / `false`.
        Bool(bool),
        /// A complete number token.
        Num(f64),
        /// A complete string value (keys are [`Event::Key`] instead).
        Str(String),
        /// `[` — an array opens.
        ArrStart,
        /// `]` — the innermost array closes.
        ArrEnd,
        /// `{` — an object opens.
        ObjStart,
        /// An object member key; the member value's events follow.
        Key(String),
        /// `}` — the innermost object closes.
        ObjEnd,
    }

    /// Per-connection resource limits for a [`StreamParser`].
    ///
    /// Parser state is one [`Ctx`] byte per nesting level plus the bytes of
    /// the single in-progress token, so total memory is bounded by
    /// `max_depth + max_token_bytes` regardless of document size.
    #[derive(Clone, Copy, Debug)]
    pub struct Limits {
        /// Maximum container nesting depth.
        pub max_depth: usize,
        /// Maximum bytes buffered for one token (string or number).
        pub max_token_bytes: usize,
    }

    impl Default for Limits {
        fn default() -> Self {
            Limits {
                max_depth: 256,
                max_token_bytes: 1 << 20,
            }
        }
    }

    impl Limits {
        /// Permissive limits for harnesses comparing against the recursive
        /// batch parser, chosen so the limits never bind on small inputs.
        pub fn lenient() -> Self {
            Limits {
                max_depth: 1 << 16,
                max_token_bytes: 1 << 24,
            }
        }
    }

    /// Container kind on the parser stack.
    #[derive(Clone, Copy, Debug, PartialEq)]
    enum Ctx {
        Arr,
        Obj,
    }

    /// What the grammar expects next, between tokens.
    #[derive(Clone, Copy, Debug, PartialEq)]
    enum Phase {
        /// A value must come next (top level, after `[`-comma, after `:`).
        Value,
        /// Directly after `[`: a value or `]`.
        FirstValueOrEnd,
        /// Directly after `{`: a key or `}`.
        FirstKeyOrEnd,
        /// After `,` inside an object: a key.
        Key,
        /// Between an object key and its value.
        Colon,
        /// After a member/element: `,` or the container's closer.
        CommaOrEnd,
        /// Top-level value finished; only trailing whitespace is legal.
        Done,
    }

    /// Position inside a number token, mirroring the batch parser's
    /// positional greedy grammar (`-? digits* ('.' digits*)? ([eE] [+-]?
    /// digits*)?`) so both parsers cut the token at the same byte.
    #[derive(Clone, Copy, Debug)]
    enum NumPos {
        Int,
        Frac,
        ExpMark,
        Exp,
    }

    /// Escape-sequence progress inside a string token.
    #[derive(Clone, Copy, Debug)]
    enum Esc {
        None,
        Start,
        Hex { hex: [u8; 4], n: usize },
    }

    /// In-progress token spanning chunk boundaries.
    #[derive(Debug)]
    enum Token {
        None,
        Lit { want: &'static [u8], got: usize },
        Num { buf: String, pos: NumPos },
        Str { buf: Vec<u8>, key: bool, esc: Esc },
    }

    fn num_step(pos: NumPos, b: u8) -> Option<NumPos> {
        match pos {
            NumPos::Int => match b {
                b'0'..=b'9' => Some(NumPos::Int),
                b'.' => Some(NumPos::Frac),
                b'e' | b'E' => Some(NumPos::ExpMark),
                _ => None,
            },
            NumPos::Frac => match b {
                b'0'..=b'9' => Some(NumPos::Frac),
                b'e' | b'E' => Some(NumPos::ExpMark),
                _ => None,
            },
            NumPos::ExpMark => match b {
                b'+' | b'-' | b'0'..=b'9' => Some(NumPos::Exp),
                _ => None,
            },
            NumPos::Exp => match b {
                b'0'..=b'9' => Some(NumPos::Exp),
                _ => None,
            },
        }
    }

    /// Feed-by-chunk JSON parser emitting [`Event`]s.
    ///
    /// Call [`StreamParser::feed`] with each arriving chunk (boundaries may
    /// fall anywhere, including inside tokens, escapes and `\u` hex digits)
    /// and [`StreamParser::finish`] at end of input. The accepted language
    /// and resulting values are identical to [`Json::parse`]; inputs the
    /// batch parser rejects are rejected here too (byte offsets and
    /// messages may differ).
    #[derive(Debug)]
    pub struct StreamParser {
        limits: Limits,
        stack: Vec<Ctx>,
        phase: Phase,
        token: Token,
        offset: usize,
        failed: bool,
    }

    impl StreamParser {
        /// New parser enforcing `limits`.
        pub fn new(limits: Limits) -> Self {
            StreamParser {
                limits,
                stack: Vec::new(),
                phase: Phase::Value,
                token: Token::None,
                offset: 0,
                failed: false,
            }
        }

        fn fail(&mut self, msg: &str) -> JsonError {
            self.failed = true;
            JsonError {
                offset: self.offset,
                msg: msg.to_string(),
            }
        }

        /// Bytes currently buffered for the in-progress token — the
        /// parser's only input-proportional state, bounded by
        /// [`Limits::max_token_bytes`].
        pub fn buffered_bytes(&self) -> usize {
            match &self.token {
                Token::Str { buf, .. } => buf.len(),
                Token::Num { buf, .. } => buf.len(),
                _ => 0,
            }
        }

        /// Current container nesting depth.
        pub fn depth(&self) -> usize {
            self.stack.len()
        }

        /// True once a complete top-level value has been parsed (trailing
        /// whitespace may still follow).
        pub fn is_done(&self) -> bool {
            !self.failed && self.phase == Phase::Done && matches!(self.token, Token::None)
        }

        /// Consume one chunk, appending events to `out`. Errors are sticky:
        /// once a feed fails, the parser stays failed.
        pub fn feed(&mut self, chunk: &[u8], out: &mut Vec<Event>) -> Result<(), JsonError> {
            if self.failed {
                return Err(JsonError {
                    offset: self.offset,
                    msg: "parser already failed".into(),
                });
            }
            let mut i = 0;
            while i < chunk.len() {
                if self.step(chunk[i], out)? {
                    i += 1;
                    self.offset += 1;
                }
            }
            Ok(())
        }

        /// Signal end of input: closes a pending number token and verifies
        /// exactly one complete top-level value was seen.
        pub fn finish(&mut self, out: &mut Vec<Event>) -> Result<(), JsonError> {
            if self.failed {
                return Err(JsonError {
                    offset: self.offset,
                    msg: "parser already failed".into(),
                });
            }
            match std::mem::replace(&mut self.token, Token::None) {
                Token::None => {}
                Token::Num { buf, .. } => self.close_number(&buf, out)?,
                Token::Str { .. } => return Err(self.fail("unterminated string")),
                Token::Lit { .. } => return Err(self.fail("truncated literal")),
            }
            if self.phase != Phase::Done {
                return Err(self.fail("unexpected end of input"));
            }
            Ok(())
        }

        /// Process one byte; `Ok(false)` means the byte closed a number
        /// token and must be re-processed structurally.
        fn step(&mut self, b: u8, out: &mut Vec<Event>) -> Result<bool, JsonError> {
            match std::mem::replace(&mut self.token, Token::None) {
                Token::None => self.structural(b, out).map(|()| true),
                Token::Lit { want, got } => {
                    if want[got] != b {
                        return Err(self.fail("invalid literal"));
                    }
                    let got = got + 1;
                    if got == want.len() {
                        out.push(match want[0] {
                            b'n' => Event::Null,
                            b't' => Event::Bool(true),
                            _ => Event::Bool(false),
                        });
                        self.value_done();
                    } else {
                        self.token = Token::Lit { want, got };
                    }
                    Ok(true)
                }
                Token::Num { mut buf, pos } => match num_step(pos, b) {
                    Some(next) => {
                        if buf.len() >= self.limits.max_token_bytes {
                            return Err(self.fail("number token exceeds limit"));
                        }
                        buf.push(b as char);
                        self.token = Token::Num { buf, pos: next };
                        Ok(true)
                    }
                    None => {
                        self.close_number(&buf, out)?;
                        Ok(false)
                    }
                },
                Token::Str { mut buf, key, esc } => {
                    match esc {
                        Esc::Start => {
                            let mapped: u8 = match b {
                                b'"' => b'"',
                                b'\\' => b'\\',
                                b'/' => b'/',
                                b'n' => b'\n',
                                b't' => b'\t',
                                b'r' => b'\r',
                                b'b' => 0x08,
                                b'f' => 0x0c,
                                b'u' => {
                                    self.token = Token::Str {
                                        buf,
                                        key,
                                        esc: Esc::Hex { hex: [0; 4], n: 0 },
                                    };
                                    return Ok(true);
                                }
                                _ => return Err(self.fail("bad escape")),
                            };
                            if buf.len() >= self.limits.max_token_bytes {
                                return Err(self.fail("string token exceeds limit"));
                            }
                            buf.push(mapped);
                            self.token = Token::Str {
                                buf,
                                key,
                                esc: Esc::None,
                            };
                            Ok(true)
                        }
                        Esc::Hex { mut hex, n } => {
                            hex[n] = b;
                            let n = n + 1;
                            if n < 4 {
                                self.token = Token::Str {
                                    buf,
                                    key,
                                    esc: Esc::Hex { hex, n },
                                };
                                return Ok(true);
                            }
                            let cp = match std::str::from_utf8(&hex) {
                                Ok(h) => u32::from_str_radix(h, 16).ok(),
                                Err(_) => None,
                            };
                            let Some(cp) = cp else {
                                return Err(self.fail("bad \\u escape"));
                            };
                            // Lone surrogates become U+FFFD, matching the
                            // batch parser.
                            let c = char::from_u32(cp).unwrap_or('\u{fffd}');
                            if buf.len() + c.len_utf8() > self.limits.max_token_bytes {
                                return Err(self.fail("string token exceeds limit"));
                            }
                            let mut tmp = [0u8; 4];
                            buf.extend_from_slice(c.encode_utf8(&mut tmp).as_bytes());
                            self.token = Token::Str {
                                buf,
                                key,
                                esc: Esc::None,
                            };
                            Ok(true)
                        }
                        Esc::None => match b {
                            b'"' => {
                                let s = match String::from_utf8(buf) {
                                    Ok(s) => s,
                                    Err(_) => return Err(self.fail("invalid utf-8")),
                                };
                                if key {
                                    out.push(Event::Key(s));
                                    self.phase = Phase::Colon;
                                } else {
                                    out.push(Event::Str(s));
                                    self.value_done();
                                }
                                Ok(true)
                            }
                            b'\\' => {
                                self.token = Token::Str {
                                    buf,
                                    key,
                                    esc: Esc::Start,
                                };
                                Ok(true)
                            }
                            _ => {
                                if buf.len() >= self.limits.max_token_bytes {
                                    return Err(self.fail("string token exceeds limit"));
                                }
                                buf.push(b);
                                self.token = Token::Str {
                                    buf,
                                    key,
                                    esc: Esc::None,
                                };
                                Ok(true)
                            }
                        },
                    }
                }
            }
        }

        fn close_number(&mut self, buf: &str, out: &mut Vec<Event>) -> Result<(), JsonError> {
            match buf.parse::<f64>() {
                Ok(x) => {
                    out.push(Event::Num(x));
                    self.value_done();
                    Ok(())
                }
                Err(_) => Err(self.fail("bad number")),
            }
        }

        fn value_done(&mut self) {
            self.token = Token::None;
            self.phase = if self.stack.is_empty() {
                Phase::Done
            } else {
                Phase::CommaOrEnd
            };
        }

        /// Dispatch a byte arriving between tokens.
        fn structural(&mut self, b: u8, out: &mut Vec<Event>) -> Result<(), JsonError> {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                return Ok(());
            }
            match self.phase {
                Phase::Value | Phase::FirstValueOrEnd => {
                    if self.phase == Phase::FirstValueOrEnd && b == b']' {
                        self.stack.pop();
                        out.push(Event::ArrEnd);
                        self.value_done();
                        return Ok(());
                    }
                    self.begin_value(b, out)
                }
                Phase::FirstKeyOrEnd => match b {
                    b'"' => {
                        self.token = Token::Str {
                            buf: Vec::new(),
                            key: true,
                            esc: Esc::None,
                        };
                        Ok(())
                    }
                    b'}' => {
                        self.stack.pop();
                        out.push(Event::ObjEnd);
                        self.value_done();
                        Ok(())
                    }
                    _ => Err(self.fail("expected '\"' or '}'")),
                },
                Phase::Key => match b {
                    b'"' => {
                        self.token = Token::Str {
                            buf: Vec::new(),
                            key: true,
                            esc: Esc::None,
                        };
                        Ok(())
                    }
                    _ => Err(self.fail("expected '\"'")),
                },
                Phase::Colon => match b {
                    b':' => {
                        self.phase = Phase::Value;
                        Ok(())
                    }
                    _ => Err(self.fail("expected ':'")),
                },
                Phase::CommaOrEnd => {
                    let Some(&ctx) = self.stack.last() else {
                        return Err(self.fail("parser state error"));
                    };
                    match (ctx, b) {
                        (Ctx::Arr, b',') => {
                            self.phase = Phase::Value;
                            Ok(())
                        }
                        (Ctx::Obj, b',') => {
                            self.phase = Phase::Key;
                            Ok(())
                        }
                        (Ctx::Arr, b']') | (Ctx::Obj, b'}') => {
                            self.stack.pop();
                            out.push(if ctx == Ctx::Arr {
                                Event::ArrEnd
                            } else {
                                Event::ObjEnd
                            });
                            self.value_done();
                            Ok(())
                        }
                        (Ctx::Arr, _) => Err(self.fail("expected ',' or ']'")),
                        (Ctx::Obj, _) => Err(self.fail("expected ',' or '}'")),
                    }
                }
                Phase::Done => Err(self.fail("trailing data")),
            }
        }

        /// Start a value from its first byte.
        fn begin_value(&mut self, b: u8, out: &mut Vec<Event>) -> Result<(), JsonError> {
            match b {
                b'n' => {
                    self.token = Token::Lit {
                        want: b"null",
                        got: 1,
                    };
                    Ok(())
                }
                b't' => {
                    self.token = Token::Lit {
                        want: b"true",
                        got: 1,
                    };
                    Ok(())
                }
                b'f' => {
                    self.token = Token::Lit {
                        want: b"false",
                        got: 1,
                    };
                    Ok(())
                }
                b'"' => {
                    self.token = Token::Str {
                        buf: Vec::new(),
                        key: false,
                        esc: Esc::None,
                    };
                    Ok(())
                }
                b'-' | b'0'..=b'9' => {
                    self.token = Token::Num {
                        buf: (b as char).to_string(),
                        pos: NumPos::Int,
                    };
                    Ok(())
                }
                b'[' | b'{' => {
                    if self.stack.len() >= self.limits.max_depth {
                        return Err(self.fail("nesting depth exceeds limit"));
                    }
                    if b == b'[' {
                        self.stack.push(Ctx::Arr);
                        out.push(Event::ArrStart);
                        self.phase = Phase::FirstValueOrEnd;
                    } else {
                        self.stack.push(Ctx::Obj);
                        out.push(Event::ObjStart);
                        self.phase = Phase::FirstKeyOrEnd;
                    }
                    Ok(())
                }
                _ => Err(self.fail("unexpected character")),
            }
        }
    }

    /// Partially built container on the [`ValueBuilder`] stack.
    #[derive(Debug)]
    enum Partial {
        Arr(Vec<Json>),
        Obj(BTreeMap<String, Json>, Option<String>),
    }

    /// Reassembles a [`StreamParser`] event sequence into a [`Json`] tree,
    /// with the batch parser's last-wins semantics for duplicate keys.
    #[derive(Debug, Default)]
    pub struct ValueBuilder {
        stack: Vec<Partial>,
        root: Option<Json>,
    }

    impl ValueBuilder {
        /// Empty builder.
        pub fn new() -> Self {
            ValueBuilder::default()
        }

        /// Apply the next event. Event sequences produced by a
        /// [`StreamParser`] never error here; the checks guard misuse.
        pub fn on_event(&mut self, ev: Event) -> Result<(), JsonError> {
            let bad = || JsonError {
                offset: 0,
                msg: "malformed event sequence".into(),
            };
            match ev {
                Event::ArrStart => {
                    self.stack.push(Partial::Arr(Vec::new()));
                    Ok(())
                }
                Event::ObjStart => {
                    self.stack.push(Partial::Obj(BTreeMap::new(), None));
                    Ok(())
                }
                Event::Key(k) => match self.stack.last_mut() {
                    Some(Partial::Obj(_, pending @ None)) => {
                        *pending = Some(k);
                        Ok(())
                    }
                    _ => Err(bad()),
                },
                Event::ArrEnd => match self.stack.pop() {
                    Some(Partial::Arr(v)) => self.attach(Json::Arr(v)),
                    _ => Err(bad()),
                },
                Event::ObjEnd => match self.stack.pop() {
                    Some(Partial::Obj(m, None)) => self.attach(Json::Obj(m)),
                    _ => Err(bad()),
                },
                Event::Null => self.attach(Json::Null),
                Event::Bool(b) => self.attach(Json::Bool(b)),
                Event::Num(x) => self.attach(Json::Num(x)),
                Event::Str(s) => self.attach(Json::Str(s)),
            }
        }

        fn attach(&mut self, v: Json) -> Result<(), JsonError> {
            match self.stack.last_mut() {
                Some(Partial::Arr(items)) => {
                    items.push(v);
                    Ok(())
                }
                Some(Partial::Obj(m, pending)) => match pending.take() {
                    Some(k) => {
                        m.insert(k, v);
                        Ok(())
                    }
                    None => Err(JsonError {
                        offset: 0,
                        msg: "value without key".into(),
                    }),
                },
                None => {
                    if self.root.is_some() {
                        return Err(JsonError {
                            offset: 0,
                            msg: "multiple top-level values".into(),
                        });
                    }
                    self.root = Some(v);
                    Ok(())
                }
            }
        }

        /// The finished tree, if a complete top-level value was assembled.
        pub fn take(&mut self) -> Option<Json> {
            if self.stack.is_empty() {
                self.root.take()
            } else {
                None
            }
        }
    }

    /// Parse a document delivered as chunks through the incremental
    /// pipeline, returning the same tree [`Json::parse`] would.
    pub fn parse_chunks(chunks: &[&[u8]], limits: Limits) -> Result<Json, JsonError> {
        let mut p = StreamParser::new(limits);
        let mut b = ValueBuilder::new();
        let mut evs = Vec::new();
        for c in chunks {
            p.feed(c, &mut evs)?;
            for e in evs.drain(..) {
                b.on_event(e)?;
            }
        }
        p.finish(&mut evs)?;
        for e in evs.drain(..) {
            b.on_event(e)?;
        }
        b.take().ok_or_else(|| JsonError {
            offset: 0,
            msg: "incomplete document".into(),
        })
    }

    /// Comma/colon bookkeeping for one open container in the emitter.
    #[derive(Debug)]
    struct EmitFrame {
        ctx: Ctx,
        count: usize,
    }

    /// Incremental JSON writer whose concatenated output is byte-identical
    /// to [`Json::to_string`] of the equivalent materialised tree.
    ///
    /// Interleave structural calls with [`StreamEmitter::take`] to drain
    /// the buffer, so a large document is never resident at once.
    #[derive(Debug, Default)]
    pub struct StreamEmitter {
        out: String,
        stack: Vec<EmitFrame>,
        after_key: bool,
    }

    impl StreamEmitter {
        /// Empty emitter.
        pub fn new() -> Self {
            StreamEmitter::default()
        }

        fn pre_value(&mut self) {
            if self.after_key {
                self.after_key = false;
                return;
            }
            let comma = match self.stack.last_mut() {
                Some(f) => {
                    f.count += 1;
                    f.count > 1
                }
                None => false,
            };
            if comma {
                self.out.push(',');
            }
        }

        /// Emit an object member key (the member value must follow).
        pub fn key(&mut self, k: &str) {
            debug_assert!(!self.after_key, "key() twice without a value");
            let comma = match self.stack.last_mut() {
                Some(f) => {
                    f.count += 1;
                    f.count > 1
                }
                None => false,
            };
            if comma {
                self.out.push(',');
            }
            write_str(&mut self.out, k);
            self.out.push(':');
            self.after_key = true;
        }

        /// Emit `null`.
        pub fn push_null(&mut self) {
            self.pre_value();
            self.out.push_str("null");
        }

        /// Emit a boolean.
        pub fn push_bool(&mut self, b: bool) {
            self.pre_value();
            self.out.push_str(if b { "true" } else { "false" });
        }

        /// Emit a number with [`Json::to_string`] formatting.
        pub fn push_num(&mut self, x: f64) {
            self.pre_value();
            write_num(&mut self.out, x);
        }

        /// Emit a string with [`Json::to_string`] escaping.
        pub fn push_str(&mut self, s: &str) {
            self.pre_value();
            write_str(&mut self.out, s);
        }

        /// Emit a whole materialised subtree in compact form.
        pub fn value(&mut self, v: &Json) {
            self.pre_value();
            v.write(&mut self.out, None, 0);
        }

        /// Open an array.
        pub fn begin_arr(&mut self) {
            self.pre_value();
            self.out.push('[');
            self.stack.push(EmitFrame {
                ctx: Ctx::Arr,
                count: 0,
            });
        }

        /// Close the innermost array.
        pub fn end_arr(&mut self) {
            debug_assert!(matches!(self.stack.last(), Some(f) if f.ctx == Ctx::Arr));
            self.stack.pop();
            self.out.push(']');
        }

        /// Open an object.
        pub fn begin_obj(&mut self) {
            self.pre_value();
            self.out.push('{');
            self.stack.push(EmitFrame {
                ctx: Ctx::Obj,
                count: 0,
            });
        }

        /// Close the innermost object.
        pub fn end_obj(&mut self) {
            debug_assert!(!self.after_key, "object closed after dangling key");
            debug_assert!(matches!(self.stack.last(), Some(f) if f.ctx == Ctx::Obj));
            self.stack.pop();
            self.out.push('}');
        }

        /// Drain the buffered output accumulated since the last take.
        pub fn take(&mut self) -> String {
            std::mem::take(&mut self.out)
        }

        /// Bytes currently buffered (un-taken).
        pub fn buffered(&self) -> usize {
            self.out.len()
        }

        /// Current container nesting depth.
        pub fn depth(&self) -> usize {
            self.stack.len()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"mset2_train","shapes":[8,16,32],"pi":3.25,"ok":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo⚡""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo⚡"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(8.0).as_usize(), Some(8));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }

    /// Incremental parse at every two-part split must agree with the batch
    /// parser: same value on success, error on the same inputs.
    fn assert_stream_equiv(src: &str) {
        let batch = Json::parse(src);
        let bytes = src.as_bytes();
        for cut in 0..=bytes.len() {
            let got = stream::parse_chunks(
                &[&bytes[..cut], &bytes[cut..]],
                stream::Limits::lenient(),
            );
            match (&batch, &got) {
                (Ok(b), Ok(g)) => assert_eq!(b, g, "split at {cut} of {src:?}"),
                (Err(_), Err(_)) => {}
                (b, g) => panic!("split at {cut} of {src:?}: batch={b:?} stream={g:?}"),
            }
        }
    }

    #[test]
    fn stream_matches_batch_on_documents() {
        for src in [
            "null",
            " true ",
            "-12.5e2",
            "007",
            "1.",
            "-.5",
            r#""a\nbA✓c""#,
            "[]",
            "{}",
            "[1,2,[3,{\"a\":null}],false]",
            r#"{"name":"mset2_train","shapes":[8,16,32],"pi":3.25,"ok":true,"none":null}"#,
            r#"{"a":1,"a":2}"#,
        ] {
            assert_stream_equiv(src);
        }
    }

    #[test]
    fn stream_rejects_what_batch_rejects() {
        for src in [
            "", "  ", "{", "[1,]", "12 34", r#"{"a" 1}"#, "-", "1e+", "nul", "nullx",
            r#""abc"#, r#""\x""#, r#""\u12"#, "[1 2]", "{,}", "[1,2,],", "tru e",
        ] {
            assert_stream_equiv(src);
        }
    }

    #[test]
    fn stream_depth_limit_binds() {
        let deep = "[".repeat(10) + &"]".repeat(10);
        let limits = stream::Limits {
            max_depth: 4,
            max_token_bytes: 1 << 10,
        };
        assert!(stream::parse_chunks(&[deep.as_bytes()], limits).is_err());
        let ok = "[".repeat(4) + &"]".repeat(4);
        assert!(stream::parse_chunks(&[ok.as_bytes()], limits).is_ok());
    }

    #[test]
    fn stream_token_limit_bounds_memory() {
        let limits = stream::Limits {
            max_depth: 8,
            max_token_bytes: 16,
        };
        let mut p = stream::StreamParser::new(limits);
        let mut evs = Vec::new();
        let long = format!("\"{}\"", "x".repeat(64));
        let err = p
            .feed(long.as_bytes(), &mut evs)
            .expect_err("token cap must bind");
        assert!(err.msg.contains("exceeds limit"));
        assert!(p.buffered_bytes() <= 16 + 4);
    }

    #[test]
    fn emitter_matches_to_string() {
        fn drive(e: &mut stream::StreamEmitter, v: &Json, out: &mut String) {
            match v {
                Json::Null => e.push_null(),
                Json::Bool(b) => e.push_bool(*b),
                Json::Num(x) => e.push_num(*x),
                Json::Str(s) => e.push_str(s),
                Json::Arr(items) => {
                    e.begin_arr();
                    for it in items {
                        drive(e, it, out);
                        out.push_str(&e.take()); // drain mid-document
                    }
                    e.end_arr();
                }
                Json::Obj(m) => {
                    e.begin_obj();
                    for (k, v) in m {
                        e.key(k);
                        drive(e, v, out);
                    }
                    e.end_obj();
                }
            }
        }
        let v = Json::parse(
            r#"{"a":[1,2,{"b":"c\nd"},[],{}],"e":-0.5,"f":null,"g":true,"h":"⚡"}"#,
        )
        .unwrap();
        let mut e = stream::StreamEmitter::new();
        let mut out = String::new();
        drive(&mut e, &v, &mut out);
        out.push_str(&e.take());
        assert_eq!(out, v.to_string());
        assert_eq!(e.depth(), 0);
        assert_eq!(e.buffered(), 0);
    }

    #[test]
    fn emitter_value_subtree_matches() {
        let v = Json::parse(r#"{"rows":[[1,2],[3,4]],"n":2}"#).unwrap();
        let mut e = stream::StreamEmitter::new();
        e.begin_obj();
        e.key("n");
        e.value(v.get("n").unwrap());
        e.key("rows");
        e.value(v.get("rows").unwrap());
        e.end_obj();
        assert_eq!(e.take(), v.to_string());
    }

    #[test]
    fn stream_events_carry_structure() {
        use stream::Event;
        let mut p = stream::StreamParser::new(stream::Limits::default());
        let mut evs = Vec::new();
        p.feed(br#"{"k":[1,"s"#, &mut evs).unwrap();
        p.feed(br#""]}"#, &mut evs).unwrap();
        p.finish(&mut evs).unwrap();
        assert_eq!(
            evs,
            vec![
                Event::ObjStart,
                Event::Key("k".into()),
                Event::ArrStart,
                Event::Num(1.0),
                Event::Str("s".into()),
                Event::ArrEnd,
                Event::ObjEnd,
            ]
        );
        assert!(p.is_done());
    }
}
