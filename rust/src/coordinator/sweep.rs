//! Nested-loop Monte Carlo sweep engine.
//!
//! For each grid cell `(n_signals, n_memvec, n_obs)`:
//!
//! 1. the MSET training constraint `m ≥ 2n` is checked — violating cells
//!    become *gaps* (the missing surface regions of paper Fig. 6);
//! 2. `trials` independent trials run, each on a fresh TPSS synthesis
//!    (deterministically seeded per cell/trial, so results are independent
//!    of scheduling order);
//! 3. each trial measures the **training cost** (memory selection + the
//!    training executable) and the **surveillance cost** (streaming
//!    `n_obs` observations through the surveillance executable);
//! 4. per-cell costs are aggregated into robust summaries.
//!
//! Trials are fanned out as independent `(cell, trial)` tasks over the
//! shared [`TrialExecutor`] and **stream back**: each cell retires the
//! moment its own trials are complete — there is no whole-grid barrier, so
//! one slow cell never holds up aggregation (or the cache write) of the
//! others. Device executions still serialise on the dedicated PJRT thread
//! (see `runtime`), so measured execution times stay contention-free.
//! Native trials run their numeric pipeline on the executing worker's
//! thread-local [`crate::linalg::Workspace`] arena — the long-lived
//! executor threads keep kernel scratch warm across trials (trimmed to a
//! bounded footprint after each one), so steady-state trials stay off
//! the allocator entirely.
//!
//! The fixed-`trials` schedule here is the paper-faithful *exhaustive*
//! mode. Setting [`SweepSpec::ci_target`] hands the same grid to the
//! adaptive planner ([`crate::coordinator::planner`]), which spends trials
//! where cost variance needs them and can skip surface-predictable cells.
//!
//! Because trial seeds are content-derived per `(cell, trial index)`, the
//! executor may run trials in any order, interleaved with any other job's
//! trials, without changing a single measurement input — completion
//! *order* is the only thing scheduling can affect.

use crate::linalg::Mat;
use crate::metrics::Registry;
use crate::models;
use crate::obs::EventBus;
use crate::util::json::Json;
use crate::mset;
use crate::runtime::mset::{DeviceAakr, DeviceMset};
use crate::runtime::DeviceHandle;
use crate::surface::{Sample, SurfaceGrid};
use crate::tpss::{synthesize, TpssConfig};
use crate::util::rng::Rng;
use crate::util::threadpool::{CancelToken, JobTicket, TrialExecutor};
use crate::util::Summary;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock};
use std::time::{Duration, Instant};

/// Sentinel error the sweep engine returns when its job's cancellation
/// token fires mid-run. Callers downcast (`err.is::<Cancelled>()`) to
/// distinguish an operator cancellation from a real failure; whatever
/// trials finished before the cancellation are already in the cell store.
#[derive(Clone, Copy, Debug, thiserror::Error)]
#[error("sweep cancelled")]
pub struct Cancelled;

/// Live progress of one sweep, updated atomically from executor worker
/// threads (trial counts) and the driving thread (cell retirements) while
/// the sweep runs. Every counter is monotone non-decreasing over a job's
/// lifetime, so pollers can rely on `trials_done / trials_planned` never
/// moving backwards.
#[derive(Debug, Default)]
pub struct SweepProgress {
    /// Freshly executed trials (cache-served trials are not counted).
    pub trials_done: AtomicUsize,
    /// Trials scheduled so far; grows as the adaptive planner tops up.
    pub trials_planned: AtomicUsize,
    /// Grid cells in the sweep, constraint gaps included.
    pub cells_total: AtomicUsize,
    /// Cells with a final result (measured, interpolated, or gap).
    pub cells_done: AtomicUsize,
    /// Cells accepted at pilot precision by the planner's surface model.
    pub cells_interpolated: AtomicUsize,
    /// Live event sink for `/events` streams; attached once by the job
    /// layer before the sweep starts (absent for library callers, which
    /// keeps the hot path free of any publishing cost).
    events: OnceLock<Arc<EventBus>>,
}

impl SweepProgress {
    /// Attach the live event bus cell retirements publish to. At most one
    /// bus per progress; later calls are no-ops.
    pub fn attach_events(&self, bus: Arc<EventBus>) {
        let _ = self.events.set(bus);
    }

    /// The attached live event bus, if any.
    pub fn event_bus(&self) -> Option<&Arc<EventBus>> {
        self.events.get()
    }

    /// Publish a cell-retirement event to the attached bus (no-op
    /// without one). `source` says how the cell's summary was obtained:
    /// `"measured"`, `"cached"`, `"interpolated"`, or `"gap"`.
    pub fn emit_cell(&self, key: CellKey, source: &str) {
        if let Some(bus) = self.events.get() {
            bus.publish_json(&Json::obj(vec![
                ("event", Json::Str("cell".to_string())),
                (
                    "cell",
                    Json::Str(format!("{}/{}/{}", key.n, key.m, key.obs)),
                ),
                ("source", Json::Str(source.to_string())),
                (
                    "cells_done",
                    Json::Num(self.cells_done.load(Ordering::SeqCst) as f64),
                ),
                (
                    "cells_total",
                    Json::Num(self.cells_total.load(Ordering::SeqCst) as f64),
                ),
                (
                    "trials_done",
                    Json::Num(self.trials_done.load(Ordering::SeqCst) as f64),
                ),
            ]));
        }
    }

    /// Plain-value copy for status reporting (each field is read
    /// atomically; the set is only loosely consistent, which is fine for
    /// a progress gauge).
    pub fn snapshot(&self) -> ProgressSnapshot {
        ProgressSnapshot {
            trials_done: self.trials_done.load(Ordering::SeqCst),
            trials_planned: self.trials_planned.load(Ordering::SeqCst),
            cells_total: self.cells_total.load(Ordering::SeqCst),
            cells_done: self.cells_done.load(Ordering::SeqCst),
            cells_interpolated: self.cells_interpolated.load(Ordering::SeqCst),
        }
    }
}

/// Plain-value snapshot of a [`SweepProgress`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProgressSnapshot {
    /// Freshly executed trials.
    pub trials_done: usize,
    /// Trials scheduled so far.
    pub trials_planned: usize,
    /// Grid cells in the sweep.
    pub cells_total: usize,
    /// Cells with a final result.
    pub cells_done: usize,
    /// Cells accepted via surface interpolation.
    pub cells_interpolated: usize,
}

/// Per-trial measured costs of one cell (seconds), in trial-index order —
/// entry `t` was measured under the content-derived seed for trial `t`, so
/// stored vectors can be extended trial-by-trial (the planner's top-ups)
/// or truncated to a prefix (an exhaustive request against a longer entry)
/// without invalidating the measurements.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CellCosts {
    /// Training-phase wall time per trial.
    pub train_s: Vec<f64>,
    /// Surveillance-phase wall time per trial.
    pub surveil_s: Vec<f64>,
}

impl CellCosts {
    /// Normalise a fetched entry against a per-cell trial limit: both
    /// phases are truncated to the shorter of the two (they share one
    /// trial schedule — a mismatch means a foreign or corrupt store) and
    /// to `limit`. Returns the resulting usable trial count.
    pub fn normalize(&mut self, limit: usize) -> usize {
        let n = self.train_s.len().min(self.surveil_s.len()).min(limit);
        self.train_s.truncate(n);
        self.surveil_s.truncate(n);
        n
    }
}

/// A store of per-cell measurements the sweep engine can consult before
/// scheduling trials. Implemented by [`crate::service::cache::SweepCache`];
/// the coordinator only sees this trait, keeping the service a layer above
/// it rather than a dependency of it.
pub trait CellStore: Send + Sync {
    /// Measurements for `cell` under an identical `(spec, backend)`
    /// context, if present: the stored prefix of the cell's deterministic
    /// trial sequence, whatever its current length. Callers must treat a
    /// returned entry as reusable — serve from it, or top it up with the
    /// missing trial indices — never discard it.
    fn fetch(&self, cell: CellKey, spec: &SweepSpec, backend: &str) -> Option<CellCosts>;
    /// Record the (possibly extended) trial costs for `cell`, replacing
    /// any previous entry.
    fn store(&self, cell: CellKey, spec: &SweepSpec, backend: &str, costs: CellCosts);
}

/// Where trials execute.
#[derive(Clone)]
pub enum Backend {
    /// AOT artifacts through the PJRT device thread (production path).
    Device(DeviceHandle),
    /// Native Rust implementation (comparator / no-artifact fallback).
    Native,
}

impl Backend {
    /// Stable tag used in cache keys and logs.
    pub fn tag(&self) -> &'static str {
        match self {
            Backend::Device(_) => "device",
            Backend::Native => "native",
        }
    }
}

/// Sweep specification (the outer loops of paper Fig. 1).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Signal-count axis (`n`).
    pub signals: Vec<usize>,
    /// Memory-vector axis (`m`).
    pub memvecs: Vec<usize>,
    /// Observation-count axis (`N`).
    pub obs: Vec<usize>,
    /// Monte Carlo trials per cell (exhaustive mode).
    pub trials: usize,
    /// Root seed; every trial seed is derived from it and the cell content.
    pub seed: u64,
    /// Pluggable model: `mset2` | `aakr` | `ridge`.
    pub model: String,
    /// Worker threads for trial fan-out (0 = auto).
    pub workers: usize,
    /// Adaptive planner: trials per cell in the cheap pilot round.
    pub pilot_trials: usize,
    /// Adaptive planner: relative 95%-CI half-width target that stops trial
    /// allocation for a cell. `0.0` disables the planner entirely — the
    /// sweep runs the exhaustive fixed-`trials` loop, which is what the
    /// Fig. 4–8 reproductions rely on for bit-identical trial schedules.
    pub ci_target: f64,
    /// Adaptive planner: per-cell trial cap
    /// (`0` = `max(trials, pilot_trials)`).
    pub max_trials: usize,
    /// Adaptive planner: allow the surface-model pruning step to skip cells
    /// whose cost is already predicted accurately (such cells are marked
    /// [`CellMeasure::interpolated`] in the result).
    pub interpolate: bool,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            signals: vec![8, 16],
            memvecs: vec![32, 64],
            obs: vec![256],
            trials: 3,
            seed: 7,
            model: "mset2".into(),
            workers: 0,
            pilot_trials: 2,
            ci_target: 0.0,
            max_trials: 0,
            interpolate: true,
        }
    }
}

impl SweepSpec {
    /// Reject specs that cannot run: unknown model, zero trials, or empty
    /// sweep axes (e.g. `"signals": []` in a config file or service
    /// request) — callers get a clean error instead of a downstream panic.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(
                self.model.as_str(),
                "mset2" | "aakr" | "ridge" | "mlp" | "svr"
            ),
            "model must be mset2|aakr|ridge|mlp|svr, got '{}'",
            self.model
        );
        anyhow::ensure!(self.trials >= 1, "trials must be ≥ 1");
        anyhow::ensure!(
            !self.signals.is_empty() && !self.memvecs.is_empty() && !self.obs.is_empty(),
            "sweep axes must be non-empty"
        );
        anyhow::ensure!(
            self.ci_target >= 0.0, // also rejects NaN
            "ci_target must be ≥ 0 (0 disables the adaptive planner)"
        );
        if self.adaptive() {
            anyhow::ensure!(self.ci_target.is_finite(), "ci_target must be finite");
            anyhow::ensure!(
                self.pilot_trials >= 2,
                "pilot_trials must be ≥ 2 (a variance estimate needs two samples)"
            );
            anyhow::ensure!(
                self.effective_max_trials() >= self.pilot_trials,
                "max_trials ({}) must be ≥ pilot_trials ({})",
                self.effective_max_trials(),
                self.pilot_trials
            );
        }
        Ok(())
    }

    /// Whether the adaptive planner is enabled (`ci_target > 0`). Disabled
    /// specs run the exhaustive nested loop unchanged.
    pub fn adaptive(&self) -> bool {
        self.ci_target > 0.0
    }

    /// Per-cell trial cap in adaptive mode: `max_trials`, defaulting to
    /// `max(trials, pilot_trials)` when unset (0).
    pub fn effective_max_trials(&self) -> usize {
        if self.max_trials == 0 {
            self.trials.max(self.pilot_trials)
        } else {
            self.max_trials
        }
    }

    /// Worker threads for trial fan-out: `workers`, defaulting to the
    /// machine's available parallelism when unset (0).
    pub fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::threadpool::default_workers()
        } else {
            self.workers
        }
    }

    /// Whether a cell is a constraint gap (`m < 2n` under MSET training).
    pub(crate) fn is_gap(&self, key: CellKey) -> bool {
        key.m < 2 * key.n && self.model == "mset2"
    }
}

/// One grid-cell coordinate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CellKey {
    /// Number of signals.
    pub n: usize,
    /// Number of memory vectors.
    pub m: usize,
    /// Number of observations.
    pub obs: usize,
}

/// Aggregated measurements for one cell.
#[derive(Clone, Debug)]
pub struct CellMeasure {
    /// Grid coordinate of the cell.
    pub key: CellKey,
    /// `None` when the training constraint `m ≥ 2n` is violated (gap).
    pub train: Option<Summary>,
    /// Surveillance-phase summary (`None` for gaps).
    pub surveil: Option<Summary>,
    /// Training constraint violated — the cell has no measurements.
    pub violated: bool,
    /// Accepted early by the adaptive planner's surface model instead of
    /// being measured to the CI target — at pilot precision in a
    /// cold-cache run; a cache-preloaded cell may carry more trials than
    /// the pilot when pruned. Always `false` in exhaustive mode; see
    /// [`crate::coordinator::planner`].
    pub interpolated: bool,
    /// The cell exhausted its trial retries and was **quarantined**: the
    /// sweep kept going and this entry carries whatever contiguous trial
    /// prefix succeeded (possibly none). Failed cells are excluded from
    /// surface fits, panels, and recommendations; a job only errors when
    /// *every* measurable cell fails.
    pub failed: bool,
}

/// Complete sweep output.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// The spec the sweep ran under.
    pub spec: SweepSpec,
    /// One entry per distinct grid cell, in grid order.
    pub cells: Vec<CellMeasure>,
}

/// Per-trial raw timings.
#[derive(Clone, Copy, Debug)]
pub(crate) struct TrialCost {
    pub(crate) train_s: f64,
    pub(crate) surveil_s: f64,
}

pub(crate) fn run_trial(
    backend: &Backend,
    model_name: &str,
    key: CellKey,
    seed: u64,
) -> anyhow::Result<TrialCost> {
    let CellKey { n, m, obs } = key;
    // Training window: the paper's "number of observations in the training
    // process" is the obs axis for the training phase.
    let train_rows = obs.max(m); // need at least m candidates to select from
    let train_ds = synthesize(&TpssConfig::sized(n, train_rows), seed);
    let probe_ds = synthesize(&TpssConfig::sized(n, obs), seed ^ 0x5EED);

    match backend {
        Backend::Device(handle) => {
            // Selection + scaling are part of the measured training phase
            // (they are training work), then the device executes.
            let t0 = Instant::now();
            let scaler = mset::Scaler::fit(&train_ds.data);
            let xs = scaler.transform(&train_ds.data);
            let idx = mset::select_memory(&xs, m);
            let mut d = Mat::zeros(m, n);
            for (r, &i) in idx.iter().enumerate() {
                d.row_mut(r).copy_from_slice(xs.row(i));
            }
            let prep_s = t0.elapsed().as_secs_f64();
            let probe_scaled = scaler.transform(&probe_ds.data);

            match model_name {
                "mset2" => {
                    let mut sess = DeviceMset::new(handle.clone(), &d)?;
                    let (_, tcost) = sess.train()?;
                    Registry::global().inc("sweep.device.train_calls");
                    let (_, _, scost) = sess.surveil(&probe_scaled)?;
                    Registry::global().add("sweep.device.surveil_calls", scost.calls as u64);
                    Ok(TrialCost {
                        train_s: prep_s + tcost.exec.as_secs_f64(),
                        surveil_s: scost.exec.as_secs_f64(),
                    })
                }
                "aakr" => {
                    let sess = DeviceAakr::new(handle.clone(), &d)?;
                    let (_, _, scost) = sess.surveil(&probe_scaled)?;
                    Ok(TrialCost {
                        train_s: prep_s, // AAKR "training" = selection only
                        surveil_s: scost.exec.as_secs_f64(),
                    })
                }
                other => anyhow::bail!(
                    "model '{other}' has no device artifacts; use --backend native"
                ),
            }
        }
        Backend::Native => {
            let mut plugin = models::by_name(model_name)?;
            let t0 = Instant::now();
            plugin.fit(&train_ds.data, m)?;
            let train_s = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let _est = plugin.estimate(&probe_ds.data);
            let surveil_s = t1.elapsed().as_secs_f64();
            Ok(TrialCost { train_s, surveil_s })
        }
    }
}

/// Trial-seed tag derived from the cell *content*, not its grid position,
/// so a cell's measurements are identical no matter which request's grid it
/// appears in — the property that makes the sweep cache content-addressed.
fn cell_tag(key: CellKey) -> u64 {
    crate::util::fnv1a(format!("{}/{}/{}", key.n, key.m, key.obs).as_bytes())
}

/// Seed for trial `t` of `key`: forked from the spec's root seed by the
/// cell-content tag plus the trial index. A cell's trial `t` therefore sees
/// the same synthetic telemetry regardless of grid composition, scheduling
/// order, worker count, or whether the exhaustive loop or the adaptive
/// planner asked for it — the invariant both the sweep cache and the
/// planner's incremental trial top-ups rely on.
pub(crate) fn trial_seed(spec: &SweepSpec, key: CellKey, t: usize) -> u64 {
    let mut rng = Rng::new(spec.seed).fork(cell_tag(key).wrapping_add(t as u64));
    rng.next_u64()
}

/// The spec's distinct grid cells in deterministic nested-loop order.
/// Duplicate axis values would create duplicate cells (double-counted
/// trials, conflicting cache writes) — each distinct cell appears once.
pub(crate) fn grid_keys(spec: &SweepSpec) -> Vec<CellKey> {
    let mut keys = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for &n in &spec.signals {
        for &m in &spec.memvecs {
            for &obs in &spec.obs {
                let key = CellKey { n, m, obs };
                if seen.insert(key) {
                    keys.push(key);
                }
            }
        }
    }
    keys
}

/// Run the full nested-loop Monte Carlo sweep.
pub fn run_sweep(spec: &SweepSpec, backend: Backend) -> anyhow::Result<SweepResult> {
    run_sweep_cached(spec, backend, None)
}

/// [`run_sweep`] with an optional cell-level cache: cells already measured
/// under an identical `(cell, model, seed, backend)` context are reused
/// without scheduling any trials; freshly measured cells are inserted for
/// future requests. Because trial seeds are content-derived per trial
/// index, a stored entry with at least `trials` measurements serves the
/// request as a prefix, and a shorter one is topped up with only the
/// missing trial indices (the merged entry is written back).
///
/// When [`SweepSpec::adaptive`] is set the sweep is delegated to the
/// [`crate::coordinator::planner`], which spends trials where the cost
/// variance needs them instead of uniformly (cached measurements count
/// toward its convergence target for free).
///
/// Standalone entry point: spins up a private [`TrialExecutor`] sized by
/// [`SweepSpec::effective_workers`]. Services sharing one executor across
/// jobs call [`run_sweep_executor`] instead.
pub fn run_sweep_cached(
    spec: &SweepSpec,
    backend: Backend,
    cache: Option<&dyn CellStore>,
) -> anyhow::Result<SweepResult> {
    spec.validate()?;
    let exec = TrialExecutor::new(spec.effective_workers(), true);
    let ticket = exec.register(1.0);
    let progress = Arc::new(SweepProgress::default());
    run_sweep_executor(spec, backend, cache, &ticket, &progress)
}

/// Run a sweep on a caller-provided executor job: the service's shared
/// [`TrialExecutor`] interleaves this sweep's `(cell, trial)` tasks fairly
/// with every other job's. `progress` is updated live; cancelling the
/// ticket's token makes the engine stop scheduling, drain in-flight
/// trials, flush every finished trial prefix to the cell store, and
/// return [`Cancelled`].
pub fn run_sweep_executor(
    spec: &SweepSpec,
    backend: Backend,
    cache: Option<&dyn CellStore>,
    ticket: &JobTicket,
    progress: &Arc<SweepProgress>,
) -> anyhow::Result<SweepResult> {
    spec.validate()?;
    if ticket.cancel_token().is_cancelled() {
        return Err(Cancelled.into());
    }
    if spec.adaptive() {
        return super::planner::run_adaptive(spec, backend, cache, ticket, progress);
    }
    run_exhaustive_streaming(spec, backend, cache, ticket, progress)
}

/// Per-cell accumulator for the streaming exhaustive engine.
struct CellAcc {
    key: CellKey,
    /// Cached prefix; extended with fresh trials at retirement.
    costs: CellCosts,
    /// Trials preloaded from the cache (length of the stored prefix).
    cached: usize,
    /// Fresh results by `trial_index - cached` (completion order varies).
    fresh: Vec<Option<TrialCost>>,
    /// Fresh results still outstanding.
    remaining: usize,
    /// At least one trial exhausted its retries: the cell will retire
    /// quarantined (see [`CellMeasure::failed`]).
    failed: bool,
}

fn measure_of(key: CellKey, costs: &CellCosts) -> CellMeasure {
    CellMeasure {
        key,
        train: Some(Summary::of(&costs.train_s)),
        surveil: Some(Summary::of(&costs.surveil_s)),
        violated: false,
        interpolated: false,
        failed: false,
    }
}

/// Quarantined-cell measure: summaries over whatever contiguous trial
/// prefix survived (absent when nothing did).
pub(crate) fn failed_measure(key: CellKey, costs: &CellCosts) -> CellMeasure {
    Registry::global().inc("sweep.failed_cells");
    CellMeasure {
        key,
        train: (!costs.train_s.is_empty()).then(|| Summary::of(&costs.train_s)),
        surveil: (!costs.surveil_s.is_empty()).then(|| Summary::of(&costs.surveil_s)),
        violated: false,
        interpolated: false,
        failed: true,
    }
}

pub(crate) fn gap_measure(key: CellKey) -> CellMeasure {
    Registry::global().inc("sweep.gap_cells");
    CellMeasure {
        key,
        train: None,
        surveil: None,
        violated: true,
        interpolated: false,
        failed: false,
    }
}

/// Trial retry budget: a failing or panicking trial is re-attempted this
/// many times before the engine gives up on it and quarantines the cell.
pub(crate) const TRIAL_MAX_RETRIES: u64 = 2;

/// Render a caught panic payload (the common `&str`/`String` cases).
pub(crate) fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One guarded trial attempt: the `executor.trial.run` failpoint, then the
/// real measurement, with panics contained and converted to errors — a
/// poisoned trial must cost the job one retry, not the whole sweep (the
/// executor's `worker_loop` only logs escaped panics, permanently losing
/// the in-flight trial's result slot).
fn attempt_trial(
    backend: &Backend,
    model: &str,
    key: CellKey,
    seed: u64,
    attempt: u64,
) -> anyhow::Result<TrialCost> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        crate::util::failpoint::hit("executor.trial.run", seed.wrapping_add(attempt))?;
        run_trial(backend, model, key, seed)
    }))
    .unwrap_or_else(|p| Err(anyhow::anyhow!("trial task panicked: {}", panic_text(&*p))))
}

/// Run a trial with bounded retries and deterministic backoff + jitter.
/// The backoff schedule derives from the trial seed, so chaos runs replay
/// identically; delays are milliseconds — retries are for transient faults
/// (an injected fault, a panicked model, a blip), not capacity waits.
fn run_trial_with_retries(
    backend: &Backend,
    model: &str,
    key: CellKey,
    seed: u64,
    cancel: &CancelToken,
) -> anyhow::Result<TrialCost> {
    let mut attempt: u64 = 0;
    loop {
        match attempt_trial(backend, model, key, seed, attempt) {
            Ok(c) => return Ok(c),
            Err(e) => {
                if attempt >= TRIAL_MAX_RETRIES || cancel.is_cancelled() {
                    Registry::global().inc("executor.trial.failed");
                    log::warn!(
                        "trial {}/{}/{} seed {seed:#x} failed after {attempt} retries: {e:#}",
                        key.n,
                        key.m,
                        key.obs
                    );
                    return Err(e);
                }
                attempt += 1;
                Registry::global().inc("executor.trial.retries");
                let base_ms = 1u64 << (attempt - 1).min(4);
                let jitter_ms = Rng::new(seed ^ attempt.rotate_left(13)).below(base_ms + 1);
                std::thread::sleep(Duration::from_millis(base_ms + jitter_ms));
            }
        }
    }
}

/// Queue one `(cell, trial)` measurement on the job's executor queue. The
/// result lands on `tx` tagged `(slot, t)` — a task reclaimed by a
/// cancellation simply drops its sender without reporting. Shared by the
/// exhaustive engine and the adaptive planner so both schedule trials
/// identically. An `Err` result means the trial exhausted
/// [`TRIAL_MAX_RETRIES`] — the engines quarantine the owning cell rather
/// than failing the job.
#[allow(clippy::too_many_arguments)]
pub(crate) fn submit_trial(
    ticket: &JobTicket,
    spec: &SweepSpec,
    backend: &Backend,
    key: CellKey,
    slot: usize,
    t: usize,
    tx: &mpsc::Sender<(usize, usize, anyhow::Result<TrialCost>)>,
    progress: &Arc<SweepProgress>,
    cancel: &CancelToken,
) {
    let seed = trial_seed(spec, key, t);
    let tx = tx.clone();
    let backend = backend.clone();
    let model = spec.model.clone();
    let progress = Arc::clone(progress);
    let cancel = cancel.clone();
    // Span plumbing: the submitting thread (the job driver) carries the
    // job's flight recorder in its thread-local; move the Arc into the
    // closure so spans recorded on whichever executor worker runs the
    // trial still land in the right job's ring. `None` (plain CLI sweeps,
    // benches) keeps the hot path span-free.
    let recorder = crate::obs::current();
    let enqueued = Instant::now();
    ticket.submit(move || {
        if cancel.is_cancelled() {
            return; // dequeued just before the reclaim swept it
        }
        let started = Instant::now();
        let queue_wait = started.saturating_duration_since(enqueued);
        let r = run_trial_with_retries(&backend, &model, key, seed, &cancel);
        // The native numeric pipeline runs on this worker's thread-local
        // kernel workspace (zero steady-state allocations); keep the
        // arena warm for the next trial but bound what a huge cell can
        // leave pinned per worker.
        crate::linalg::workspace::trim_thread(crate::linalg::workspace::DEFAULT_RETAIN_ELEMS);
        Registry::global().inc("sweep.trials");
        Registry::global().time("sweep.trial_seconds", started.elapsed());
        Registry::global().time("executor.queue_wait_seconds", queue_wait);
        progress.trials_done.fetch_add(1, Ordering::SeqCst);
        if let Some(rec) = &recorder {
            let ended = Instant::now();
            let meta = format!("cell={}/{}/{} trial={t}", key.n, key.m, key.obs);
            match &r {
                Ok(cost) => {
                    // Split the run window at the measured train/surveil
                    // boundary: queue wait is charged to the train span
                    // (the task's wait), the surveil span follows on.
                    let split = started
                        + Duration::from_secs_f64(cost.train_s.clamp(0.0, 1e9));
                    let split = split.min(ended);
                    rec.push("trial", "train", started, split, queue_wait, meta.clone());
                    rec.push("trial", "surveil", split, ended, Duration::ZERO, meta);
                }
                Err(_) => {
                    rec.push("trial", "error", started, ended, queue_wait, meta);
                }
            }
        }
        let _ = tx.send((slot, t, r));
    });
}

/// The exhaustive fixed-`trials` schedule, streamed: every missing
/// `(cell, trial)` is submitted up front, results retire each cell
/// independently as its last trial lands, and the deterministic
/// trial-index order of the aggregated vectors is restored from the trial
/// index carried with each result — so per-cell summaries are bit-identical
/// to the sequential nested loop no matter how the executor interleaves.
fn run_exhaustive_streaming(
    spec: &SweepSpec,
    backend: Backend,
    cache: Option<&dyn CellStore>,
    ticket: &JobTicket,
    progress: &Arc<SweepProgress>,
) -> anyhow::Result<SweepResult> {
    let keys = grid_keys(spec);
    let cancel = ticket.cancel_token();
    progress.cells_total.store(keys.len(), Ordering::SeqCst);

    // Probe the cache and build per-cell accumulators for the remainder.
    // A cached entry is always usable: one holding at least `trials`
    // measurements serves the request as a prefix, and a shorter one — e.g.
    // from an adaptive sweep that converged early — keeps its measurements
    // and is topped up with only the missing trial indices.
    let mut cells: Vec<Option<CellMeasure>> = vec![None; keys.len()];
    let mut accs: HashMap<usize, CellAcc> = HashMap::new();
    let mut planned = 0usize;
    for (i, &key) in keys.iter().enumerate() {
        if spec.is_gap(key) {
            cells[i] = Some(gap_measure(key));
            progress.cells_done.fetch_add(1, Ordering::SeqCst);
            progress.emit_cell(key, "gap");
            continue;
        }
        let mut costs = CellCosts::default();
        if let Some(c) = cache {
            if let Some(mut got) = c.fetch(key, spec, backend.tag()) {
                got.normalize(spec.trials);
                costs = got;
            }
        }
        let have = costs.train_s.len();
        if have >= spec.trials {
            cells[i] = Some(measure_of(key, &costs));
            progress.cells_done.fetch_add(1, Ordering::SeqCst);
            progress.emit_cell(key, "cached");
            continue;
        }
        let fresh_n = spec.trials - have;
        planned += fresh_n;
        accs.insert(
            i,
            CellAcc {
                key,
                costs,
                cached: have,
                fresh: vec![None; fresh_n],
                remaining: fresh_n,
                failed: false,
            },
        );
    }
    progress.trials_planned.fetch_add(planned, Ordering::SeqCst);
    log::info!(
        "sweep: {} cells ({} to measure) × {} trials, model={}, backend={}, executor={}",
        keys.len(),
        accs.len(),
        spec.trials,
        spec.model,
        backend.tag(),
        ticket.executor_workers()
    );

    // Submit every missing (cell, trial) task; results stream back tagged
    // with (cell index, trial index). Task closures own `tx` clones, so the
    // channel disconnects exactly when every task has run or been reclaimed
    // by a cancellation — the drain loop needs no separate bookkeeping.
    let (tx, rx) = mpsc::channel::<(usize, usize, anyhow::Result<TrialCost>)>();
    for (i, &key) in keys.iter().enumerate() {
        let Some(acc) = accs.get(&i) else { continue };
        for t in acc.cached..spec.trials {
            submit_trial(ticket, spec, &backend, key, i, t, &tx, progress, &cancel);
        }
    }
    drop(tx);

    let mut first_err: Option<anyhow::Error> = None;
    let mut handle = |accs: &mut HashMap<usize, CellAcc>,
                      cells: &mut Vec<Option<CellMeasure>>,
                      (i, t, r): (usize, usize, anyhow::Result<TrialCost>)| {
        let acc = accs.get_mut(&i).expect("result for unknown cell");
        let slot = t - acc.cached;
        match r {
            Ok(c) => {
                if acc.fresh[slot].is_none() {
                    acc.remaining -= 1;
                }
                acc.fresh[slot] = Some(c);
            }
            Err(e) => {
                // The trial exhausted its retries (see `submit_trial`):
                // quarantine the cell but keep the sweep going — one
                // poisoned cell must not fail the other cells' work. The
                // slot stays empty; each task reports exactly once, so
                // the outstanding count still converges.
                acc.failed = true;
                acc.remaining -= 1;
                if first_err.is_none() {
                    first_err = Some(anyhow::anyhow!("cell {:?}: {e:#}", acc.key));
                }
            }
        }
        if acc.remaining == 0 {
            // Retire this cell now — no waiting on the rest of the grid.
            // Fresh trials append in trial-index order, so the merged
            // vectors stay aligned with the deterministic trial-seed
            // sequence; a quarantined cell keeps only its contiguous
            // finished prefix (the only reusable part).
            let mut acc = accs.remove(&i).expect("accumulator present");
            for c in &acc.fresh {
                match c {
                    Some(c) => {
                        acc.costs.train_s.push(c.train_s);
                        acc.costs.surveil_s.push(c.surveil_s);
                    }
                    None => break, // hole from a failed trial
                }
            }
            if acc.costs.train_s.len() > acc.cached || !acc.failed {
                if let Some(store) = cache {
                    store.store(acc.key, spec, backend.tag(), acc.costs.clone());
                }
            }
            cells[i] = Some(if acc.failed {
                failed_measure(acc.key, &acc.costs)
            } else {
                measure_of(acc.key, &acc.costs)
            });
            progress.cells_done.fetch_add(1, Ordering::SeqCst);
            progress.emit_cell(acc.key, if acc.failed { "failed" } else { "measured" });
        }
    };
    loop {
        match rx.recv_timeout(std::time::Duration::from_millis(50)) {
            Ok(msg) => handle(&mut accs, &mut cells, msg),
            Err(mpsc::RecvTimeoutError::Disconnected) => break, // all tasks ran
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // A cancellation with parked workers leaves reclaimed-task
                // senders alive until a sweep; `pending` performs one, and
                // `(0, 0)` means nothing can send any more.
                if cancel.is_cancelled() && ticket.pending() == (0, 0) {
                    while let Ok(msg) = rx.try_recv() {
                        handle(&mut accs, &mut cells, msg);
                    }
                    break;
                }
            }
        }
    }

    if cancel.is_cancelled() {
        // Flush the contiguous finished prefix of every partial cell so a
        // resubmitted request reuses the work the cancellation stranded.
        let mut flushed = 0usize;
        for (_, mut acc) in accs {
            for c in &acc.fresh {
                match c {
                    Some(c) => {
                        acc.costs.train_s.push(c.train_s);
                        acc.costs.surveil_s.push(c.surveil_s);
                    }
                    None => break, // only a prefix is reusable
                }
            }
            if acc.costs.train_s.len() > acc.cached {
                if let Some(store) = cache {
                    store.store(acc.key, spec, backend.tag(), acc.costs.clone());
                    flushed += 1;
                }
            }
        }
        log::info!("sweep cancelled: {flushed} partial cells flushed to the store");
        return Err(Cancelled.into());
    }
    // Every sender is gone and nothing was cancelled, so every cell must
    // have retired — trial panics are contained and retried inside the
    // task, so a missing cell here is an engine invariant violation, not
    // an expected failure mode.
    let mut out = Vec::with_capacity(cells.len());
    for c in cells {
        match c {
            Some(m) => out.push(m),
            None => anyhow::bail!("sweep lost trial results (task reclaimed without cancel?)"),
        }
    }
    // Quarantine keeps a sweep useful through partial failures, but a run
    // where *nothing* measured is an error the caller must see.
    let measurable = out.iter().filter(|c| !c.violated).count();
    let failed = out.iter().filter(|c| c.failed).count();
    if measurable > 0 && failed == measurable {
        let cause = first_err
            .take()
            .unwrap_or_else(|| anyhow::anyhow!("unknown trial failure"));
        return Err(cause.context(format!(
            "sweep failed: all {measurable} measurable cells quarantined after trial retries"
        )));
    }
    if failed > 0 {
        log::warn!("sweep finished with {failed}/{measurable} cells quarantined");
    }
    Ok(SweepResult {
        spec: spec.clone(),
        cells: out,
    })
}

impl SweepResult {
    /// Measured cells as response-surface samples for a phase
    /// (`"train"` or `"surveil"`), using median cost. Quarantined cells
    /// are excluded — their partial timings must not skew surface fits.
    pub fn samples(&self, phase: &str) -> Vec<Sample> {
        self.cells
            .iter()
            .filter(|c| !c.failed)
            .filter_map(|c| {
                let s = match phase {
                    "train" => c.train.as_ref(),
                    "surveil" => c.surveil.as_ref(),
                    _ => None,
                }?;
                Some(Sample {
                    n_signals: c.key.n,
                    n_memvec: c.key.m,
                    n_obs: c.key.obs,
                    cost: s.median.max(1e-9),
                })
            })
            .collect()
    }

    /// Paper-panel grid: fix `n_signals`, rows = memvecs, cols = obs.
    pub fn panel(&self, phase: &str, n_fixed: usize) -> SurfaceGrid {
        let rows: Vec<usize> = dedup_sorted(self.cells.iter().map(|c| c.key.m));
        let cols: Vec<usize> = dedup_sorted(self.cells.iter().map(|c| c.key.obs));
        let mut grid = SurfaceGrid::new(
            "n_memvec",
            "n_obs",
            rows.iter().map(|&v| v as f64).collect(),
            cols.iter().map(|&v| v as f64).collect(),
        );
        for c in &self.cells {
            if c.key.n != n_fixed || c.violated || c.failed {
                continue;
            }
            let v = match phase {
                "train" => c.train.as_ref(),
                "surveil" => c.surveil.as_ref(),
                _ => None,
            };
            if let Some(s) = v {
                let r = rows.iter().position(|&m| m == c.key.m).unwrap();
                let col = cols.iter().position(|&o| o == c.key.obs).unwrap();
                grid.set(r, col, s.median);
            }
        }
        grid
    }

    /// Cells that were skipped due to the training constraint.
    pub fn gap_cells(&self) -> Vec<CellKey> {
        self.cells
            .iter()
            .filter(|c| c.violated)
            .map(|c| c.key)
            .collect()
    }

    /// Cells measured to full precision (non-gap, not interpolated, not
    /// quarantined).
    pub fn measured_cells(&self) -> usize {
        self.cells
            .iter()
            .filter(|c| !c.violated && !c.interpolated && !c.failed)
            .count()
    }

    /// Cells quarantined after exhausting their trial retries.
    pub fn failed_cells(&self) -> Vec<CellKey> {
        self.cells
            .iter()
            .filter(|c| c.failed)
            .map(|c| c.key)
            .collect()
    }

    /// Cells accepted at pilot precision via the planner's surface model.
    pub fn interpolated_cells(&self) -> usize {
        self.cells.iter().filter(|c| c.interpolated).count()
    }

    /// Total trials aggregated across all measured cells (the sweep's
    /// Monte Carlo budget — the quantity the adaptive planner minimises).
    pub fn total_trials(&self) -> usize {
        self.cells
            .iter()
            .filter_map(|c| c.train.as_ref())
            .map(|s| s.n)
            .sum()
    }
}

fn dedup_sorted(it: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut v: Vec<usize> = it.collect();
    v.sort_unstable();
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::cache::SweepCache;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![4, 8],
            memvecs: vec![8, 16],
            obs: vec![32, 64],
            trials: 2,
            seed: 1,
            model: "mset2".into(),
            workers: 2,
            ..SweepSpec::default()
        }
    }

    #[test]
    fn native_sweep_covers_grid_with_gaps() {
        let res = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        assert_eq!(res.cells.len(), 8);
        // n=8, m=8: 8 < 16 → gap
        let gaps = res.gap_cells();
        assert!(gaps.iter().all(|k| k.m < 2 * k.n));
        assert_eq!(gaps.len(), 2); // (8,8,32), (8,8,64)
        for c in &res.cells {
            if !c.violated {
                let t = c.train.as_ref().unwrap();
                assert_eq!(t.n, 2);
                assert!(t.median > 0.0);
            }
        }
    }

    #[test]
    fn sweep_is_deterministic_in_seed() {
        // Measured times differ run-to-run, but the grid structure, gap
        // cells and trial counts must be identical.
        let a = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        let b = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        assert_eq!(a.gap_cells(), b.gap_cells());
        assert_eq!(a.cells.len(), b.cells.len());
    }

    #[test]
    fn samples_exclude_gaps() {
        let res = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        let s = res.samples("train");
        assert_eq!(s.len(), 6); // 8 cells − 2 gaps
        assert!(s.iter().all(|x| x.cost > 0.0));
    }

    #[test]
    fn panel_extraction() {
        let res = run_sweep(&tiny_spec(), Backend::Native).unwrap();
        let g = res.panel("surveil", 4);
        // rows = memvecs {8,16}, cols = obs {32,64}; n=4 has no gaps
        assert_eq!(g.row_vals, vec![8.0, 16.0]);
        assert_eq!(g.col_vals, vec![32.0, 64.0]);
        assert!((g.coverage() - 1.0).abs() < 1e-12);
        let g8 = res.panel("train", 8);
        assert!(g8.coverage() < 1.0, "n=8 must show constraint gaps");
    }

    #[test]
    fn all_native_pluggable_models_sweep() {
        for model in ["aakr", "ridge", "mlp", "svr"] {
            let spec = SweepSpec {
                model: model.into(),
                signals: vec![4],
                memvecs: vec![16],
                obs: vec![32],
                trials: 1,
                ..tiny_spec()
            };
            let res = run_sweep(&spec, Backend::Native).unwrap();
            assert_eq!(res.cells.len(), 1);
            assert!(!res.cells[0].violated);
        }
    }

    #[test]
    fn duplicate_axis_values_measure_once() {
        let spec = SweepSpec {
            signals: vec![4, 4],
            memvecs: vec![16],
            obs: vec![32],
            trials: 2,
            ..tiny_spec()
        };
        let res = run_sweep(&spec, Backend::Native).unwrap();
        assert_eq!(res.cells.len(), 1, "duplicate cells must be deduplicated");
        assert_eq!(res.cells[0].train.as_ref().unwrap().n, 2);
    }

    #[test]
    fn empty_axes_error_cleanly() {
        let bad = SweepSpec {
            signals: vec![],
            ..tiny_spec()
        };
        let err = run_sweep(&bad, Backend::Native).unwrap_err().to_string();
        assert!(err.contains("non-empty"), "{err}");
    }

    #[test]
    fn cached_sweep_reuses_cells_across_grids() {
        let cache = SweepCache::in_memory();
        let a = run_sweep_cached(&tiny_spec(), Backend::Native, Some(&cache)).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 6)); // 8 cells − 2 gaps
        assert_eq!(cache.len(), 6);

        // Identical request: every measurable cell served from the cache,
        // with bit-identical summaries (same stored trial costs).
        let b = run_sweep_cached(&tiny_spec(), Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 6);
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.key, cb.key);
            assert_eq!(ca.violated, cb.violated);
            if !ca.violated {
                assert_eq!(
                    ca.train.as_ref().unwrap().median,
                    cb.train.as_ref().unwrap().median
                );
                assert_eq!(
                    ca.surveil.as_ref().unwrap().median,
                    cb.surveil.as_ref().unwrap().median
                );
            }
        }

        // A differently-shaped grid still reuses its shared cells — seeds
        // are content-derived, so cell identity survives re-gridding.
        let sub = SweepSpec {
            signals: vec![4],
            memvecs: vec![8, 16],
            obs: vec![32],
            ..tiny_spec()
        };
        run_sweep_cached(&sub, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 8, "both sub-grid cells must be reused");
    }

    #[test]
    fn cached_entry_serves_smaller_trial_request_as_prefix() {
        let cache = SweepCache::in_memory();
        let spec3 = SweepSpec {
            trials: 3,
            ..tiny_spec()
        };
        run_sweep_cached(&spec3, Backend::Native, Some(&cache)).unwrap();
        let len_after_first = cache.len();

        // Fewer trials, same seed: every cell is served from the stored
        // entries' prefixes — no new measurements, no new entries.
        let spec2 = SweepSpec {
            trials: 2,
            ..tiny_spec()
        };
        let res = run_sweep_cached(&spec2, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.len(), len_after_first);
        assert_eq!(cache.hits(), 6); // 8 cells − 2 gaps
        for c in &res.cells {
            if !c.violated {
                assert_eq!(c.train.as_ref().unwrap().n, 2);
                assert_eq!(c.surveil.as_ref().unwrap().n, 2);
            }
        }
    }

    #[test]
    fn cache_misses_on_different_seed_or_trials() {
        let cache = SweepCache::in_memory();
        run_sweep_cached(&tiny_spec(), Backend::Native, Some(&cache)).unwrap();
        let reseeded = SweepSpec {
            seed: 99,
            ..tiny_spec()
        };
        run_sweep_cached(&reseeded, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 0, "different seed must not share cells");
        assert_eq!(cache.len(), 12);
    }

    #[test]
    fn poisoned_cells_quarantine_while_healthy_cells_survive() {
        use crate::util::failpoint;
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        // Warm two cells, then poison every fresh trial: the warmed cells
        // retire from the cache, the fresh ones quarantine, and the job
        // still completes.
        let cache = SweepCache::in_memory();
        let sub = SweepSpec {
            signals: vec![4],
            memvecs: vec![8, 16],
            obs: vec![32],
            ..tiny_spec()
        };
        run_sweep_cached(&sub, Backend::Native, Some(&cache)).unwrap();
        let r0 = Registry::global().counter("executor.trial.retries");
        let f0 = Registry::global().counter("executor.trial.failed");
        failpoint::arm_from_str("executor.trial.run:1:error:3").unwrap();
        let full = SweepSpec {
            signals: vec![4],
            memvecs: vec![8, 16],
            obs: vec![32, 64],
            ..tiny_spec()
        };
        let res = run_sweep_cached(&full, Backend::Native, Some(&cache)).unwrap();
        failpoint::disarm_all();
        assert_eq!(res.cells.len(), 4);
        let failed = res.failed_cells();
        assert_eq!(failed.len(), 2, "both fresh cells must quarantine");
        assert!(failed.iter().all(|k| k.obs == 64));
        // Quarantined cells are excluded from fits, panels, and counts.
        assert_eq!(res.samples("train").len(), 2);
        assert_eq!(res.measured_cells(), 2);
        // 2 cells × 2 trials, each retried TRIAL_MAX_RETRIES times.
        assert_eq!(Registry::global().counter("executor.trial.failed") - f0, 4);
        assert_eq!(
            Registry::global().counter("executor.trial.retries") - r0,
            4 * TRIAL_MAX_RETRIES
        );
    }

    #[test]
    fn all_cells_failing_is_a_classified_job_error() {
        use crate::util::failpoint;
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        // Injected *panics* exercise the containment path end to end.
        failpoint::arm_from_str("executor.trial.run:1:panic:3").unwrap();
        let spec = SweepSpec {
            signals: vec![4],
            memvecs: vec![16],
            obs: vec![32],
            trials: 1,
            ..tiny_spec()
        };
        let err = run_sweep(&spec, Backend::Native).unwrap_err();
        failpoint::disarm_all();
        assert!(
            failpoint::is_injected(&err),
            "error must classify as injected: {err:#}"
        );
        assert!(format!("{err:#}").contains("quarantined"), "{err:#}");
    }

    #[test]
    fn surveil_cost_scales_with_obs_native() {
        let spec = SweepSpec {
            signals: vec![8],
            memvecs: vec![64],
            obs: vec![64, 2048],
            trials: 3,
            ..tiny_spec()
        };
        let res = run_sweep(&spec, Backend::Native).unwrap();
        let small = res.cells[0].surveil.as_ref().unwrap().median;
        let large = res.cells[1].surveil.as_ref().unwrap().median;
        assert!(
            large > 4.0 * small,
            "32× more observations must cost ≫ more: {small} vs {large}"
        );
    }
}
