//! Runtime metrics: counters, gauges, and **bounded** latency histograms
//! with text/JSON/Prometheus export.
//!
//! The coordinator, executor, and service record device calls, cache hits,
//! trial counts, per-phase timings and HTTP latencies here;
//! `containerstress … --metrics` dumps the registry at exit and
//! `GET /metrics` serves it live (`?format=json|text|prometheus`).
//!
//! Histograms are log-bucketed with fixed memory ([`Histogram`]): a
//! long-lived `serve` process can record samples forever without growing —
//! the unbounded `Vec<f64>` store this replaced is gone. Quantiles carry
//! ≤ 5% relative error (documented on [`Histogram`]); counts, sums, means,
//! min/max are exact. See `docs/API.md` for the metric catalog.

mod histogram;

pub use histogram::Histogram;

use crate::util::json::Json;
use crate::util::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Global-or-local metrics registry (thread-safe).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
    gauges: Mutex<BTreeMap<String, f64>>,
}

impl Registry {
    /// Fresh, empty registry (tests; production uses [`Registry::global`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Process-wide registry.
    pub fn global() -> &'static Registry {
        static GLOBAL: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Increment a counter by one.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `v` to a counter.
    pub fn add(&self, name: &str, v: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += v;
    }

    /// Record a duration sample under `name`.
    pub fn time(&self, name: &str, d: Duration) {
        self.sample(name, d.as_secs_f64());
    }

    /// Record one observation into the bounded histogram under `name`.
    pub fn sample(&self, name: &str, v: f64) {
        let mut hs = self.histograms.lock().unwrap();
        match hs.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = Histogram::new();
                h.record(v);
                hs.insert(name.to_string(), h);
            }
        }
    }

    /// Set a gauge to an instantaneous value (last write wins).
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    /// Current value of a gauge, if ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Drop every gauge whose name starts with `prefix`, returning how
    /// many were removed. Used by the job registry to reap per-job gauges
    /// when the owning job is evicted from retention — without this a
    /// long-running service leaks one gauge family per completed job into
    /// `/metrics` forever.
    pub fn remove_gauges_prefixed(&self, prefix: &str) -> usize {
        let mut gauges = self.gauges.lock().unwrap();
        let before = gauges.len();
        gauges.retain(|k, _| !k.starts_with(prefix));
        before - gauges.len()
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Snapshot of the histogram under `name`, if any samples were
    /// recorded (a clone — cheap and fixed-size, usable for merging).
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.histograms.lock().unwrap().get(name).cloned()
    }

    /// Summary statistics of a sampled series, if any were recorded.
    /// `n`/`mean`/`std`/`min`/`max` are exact; quantiles carry the
    /// [`Histogram`] error bound (≤ 5% relative).
    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.histograms
            .lock()
            .unwrap()
            .get(name)
            .and_then(Histogram::summary)
    }

    /// Human-readable dump.
    pub fn render(&self) -> String {
        let mut out = String::from("=== metrics ===\n");
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v:.3}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let Some(s) = h.summary() else { continue };
            out.push_str(&format!(
                "{k}: n={} median={:.3e}s mean={:.3e}s p75={:.3e}s\n",
                s.n, s.median, s.mean, s.p75
            ));
        }
        out
    }

    /// JSON export (counters + gauges + histogram summaries).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in self.gauges.lock().unwrap().iter() {
            gauges.insert(k.clone(), Json::Num(*v));
        }
        let mut samples = BTreeMap::new();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let Some(s) = h.summary() else { continue };
            samples.insert(
                k.clone(),
                Json::obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("median", Json::Num(s.median)),
                    ("mean", Json::Num(s.mean)),
                    ("min", Json::Num(s.min)),
                    ("max", Json::Num(s.max)),
                    ("p95", Json::Num(h.quantile(0.95).unwrap_or(s.max))),
                ]),
            );
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("timers", Json::Obj(samples)),
        ])
    }

    /// Prometheus text-exposition rendering (format version 0.0.4):
    /// counters as `<name>_total`, gauges as-is, histograms with
    /// cumulative `_bucket{le=…}` series plus `_sum`/`_count`. Metric
    /// names are sanitized to `[a-zA-Z0-9_:]` (dots become underscores).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            let name = promify(k);
            out.push_str(&format!("# TYPE {name}_total counter\n"));
            out.push_str(&format!("{name}_total {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            let name = promify(k);
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {v}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            if h.count() == 0 {
                continue;
            }
            let name = promify(k);
            out.push_str(&format!("# TYPE {name} histogram\n"));
            for (le, cum) in h.cumulative_buckets() {
                out.push_str(&format!("{name}_bucket{{le=\"{le:e}\"}} {cum}\n"));
            }
            out.push_str(&format!(
                "{name}_bucket{{le=\"+Inf\"}} {}\n",
                h.count()
            ));
            out.push_str(&format!("{name}_sum {}\n", h.sum()));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
        out
    }

    /// Reset everything (tests).
    pub fn clear(&self) {
        self.counters.lock().unwrap().clear();
        self.histograms.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
    }
}

/// Escape a Prometheus label **value** per the text exposition format:
/// backslash, double quote, and newline must be written as `\\`, `\"`,
/// and `\n` respectively or the line is unparseable by scrapers. Every
/// label value interpolated into an exposition line (including hand-built
/// info metrics like `kernel_backend_info`) must pass through here.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Sanitize a metric name for Prometheus: every character outside
/// `[a-zA-Z0-9_:]` becomes `_`, and a leading digit gets a `_` prefix.
fn promify(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        s.insert(0, '_');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.inc("a");
        r.inc("a");
        r.add("a", 3);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn samples_summarise() {
        let r = Registry::new();
        for i in 1..=5 {
            r.sample("lat", i as f64);
        }
        let s = r.summary("lat").unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0); // exact
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // quantiles are approximate: within the documented 5% bound
        assert!((s.median - 3.0).abs() <= 0.05 * 3.0, "median {}", s.median);
        assert!(r.summary("none").is_none());
    }

    #[test]
    fn gauges_last_write_wins() {
        let r = Registry::new();
        assert!(r.gauge("depth").is_none());
        r.set_gauge("depth", 4.0);
        r.set_gauge("depth", 7.0);
        assert_eq!(r.gauge("depth"), Some(7.0));
    }

    #[test]
    fn render_and_json() {
        let r = Registry::new();
        r.inc("calls");
        r.time("t", Duration::from_millis(5));
        r.set_gauge("g", 2.5);
        let text = r.render();
        assert!(text.contains("calls: 1"));
        assert!(text.contains("g: 2.500"));
        let j = r.to_json();
        assert!(j.get("counters").unwrap().get("calls").is_some());
        assert!(j.get("timers").unwrap().get("t").is_some());
        assert!(j.get("gauges").unwrap().get("g").is_some());
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let r = Registry::new();
        r.add("sweep.trials", 9);
        r.set_gauge("executor.queue_depth", 3.0);
        for i in 1..=100 {
            r.sample("service.http.request_seconds", i as f64 * 1e-3);
        }
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE sweep_trials_total counter"));
        assert!(text.contains("sweep_trials_total 9"));
        assert!(text.contains("# TYPE executor_queue_depth gauge"));
        assert!(text.contains("executor_queue_depth 3"));
        assert!(text.contains("# TYPE service_http_request_seconds histogram"));
        assert!(text.contains("service_http_request_seconds_count 100"));
        assert!(text.contains("le=\"+Inf\"} 100"));
        // every bucket line has a le label and the series is cumulative
        let cums: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("service_http_request_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse::<u64>().unwrap())
            .collect();
        assert!(cums.len() >= 2);
        assert!(cums.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*cums.last().unwrap(), 100);
    }

    /// Strict per-line validator for the Prometheus text exposition
    /// format (the subset this crate emits): `# TYPE <name> <kind>`
    /// comments, then `<name>[{label="value",…}] <number>` samples with
    /// metric names in `[a-zA-Z_:][a-zA-Z0-9_:]*` and label values fully
    /// escaped (no raw `"` or `\` or newline inside the quotes).
    fn check_prometheus_line(line: &str) {
        fn valid_name(s: &str) -> bool {
            !s.is_empty()
                && !s.starts_with(|c: char| c.is_ascii_digit())
                && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split(' ');
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("");
            assert!(valid_name(name), "bad metric name in TYPE line: {line:?}");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "bad TYPE kind: {line:?}"
            );
            assert!(parts.next().is_none(), "trailing junk in TYPE line: {line:?}");
            return;
        }
        let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(
            value.parse::<f64>().is_ok() || value == "+Inf" || value == "-Inf" || value == "NaN",
            "unparseable sample value in {line:?}"
        );
        let name = match series.split_once('{') {
            None => series,
            Some((name, labels)) => {
                let labels = labels.strip_suffix('}').expect("labels close with }");
                for pair in split_label_pairs(labels) {
                    let (k, v) = pair.split_once('=').expect("label is key=value");
                    assert!(valid_name(k), "bad label name in {line:?}");
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .expect("label value is quoted");
                    // inside the quotes: every `"` and `\` must be escaped
                    let mut chars = v.chars();
                    while let Some(c) = chars.next() {
                        match c {
                            '"' => panic!("unescaped quote in label value: {line:?}"),
                            '\n' => panic!("raw newline in label value: {line:?}"),
                            '\\' => {
                                let e = chars.next().expect("dangling backslash");
                                assert!(
                                    matches!(e, '\\' | '"' | 'n'),
                                    "bad escape \\{e} in {line:?}"
                                );
                            }
                            _ => {}
                        }
                    }
                }
                name
            }
        };
        assert!(valid_name(name), "bad metric name in sample line: {line:?}");
    }

    /// Split `k1="v1",k2="v2"` on commas that sit outside quoted values.
    fn split_label_pairs(labels: &str) -> Vec<&str> {
        let mut pairs = Vec::new();
        let (mut start, mut in_quotes, mut escaped) = (0usize, false, false);
        for (i, c) in labels.char_indices() {
            match c {
                _ if escaped => escaped = false,
                '\\' if in_quotes => escaped = true,
                '"' => in_quotes = !in_quotes,
                ',' if !in_quotes => {
                    pairs.push(&labels[start..i]);
                    start = i + 1;
                }
                _ => {}
            }
        }
        pairs.push(&labels[start..]);
        pairs
    }

    #[test]
    fn prometheus_exposition_passes_strict_line_checker() {
        let r = Registry::new();
        r.add("sweep.trials", 3);
        r.set_gauge("executor.queue_depth", 2.0);
        r.set_gauge("9starts.with-digit", 1.0);
        for i in 1..=20 {
            r.sample("service.http.request_seconds", i as f64 * 1e-3);
        }
        for line in r.render_prometheus().lines() {
            check_prometheus_line(line);
        }
        // the checker also accepts labelled info-style lines…
        check_prometheus_line("kernel_backend_info{kernel_backend=\"simd\",mode=\"forced\"} 1");
        // …and rejects unescaped values (escape_label_value makes them safe)
        let hostile = "a\\b\"c\nd";
        let escaped = escape_label_value(hostile);
        assert_eq!(escaped, "a\\\\b\\\"c\\nd");
        check_prometheus_line(&format!("info{{v=\"{escaped}\"}} 1"));
        let raw = std::panic::catch_unwind(|| {
            check_prometheus_line("info{v=\"raw\"quote\"} 1");
        });
        assert!(raw.is_err(), "checker must reject unescaped quotes");
    }

    #[test]
    fn gauges_are_removable_by_prefix() {
        let r = Registry::new();
        r.set_gauge("service.job.7.trials_done", 4.0);
        r.set_gauge("service.job.7.cells_done", 2.0);
        r.set_gauge("service.job.71.trials_done", 9.0);
        r.set_gauge("executor.queue_depth", 1.0);
        assert_eq!(r.remove_gauges_prefixed("service.job.7."), 2);
        assert!(r.gauge("service.job.7.trials_done").is_none());
        assert_eq!(r.gauge("service.job.71.trials_done"), Some(9.0));
        assert_eq!(r.gauge("executor.queue_depth"), Some(1.0));
        assert_eq!(r.remove_gauges_prefixed("service.job.7."), 0);
    }

    #[test]
    fn concurrent_increments() {
        let r = Registry::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        r.inc("n");
                    }
                });
            }
        });
        assert_eq!(r.counter("n"), 8000);
    }
}
