//! # ContainerStress
//!
//! Reproduction of *"ContainerStress: Autonomous Cloud-Node Scoping Framework
//! for Big-Data ML Use Cases"* (Wang, Gross, Subramaniam; 2020) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the ContainerStress coordinator: nested-loop
//!   Monte Carlo sweep engine, cloud shape catalog, GPU-speedup model,
//!   response-surface methodology, and scoping recommender — plus the
//!   [`service`] layer (`containerstress serve`): a multi-tenant HTTP JSON
//!   API over the scoping-job queue with a content-addressed cell-level
//!   sweep cache, so identical grid cells are never measured twice across
//!   customer requests.
//! - **L2** — MSET2 train/surveil compute graphs written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! - **L1** — the similarity-matrix hot-spot as a Pallas kernel
//!   (`python/compile/kernels/similarity.py`), fused into the L2 graphs.
//!
//! The Rust binary loads the artifacts through the PJRT CPU client
//! ([`runtime`]) and never invokes Python at run time.
//!
//! ## Pipeline
//!
//! One scope request flows `tpss` (synthetic telemetry) → `mset`/`models`
//! (estimators) → `runtime` (device execution) → `coordinator` (Monte
//! Carlo sweep — exhaustive or adaptive via [`coordinator::planner`]) →
//! `surface` (response-surface fit) → `recommend` (cloud-shape choice),
//! with [`service`] wrapping the whole pipeline in a multi-tenant HTTP
//! JSON API backed by a content-addressed cell-level sweep cache. On top
//! sits the [`scenario`] subsystem (`containerstress simulate`,
//! `POST /v1/scenarios`): trace-driven fleet what-if simulation that
//! queries the fitted surfaces as an online cost oracle instead of
//! re-running Monte Carlo trials. See `docs/ARCHITECTURE.md` for the
//! full map and `docs/API.md` for the service endpoints.
//!
//! ## Example: sweep a tiny grid and recommend a shape
//!
//! ```
//! use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
//! use containerstress::recommend::{recommend_from_sweep, Sla};
//! use containerstress::shapes::Workload;
//!
//! # fn main() -> anyhow::Result<()> {
//! let spec = SweepSpec {
//!     signals: vec![2, 3],
//!     memvecs: vec![8, 12, 16],
//!     obs: vec![16, 32],
//!     trials: 1,
//!     ..SweepSpec::default()
//! };
//! let result = run_sweep(&spec, Backend::Native)?;
//! let rec = recommend_from_sweep(&result, &Workload::customer_a(), &Sla::default())?;
//! assert!(!rec.assessments.is_empty());
//! println!("{}", rec.render());
//! # Ok(())
//! # }
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.

#![warn(missing_docs)]

pub mod accel;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod detect;
pub mod linalg;
pub mod metrics;
pub mod models;
pub mod mset;
pub mod obs;
pub mod recommend;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod service;
pub mod shapes;
pub mod surface;
pub mod tpss;
pub mod util;
