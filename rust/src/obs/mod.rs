//! Structured-span observability: per-job flight recorders, trace IDs,
//! and process-level telemetry switches.
//!
//! Each scoping/scenario job owns a [`FlightRecorder`] — a fixed-capacity
//! ring buffer of [`SpanRecord`]s. Instrumentation points across the
//! pipeline (job driver → planner rounds → executor trial tasks →
//! per-trial train/surveil phases → scenario units) push spans into the
//! recorder of the job they belong to; `GET /v1/jobs/{id}/trace` serves
//! the ordered timeline with queue-wait vs. run-time per span.
//!
//! Propagation uses two complementary mechanisms:
//! - a **thread-local current recorder** ([`install`] / [`current`]),
//!   set by the job driver thread for code that runs on that thread
//!   (planner rounds, demand resolution, the job span itself), and
//! - **explicit capture**: dispatch points grab `current()` once and move
//!   the `Arc` into task closures, so spans recorded on executor worker
//!   threads still land in the right job's recorder.
//!
//! When no recorder is installed (plain CLI sweeps, the telemetry-disabled
//! bench twin) every instrumentation point is a thread-local read plus a
//! branch — the overhead budget is enforced by `benches/obs_overhead.rs`
//! (≤ 5% on the native trial hot path).

use crate::util::fnv1a;
use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Default ring capacity per job: enough for every phase of a typical
/// adaptive sweep (hundreds of trials) while bounding memory at
/// `capacity × sizeof(SpanRecord)` regardless of job size.
pub const DEFAULT_SPAN_CAPACITY: usize = 512;

/// One completed span: a named phase of work inside a job, with offsets
/// in microseconds from the owning recorder's epoch (job submission).
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Component that produced the span (`"job"`, `"planner"`, `"trial"`,
    /// `"scenario"`, …).
    pub name: &'static str,
    /// Phase within the component (`"run"`, `"train"`, `"surveil"`,
    /// `"round"`, …).
    pub phase: &'static str,
    /// Work start, µs since the recorder epoch (after any queue wait).
    pub start_us: u64,
    /// Work end, µs since the recorder epoch.
    pub end_us: u64,
    /// Time spent queued before work started, µs (0 when the span never
    /// waited in an executor queue).
    pub queue_us: u64,
    /// Free-form context, e.g. `"cell=4/8/32 trial=1"`.
    pub meta: String,
}

impl SpanRecord {
    /// Run time (end − start) in µs.
    pub fn run_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    /// JSON object for the `/trace` endpoints.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("phase", Json::Str(self.phase.to_string())),
            ("start_us", Json::Num(self.start_us as f64)),
            ("end_us", Json::Num(self.end_us as f64)),
            ("queue_us", Json::Num(self.queue_us as f64)),
            ("run_us", Json::Num(self.run_us() as f64)),
            ("meta", Json::Str(self.meta.clone())),
        ])
    }
}

struct Ring {
    spans: VecDeque<SpanRecord>,
    dropped: u64,
}

/// Fixed-capacity per-job span ring buffer ("flight recorder").
///
/// Memory is bounded by construction: once `capacity` spans are held, the
/// oldest span is evicted per push and counted in `dropped`, so the
/// recorder keeps the most recent window of a very long job.
pub struct FlightRecorder {
    epoch: Instant,
    trace_id: String,
    capacity: usize,
    inner: Mutex<Ring>,
}

impl FlightRecorder {
    /// Recorder with the default capacity; `trace_id` is the request's
    /// correlation ID (inbound `x-request-id` or a minted one).
    pub fn new(trace_id: impl Into<String>) -> FlightRecorder {
        FlightRecorder::with_capacity(trace_id, DEFAULT_SPAN_CAPACITY)
    }

    /// Recorder with an explicit ring capacity (min 1).
    pub fn with_capacity(trace_id: impl Into<String>, capacity: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            trace_id: trace_id.into(),
            capacity: capacity.max(1),
            inner: Mutex::new(Ring {
                spans: VecDeque::new(),
                dropped: 0,
            }),
        }
    }

    /// Correlation ID this recorder was created with.
    pub fn trace_id(&self) -> &str {
        &self.trace_id
    }

    /// Ring capacity (the memory bound, in spans).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Spans evicted so far because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Microseconds between the recorder epoch and `at` (0 if earlier).
    pub fn offset_us(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record a completed span from raw instants. `queue` is the time the
    /// work sat in an executor queue before `start`.
    pub fn push(
        &self,
        name: &'static str,
        phase: &'static str,
        start: Instant,
        end: Instant,
        queue: Duration,
        meta: String,
    ) {
        self.record(SpanRecord {
            name,
            phase,
            start_us: self.offset_us(start),
            end_us: self.offset_us(end),
            queue_us: queue.as_micros() as u64,
            meta,
        });
    }

    /// Record a pre-built span, evicting the oldest entry when full.
    pub fn record(&self, span: SpanRecord) {
        let mut ring = self.inner.lock().unwrap();
        if ring.spans.len() >= self.capacity {
            ring.spans.pop_front();
            ring.dropped += 1;
        }
        ring.spans.push_back(span);
    }

    /// Spans ordered by start offset (stable for equal starts).
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut v: Vec<SpanRecord> = self.inner.lock().unwrap().spans.iter().cloned().collect();
        v.sort_by_key(|s| s.start_us);
        v
    }

    /// Full timeline as JSON for the `/trace` endpoints.
    pub fn to_json(&self) -> Json {
        let spans = self.snapshot();
        Json::obj(vec![
            ("trace_id", Json::Str(self.trace_id.clone())),
            ("capacity", Json::Num(self.capacity as f64)),
            (
                "dropped",
                Json::Num(self.inner.lock().unwrap().dropped as f64),
            ),
            (
                "spans",
                Json::Arr(spans.iter().map(SpanRecord::to_json).collect()),
            ),
        ])
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<FlightRecorder>>> = const { RefCell::new(None) };
}

/// Recorder installed on this thread, if any (cheap: a thread-local read).
pub fn current() -> Option<Arc<FlightRecorder>> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Install `rec` as this thread's current recorder for the guard's
/// lifetime; the previous recorder (usually `None`) is restored on drop,
/// including on unwind.
pub fn install(rec: Option<Arc<FlightRecorder>>) -> CurrentGuard {
    let prev = CURRENT.with(|c| c.replace(rec));
    CurrentGuard { prev }
}

/// RAII guard returned by [`install`]; restores the previous recorder.
pub struct CurrentGuard {
    prev: Option<Arc<FlightRecorder>>,
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

/// Mint a 16-hex-digit trace ID: FNV-1a over wall-clock nanos and a
/// process-wide sequence number (unique within a process, collision-safe
/// enough across restarts for log correlation).
pub fn mint_trace_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_nanos() as u64;
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&nanos.to_le_bytes());
    bytes[8..].copy_from_slice(&seq.to_le_bytes());
    format!("{:016x}", fnv1a(&bytes))
}

/// Default bounded event history retained per [`EventBus`] for replay to
/// late subscribers.
pub const DEFAULT_EVENT_HISTORY: usize = 256;

/// One published progress event: a pre-serialised compact JSON object (one
/// NDJSON line, newline excluded) plus its per-bus sequence number.
#[derive(Clone, Debug)]
pub struct BusEvent {
    /// Monotone per-bus sequence number, starting at 0.
    pub seq: u64,
    /// Compact JSON object text.
    pub line: Arc<str>,
}

#[derive(Debug)]
struct BusInner {
    history: VecDeque<BusEvent>,
    subscribers: Vec<mpsc::Sender<BusEvent>>,
    next_seq: u64,
    dropped: u64,
    closed: bool,
}

/// Per-job progress event bus feeding the `/events` streaming endpoints.
///
/// Publishers (planner cell retirements, exhaustive-sweep retirements,
/// scenario units, the job driver's terminal summary) push serialised JSON
/// lines; each subscriber gets a bounded history replay plus a live
/// channel. Memory is bounded: the history ring keeps the most recent
/// [`DEFAULT_EVENT_HISTORY`] events (older ones are counted in
/// `dropped`), and a subscriber that goes away is pruned on the next
/// publish. After [`EventBus::close`] the live channels disconnect and
/// late subscribers see history only — which always includes the terminal
/// event, since it is published last.
#[derive(Debug)]
pub struct EventBus {
    capacity: usize,
    inner: Mutex<BusInner>,
}

impl Default for EventBus {
    fn default() -> Self {
        EventBus::new()
    }
}

impl EventBus {
    /// Bus with the default history capacity.
    pub fn new() -> EventBus {
        EventBus::with_capacity(DEFAULT_EVENT_HISTORY)
    }

    /// Bus with an explicit history capacity (min 1).
    pub fn with_capacity(capacity: usize) -> EventBus {
        EventBus {
            capacity: capacity.max(1),
            inner: Mutex::new(BusInner {
                history: VecDeque::new(),
                subscribers: Vec::new(),
                next_seq: 0,
                dropped: 0,
                closed: false,
            }),
        }
    }

    /// Publish one pre-serialised event line (ignored after close).
    pub fn publish(&self, line: String) {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return;
        }
        let ev = BusEvent {
            seq: inner.next_seq,
            line: Arc::from(line.as_str()),
        };
        inner.next_seq += 1;
        if inner.history.len() >= self.capacity {
            inner.history.pop_front();
            inner.dropped += 1;
        }
        inner.history.push_back(ev.clone());
        inner.subscribers.retain(|tx| tx.send(ev.clone()).is_ok());
    }

    /// Publish a JSON object as a compact event line.
    pub fn publish_json(&self, v: &Json) {
        self.publish(v.to_string());
    }

    /// Close the bus: live subscriber channels disconnect (after draining
    /// already-sent events) and further publishes are ignored.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        inner.subscribers.clear();
    }

    /// Whether [`EventBus::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Events evicted from the history ring so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Subscribe: returns the retained history for replay and, while the
    /// bus is open, a live receiver for subsequent events. `None` means
    /// the bus already closed and the history is complete.
    pub fn subscribe(&self) -> (Vec<BusEvent>, Option<mpsc::Receiver<BusEvent>>) {
        let mut inner = self.inner.lock().unwrap();
        let replay: Vec<BusEvent> = inner.history.iter().cloned().collect();
        if inner.closed {
            return (replay, None);
        }
        let (tx, rx) = mpsc::channel();
        inner.subscribers.push(tx);
        (replay, Some(rx))
    }
}

static ACCESS_LOG: AtomicBool = AtomicBool::new(false);

/// Turn HTTP access logging on/off (`containerstress serve --access-log`).
pub fn set_access_log(on: bool) {
    ACCESS_LOG.store(on, Ordering::Relaxed);
}

/// Whether per-request HTTP access-log lines are emitted.
pub fn access_log_enabled() -> bool {
    ACCESS_LOG.load(Ordering::Relaxed)
}

static START: OnceLock<Instant> = OnceLock::new();

/// Anchor the process-start instant (first caller wins; `logger::init`
/// calls this at boot so `/healthz` uptime covers the whole process).
pub fn touch_process_start() {
    START.get_or_init(Instant::now);
}

/// Seconds since the process-start anchor.
pub fn uptime_s() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_and_orders_spans() {
        let rec = FlightRecorder::with_capacity("t-1", 4);
        let t0 = Instant::now();
        for i in 0..6u64 {
            rec.record(SpanRecord {
                name: "trial",
                phase: "train",
                start_us: 100 - i * 10, // reversed starts: snapshot must sort
                end_us: 200,
                queue_us: i,
                meta: format!("i={i}"),
            });
        }
        assert_eq!(rec.capacity(), 4);
        assert_eq!(rec.dropped(), 2);
        let spans = rec.snapshot();
        assert_eq!(spans.len(), 4);
        assert!(spans.windows(2).all(|w| w[0].start_us <= w[1].start_us));
        assert!(rec.offset_us(t0) < 1_000_000);
        let j = rec.to_json();
        assert_eq!(j.get("trace_id").and_then(Json::as_str), Some("t-1"));
        assert_eq!(j.get("spans").and_then(Json::as_arr).unwrap().len(), 4);
    }

    #[test]
    fn install_guard_restores_previous() {
        assert!(current().is_none());
        let rec = Arc::new(FlightRecorder::new("outer"));
        {
            let _g = install(Some(rec.clone()));
            assert_eq!(current().unwrap().trace_id(), "outer");
            {
                let inner = Arc::new(FlightRecorder::new("inner"));
                let _g2 = install(Some(inner));
                assert_eq!(current().unwrap().trace_id(), "inner");
            }
            assert_eq!(current().unwrap().trace_id(), "outer");
        }
        assert!(current().is_none());
    }

    #[test]
    fn trace_ids_are_distinct_hex() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn event_bus_replays_then_streams_live() {
        let bus = EventBus::new();
        bus.publish("{\"seq\":\"a\"}".to_string());
        let (replay, rx) = bus.subscribe();
        let rx = rx.expect("bus open");
        assert_eq!(replay.len(), 1);
        assert_eq!(&*replay[0].line, "{\"seq\":\"a\"}");
        bus.publish("{\"seq\":\"b\"}".to_string());
        let live = rx.recv().unwrap();
        assert_eq!(live.seq, 1);
        assert_eq!(&*live.line, "{\"seq\":\"b\"}");
        bus.publish("terminal".to_string());
        bus.close();
        // Already-sent events drain; then the channel disconnects.
        assert_eq!(&*rx.recv().unwrap().line, "terminal");
        assert!(rx.recv().is_err());
        // Late subscriber: history only, terminal event included.
        let (replay, rx) = bus.subscribe();
        assert!(rx.is_none());
        assert_eq!(&*replay.last().unwrap().line, "terminal");
    }

    #[test]
    fn event_bus_history_is_bounded() {
        let bus = EventBus::with_capacity(2);
        for i in 0..5 {
            bus.publish(format!("e{i}"));
        }
        assert_eq!(bus.dropped(), 3);
        let (replay, _rx) = bus.subscribe();
        assert_eq!(
            replay.iter().map(|e| e.line.to_string()).collect::<Vec<_>>(),
            vec!["e3", "e4"]
        );
        assert_eq!(replay[0].seq, 3);
    }

    #[test]
    fn span_run_time_and_queue_wait() {
        let rec = FlightRecorder::new("t");
        let start = Instant::now();
        let end = start + Duration::from_millis(3);
        rec.push(
            "trial",
            "surveil",
            start,
            end,
            Duration::from_millis(7),
            String::new(),
        );
        let s = &rec.snapshot()[0];
        assert_eq!(s.queue_us, 7_000);
        assert!((2_000..=4_000).contains(&s.run_us()), "run {}", s.run_us());
    }
}
