//! High-level device sessions: pad → execute → unpad whole MSET2/AAKR
//! workloads against the bucketed artifacts.
//!
//! A session binds one workload shape `(n_real, m_real)` to a bucket. Data
//! preparation (scaling, memory-vector selection) happens in L3 via
//! [`crate::mset`]; the session runs the two device phases the paper
//! measures — **training** and **streaming surveillance** — and reports
//! their pure execution times.

use super::engine::Tensor;
use super::router::{self, Bucket};
use super::DeviceHandle;
use crate::linalg::Mat;
use std::time::Duration;

/// Device-resident MSET2 session.
pub struct DeviceMset {
    handle: DeviceHandle,
    /// Artifact bucket the workload was routed to.
    pub bucket: Bucket,
    /// Real (unpadded) signal count.
    pub n_real: usize,
    /// Real (unpadded) memory-vector count.
    pub m_real: usize,
    /// Observation-chunk rows per surveillance call.
    pub chunk: usize,
    /// Similarity-kernel γ from the manifest (exposed for diagnostics).
    pub gamma: f64,
    /// Padded memory matrix, kept for surveillance calls.
    d_pad: Tensor,
    mask: Tensor,
    bw: Tensor,
    /// Trained inverse (padded), present after `train`.
    g_pad: Option<Tensor>,
    /// Bound device session for surveillance: [d, g, mask, bw] marshaled
    /// once on the device thread (§Perf — saves ~1.3 MB of marshaling per
    /// chunk at the largest bucket).
    surveil_session: Option<u64>,
}

/// Timing of one device phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseCost {
    /// Pure execution time.
    pub exec: Duration,
    /// First-use compilation time, if any (excluded from cost metrics).
    pub compile: Duration,
    /// Device calls made.
    pub calls: usize,
}

impl PhaseCost {
    fn add(&mut self, r: &super::ExecResult) {
        self.exec += r.exec_time;
        self.compile += r.compiled_in.unwrap_or_default();
        self.calls += 1;
    }
}

/// Stream a scaled window through a bound surveillance session in
/// `chunk`-row slices. One reusable slice buffer and one f32 staging
/// buffer serve every chunk (no per-chunk `Mat::zeros`), and device
/// outputs are unpadded straight into the result matrices. Shared by
/// [`DeviceMset`] and [`DeviceAakr`].
fn stream_surveil(
    handle: &DeviceHandle,
    session: u64,
    xs: &Mat,
    chunk: usize,
    bucket_n: usize,
    n_real: usize,
) -> anyhow::Result<(Mat, Mat, PhaseCost)> {
    let mut cost = PhaseCost::default();
    let mut xhat = Mat::zeros(xs.rows, xs.cols);
    let mut resid = Mat::zeros(xs.rows, xs.cols);
    let mut slice = Mat::zeros(0, 0);
    let mut staging = Vec::new();
    let mut row = 0;
    while row < xs.rows {
        let take = (xs.rows - row).min(chunk);
        // Reshape (never growing past the first chunk) and refill the
        // slice buffer, then pad to (chunk × bucket_n) in the staging
        // buffer; `Tensor::new` takes ownership, so the payload itself
        // is the only per-chunk allocation left.
        slice.reshape(take, xs.cols);
        for r in 0..take {
            slice.row_mut(r).copy_from_slice(xs.row(row + r));
        }
        router::pad_mat_f32_into(&slice, chunk, bucket_n, &mut staging);
        let x_pad = Tensor::new(vec![chunk, bucket_n], std::mem::take(&mut staging));
        let r = handle.exec_bound(session, vec![x_pad.clone()])?;
        cost.add(&r);
        // The device loop drops its tensor clone *before* sending the
        // reply (see runtime/mod.rs), so by the time exec_bound returns
        // this Arc is unique again and the staging buffer is recovered
        // for the next chunk (falls back to a fresh Vec otherwise).
        staging = std::sync::Arc::try_unwrap(x_pad.data).unwrap_or_default();
        router::unpad_rows_f32_into(
            r.outputs[0].data.as_slice(),
            bucket_n,
            take,
            n_real,
            &mut xhat,
            row,
        );
        router::unpad_rows_f32_into(
            r.outputs[1].data.as_slice(),
            bucket_n,
            take,
            n_real,
            &mut resid,
            row,
        );
        row += take;
    }
    Ok((xhat, resid, cost))
}

impl DeviceMset {
    /// Create a session for `(n_real, m_real)` from a scaled memory matrix
    /// (`m_real × n_real`, e.g. selected by [`crate::mset::select_memory`]).
    pub fn new(handle: DeviceHandle, d_scaled: &Mat) -> anyhow::Result<DeviceMset> {
        let (m_real, n_real) = (d_scaled.rows, d_scaled.cols);
        let man = handle.manifest()?;
        let bucket = router::pick_bucket(&man.buckets("mset2_train"), n_real, m_real)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact bucket fits n={n_real}, m={m_real} \
                     (largest: {:?}); re-run `make artifacts ARTIFACT_PROFILE=full`",
                    man.buckets("mset2_train").last()
                )
            })?;
        let d_pad = Tensor::new(
            vec![bucket.m, bucket.n],
            router::pad_mat_f32(d_scaled, bucket.m, bucket.n),
        );
        Ok(DeviceMset {
            handle,
            bucket,
            n_real,
            m_real,
            chunk: man.chunk,
            gamma: man.gamma,
            mask: Tensor::new(vec![bucket.m], router::mask_f32(m_real, bucket.m)),
            bw: Tensor::scalar1(router::bandwidth(man.gamma, n_real)),
            d_pad,
            g_pad: None,
            surveil_session: None,
        })
    }

    fn train_id(&self) -> String {
        format!("mset2_train_n{}_m{}", self.bucket.n, self.bucket.m)
    }

    fn surveil_id(&self) -> String {
        format!("mset2_surveil_n{}_m{}", self.bucket.n, self.bucket.m)
    }

    /// Run the training graph; returns the real-block `G` and phase cost.
    pub fn train(&mut self) -> anyhow::Result<(Mat, PhaseCost)> {
        let mut cost = PhaseCost::default();
        // Tensor buffers are Arc-shared, so these clones are O(1) — no
        // re-copy of the padded D/mask/bw payloads per train() call.
        let r = self.handle.exec(
            &self.train_id(),
            vec![self.d_pad.clone(), self.mask.clone(), self.bw.clone()],
        )?;
        cost.add(&r);
        let g_pad = r.outputs.into_iter().next().expect("train emits G");
        let g = router::unpad_mat_f32(
            g_pad.data.as_slice(),
            self.bucket.m,
            self.m_real,
            self.m_real,
        );
        // Bind the surveillance prefix once: D, G, mask, bw stay marshaled
        // on the device thread for every subsequent chunk.
        if let Some(old) = self.surveil_session.take() {
            self.handle.unbind_session(old);
        }
        let session = self.handle.bind_session(
            &self.surveil_id(),
            vec![
                self.d_pad.clone(),
                g_pad.clone(),
                self.mask.clone(),
                self.bw.clone(),
            ],
        )?;
        self.surveil_session = Some(session);
        self.g_pad = Some(g_pad);
        Ok((g, cost))
    }

    /// Stream a scaled observation window (`rows × n_real`) through the
    /// surveillance graph in bucket-sized chunks. Returns estimates,
    /// residuals (both `rows × n_real`) and the phase cost.
    pub fn surveil(&self, xs: &Mat) -> anyhow::Result<(Mat, Mat, PhaseCost)> {
        anyhow::ensure!(xs.cols == self.n_real, "signal count mismatch");
        let session = self
            .surveil_session
            .ok_or_else(|| anyhow::anyhow!("call train() before surveil()"))?;
        stream_surveil(
            &self.handle,
            session,
            xs,
            self.chunk,
            self.bucket.n,
            self.n_real,
        )
    }
}

impl Drop for DeviceMset {
    fn drop(&mut self) {
        if let Some(s) = self.surveil_session.take() {
            self.handle.unbind_session(s);
        }
    }
}

/// Device-resident AAKR session (pluggable alternative; no training graph).
pub struct DeviceAakr {
    handle: DeviceHandle,
    /// Artifact bucket the workload was routed to.
    pub bucket: Bucket,
    /// Real (unpadded) signal count.
    pub n_real: usize,
    /// Real (unpadded) memory-vector count.
    pub m_real: usize,
    /// Observation-chunk rows per surveillance call.
    pub chunk: usize,
    session: u64,
}

impl DeviceAakr {
    /// Create a session for a scaled memory matrix (`m_real × n_real`).
    pub fn new(handle: DeviceHandle, d_scaled: &Mat) -> anyhow::Result<DeviceAakr> {
        let (m_real, n_real) = (d_scaled.rows, d_scaled.cols);
        let man = handle.manifest()?;
        let bucket = router::pick_bucket(&man.buckets("aakr_surveil"), n_real, m_real)
            .ok_or_else(|| anyhow::anyhow!("no aakr bucket fits n={n_real}, m={m_real}"))?;
        let d_pad = Tensor::new(
            vec![bucket.m, bucket.n],
            router::pad_mat_f32(d_scaled, bucket.m, bucket.n),
        );
        let mask = Tensor::new(vec![bucket.m], router::mask_f32(m_real, bucket.m));
        let bw = Tensor::scalar1(router::bandwidth(man.gamma, n_real));
        let session = handle.bind_session(
            &format!("aakr_surveil_n{}_m{}", bucket.n, bucket.m),
            vec![d_pad, mask, bw],
        )?;
        Ok(DeviceAakr {
            handle,
            bucket,
            n_real,
            m_real,
            chunk: man.chunk,
            session,
        })
    }

    /// Stream a scaled window through the AAKR graph.
    pub fn surveil(&self, xs: &Mat) -> anyhow::Result<(Mat, Mat, PhaseCost)> {
        anyhow::ensure!(xs.cols == self.n_real, "signal count mismatch");
        stream_surveil(
            &self.handle,
            self.session,
            xs,
            self.chunk,
            self.bucket.n,
            self.n_real,
        )
    }
}
