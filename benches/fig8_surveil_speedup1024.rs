//! **Fig. 8**: GPU surveillance speedup factor for the **1024-signal**
//! (large IoT) use case vs (observations × memory vectors), log–log.
//! Paper: "can exceed 9000×" — larger use cases accelerate better.
//!
//! 1024 signals exceeds the local artifact buckets, so this figure is
//! model-based over the paper's range (flagged as extrapolated in
//! EXPERIMENTS.md), with the fig7-style local anchor at the largest
//! available bucket for growth-shape verification.
//!
//! Output: `results/fig8_surveil_speedup1024/`.

use containerstress::accel::{self, CpuRef, GpuSpec};
use containerstress::bench::figs;
use containerstress::report;
use containerstress::surface::SurfaceGrid;
use std::path::Path;

const N_SIGNALS: usize = 1024;

fn main() {
    containerstress::util::logger::init();
    let gpu = GpuSpec::v100();
    let cpu = CpuRef::xeon_platinum();
    let out = Path::new("results/fig8_surveil_speedup1024");

    let obs_axis: Vec<usize> = (10..=20).step_by(2).map(|k| 1usize << k).collect();
    let memvecs: Vec<usize> = (11..=13).map(|k| 1usize << k).collect(); // m ≥ 2n = 2048
    let mut grid = SurfaceGrid::new(
        "n_memvec",
        "n_obs",
        memvecs.iter().map(|&v| v as f64).collect(),
        obs_axis.iter().map(|&v| v as f64).collect(),
    );
    let mut hi = 0.0f64;
    for (r, &m) in memvecs.iter().enumerate() {
        for (c, &obs) in obs_axis.iter().enumerate() {
            let s = accel::speedup_surveil(N_SIGNALS, m, obs, &gpu, &cpu);
            hi = hi.max(s);
            grid.set(r, c, s);
        }
    }
    let ascii = report::emit_figure(
        out,
        "fig8_modelled",
        "Fig8: surveillance speedup @1024 signals (modelled, log-log)",
        &grid,
        "speedup",
        true,
    )
    .expect("emit");
    println!("{ascii}");
    println!("peak modelled speedup {hi:.0}× (paper: exceeds 9000×)");
    assert!(hi > 8000.0, "peak {hi} below the paper's 9000× anchor");

    // larger use case must accelerate better than the 64-signal one (the
    // paper's cross-figure conclusion)
    let s64 = accel::speedup_surveil(64, 8192, 1 << 20, &gpu, &cpu);
    let s1024 = accel::speedup_surveil(1024, 8192, 1 << 20, &gpu, &cpu);
    assert!(
        s1024 > s64,
        "1024-signal speedup {s1024} must exceed 64-signal {s64}"
    );
    println!("cross-check: {s64:.0}× (64 sig) < {s1024:.0}× (1024 sig) ✓");

    // growth-shape verification against the local testbed: measured cost
    // per observation must rise with m the way the model's CPU term does.
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (sig_b, mem_b) = figs::available_axes(&handle);
    let n = *sig_b.iter().max().unwrap();
    let trials = if figs::quick() { 1 } else { 2 };
    let ms: Vec<usize> = mem_b.iter().copied().filter(|&m| m >= 2 * n).collect();
    if ms.len() >= 2 {
        let t_small = figs::median(&figs::measure_surveil(&handle, n, ms[0], 1024, trials));
        let t_large = figs::median(&figs::measure_surveil(
            &handle,
            n,
            *ms.last().unwrap(),
            1024,
            trials,
        ));
        println!(
            "measured local growth with m at n={n}: {:.3} ms → {:.3} ms ({}× for {}× memvecs)",
            t_small * 1e3,
            t_large * 1e3,
            (t_large / t_small * 10.0).round() / 10.0,
            ms.last().unwrap() / ms[0]
        );
        assert!(t_large > t_small, "cost must grow with m");
    }
    println!("fig8 done → {}", out.display());
}
