//! Adaptive sweep planner — variance-targeted trial allocation with
//! surface-model cell pruning, streamed over the shared trial executor.
//!
//! The paper's nested-loop sweep spends a fixed `trials` budget on every
//! grid cell, even where the cost surface is already smooth and
//! low-variance. The planner instead converges each cell independently:
//!
//! 1. **Pilot** — every measurable cell is brought up to
//!    [`SweepSpec::pilot_trials`] cheap trials. Measurements preloaded from
//!    the cell cache count toward this for free, so a warm service skips
//!    straight to convergence checks.
//! 2. **Prune** — when [`SweepSpec::interpolate`] is set, both cost
//!    surfaces (train / surveil) are fitted once the whole grid has pilot
//!    data. A cell whose pilot median already agrees with the model's
//!    prediction to within the CI target sits well inside the converged
//!    region: it is marked *interpolated* and receives no further trials.
//!    Pruning only engages when both fits are trustworthy
//!    (r² ≥ [`PRUNE_MIN_R2`]). (In a cache-warm run a pruned cell keeps
//!    however many preloaded trials it arrived with — possibly more than
//!    the pilot budget.)
//! 3. **Allocate** — remaining trials are topped up from a **priority heap
//!    ordered by current relative CI width** (widest first). There is no
//!    round barrier: the moment a cell's own results land it either
//!    retires (CI target met, or the per-cell cap
//!    [`SweepSpec::effective_max_trials`] reached) or re-enters the heap —
//!    a straggler cell never delays its neighbours' retirement or cache
//!    write-back.
//!
//! Trial seeds stay content-derived per `(cell, trial index)` — see
//! [`super::sweep`] — so trial `t` of a cell is fed identical synthetic
//! telemetry no matter how the executor interleaves, how many jobs share
//! it, or which cache top-ups got the planner there. Adaptive and
//! exhaustive sweeps are therefore fully cache-compatible: an adaptive run
//! can finish on an exhaustive run's stored cells and vice versa.

use super::sweep::{
    failed_measure, gap_measure, grid_keys, submit_trial, Backend, Cancelled, CellCosts, CellKey,
    CellMeasure, CellStore, SweepProgress, SweepResult, SweepSpec, TrialCost,
};
use crate::metrics::Registry;
use crate::surface::{ResponseSurface, Sample};
use crate::util::threadpool::{CancelToken, JobTicket};
use crate::util::Summary;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Two-sided normal multiplier for the ~95% confidence interval behind the
/// planner's convergence test.
pub const CI_Z: f64 = 1.96;

/// Minimum response-surface fit quality (r², both phases) before the
/// surface model is trusted to prune cells.
pub const PRUNE_MIN_R2: f64 = 0.9;

/// Relative half-width of the ~95% confidence interval of the mean of
/// `xs`: `z·s / (√n·x̄)` with the sample standard deviation `s`. Returns
/// `f64::INFINITY` below two samples — one timing carries no variance
/// information — so unvisited cells always look unconverged.
pub fn rel_ci(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return f64::INFINITY;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return f64::INFINITY;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    CI_Z * var.sqrt() / ((n as f64).sqrt() * mean)
}

/// Whether both phases of a cell meet the relative-CI target.
pub fn converged(costs: &CellCosts, ci_target: f64) -> bool {
    rel_ci(&costs.train_s) <= ci_target && rel_ci(&costs.surveil_s) <= ci_target
}

/// Trials needed for `rel_ci(xs) ≤ target`, estimated from the current
/// sample: `n ≈ (z·s / (x̄·target))²`. Never less than the current count.
fn needed_trials(xs: &[f64], target: f64) -> usize {
    let n = xs.len();
    if n < 2 {
        return n + 1;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return n;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
    let need = (CI_Z * var.sqrt() / (mean * target)).powi(2);
    (need.ceil() as usize).max(n)
}

/// The heap priority of an unconverged cell: the wider of its two phases'
/// relative CI widths (the planner serves the widest first).
fn ci_width(costs: &CellCosts) -> f64 {
    rel_ci(&costs.train_s).max(rel_ci(&costs.surveil_s))
}

/// Max-heap key over CI widths. `f64::total_cmp` gives a total order
/// (`INFINITY` — an unvisited phase — sorts widest, as it must).
#[derive(PartialEq)]
struct Width(f64);

impl Eq for Width {}

impl PartialOrd for Width {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Width {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Mutable planner state for one measurable (non-gap) cell.
struct CellState {
    key: CellKey,
    costs: CellCosts,
    /// Trials preloaded from the cache (no store-back needed when the
    /// planner adds nothing beyond them).
    cached_trials: usize,
    interpolated: bool,
    /// Final decision made (summary frozen, cache written).
    retired: bool,
    /// Trial indices scheduled so far (appended + buffered + in flight).
    scheduled: usize,
    /// Results that arrived ahead of a missing earlier trial index; they
    /// append the moment the gap fills, keeping `costs` in trial order.
    buffer: HashMap<usize, TrialCost>,
    /// Scheduled trials whose results have not arrived yet.
    in_flight: usize,
    /// A trial exhausted its retries: the cell is quarantined once its
    /// in-flight trials drain (see [`CellMeasure::failed`]).
    failed: bool,
}

impl CellState {
    fn trials(&self) -> usize {
        self.costs.train_s.len()
    }

    /// Record the result of trial `t`, then append every contiguously
    /// available buffered trial so `costs` stays in trial-index order.
    fn absorb(&mut self, t: usize, c: TrialCost) {
        self.buffer.insert(t, c);
        while let Some(c) = self.buffer.remove(&self.costs.train_s.len()) {
            self.costs.train_s.push(c.train_s);
            self.costs.surveil_s.push(c.surveil_s);
        }
    }
}

/// Freeze a cell: write it back to the store (if it gained trials beyond
/// the cached prefix) and bump the progress gauges.
fn retire(
    s: &mut CellState,
    spec: &SweepSpec,
    backend: &Backend,
    cache: Option<&dyn CellStore>,
    progress: &Arc<SweepProgress>,
) {
    debug_assert!(!s.retired, "cell retired twice");
    s.retired = true;
    if s.trials() > s.cached_trials {
        if let Some(c) = cache {
            c.store(s.key, spec, backend.tag(), s.costs.clone());
        }
    }
    if s.interpolated {
        progress.cells_interpolated.fetch_add(1, Ordering::SeqCst);
    }
    progress.cells_done.fetch_add(1, Ordering::SeqCst);
    progress.emit_cell(s.key, if s.interpolated { "interpolated" } else { "measured" });
}

/// Quarantine a cell whose trial exhausted its retries: keep (and store)
/// the contiguous finished prefix, stop scheduling it, and let the sweep
/// finish without it — mirrors the exhaustive engine's poison-cell path.
fn retire_failed(
    s: &mut CellState,
    spec: &SweepSpec,
    backend: &Backend,
    cache: Option<&dyn CellStore>,
    progress: &Arc<SweepProgress>,
) {
    debug_assert!(!s.retired, "cell retired twice");
    s.retired = true;
    if s.trials() > s.cached_trials {
        if let Some(c) = cache {
            // `costs` is contiguous by construction (out-of-order results
            // wait in `buffer`), so the stored entry keeps the prefix
            // property a resumed or fault-free rerun relies on.
            c.store(s.key, spec, backend.tag(), s.costs.clone());
        }
    }
    progress.cells_done.fetch_add(1, Ordering::SeqCst);
    progress.emit_cell(s.key, "failed");
}

/// Submit trials `scheduled..goal` of cell `i` to the executor; returns
/// how many were queued. `trials_planned` is bumped *before* the first
/// task is queued so a fast worker's `trials_done` increment can never be
/// observed ahead of it (the progress counters promise
/// `trials_done ≤ trials_planned`).
#[allow(clippy::too_many_arguments)]
fn dispatch_trials(
    s: &mut CellState,
    i: usize,
    goal: usize,
    spec: &SweepSpec,
    backend: &Backend,
    ticket: &JobTicket,
    tx: &mpsc::Sender<(usize, usize, anyhow::Result<TrialCost>)>,
    progress: &Arc<SweepProgress>,
    cancel: &CancelToken,
) -> usize {
    let n = goal.saturating_sub(s.scheduled);
    progress.trials_planned.fetch_add(n, Ordering::SeqCst);
    for t in s.scheduled..goal {
        submit_trial(ticket, spec, backend, s.key, i, t, tx, progress, cancel);
    }
    s.in_flight += n;
    s.scheduled = s.scheduled.max(goal);
    n
}

/// A cell's trials have all landed — decide its fate: retire it, queue it
/// on the CI-width heap for a top-up, or (before the prune pass has run)
/// park it so the surface model gets first refusal.
#[allow(clippy::too_many_arguments)]
fn on_ready(
    states: &mut [CellState],
    i: usize,
    spec: &SweepSpec,
    target: f64,
    max: usize,
    prune_done: bool,
    heap: &mut BinaryHeap<(Width, Reverse<usize>)>,
    parked: &mut Vec<usize>,
    backend: &Backend,
    cache: Option<&dyn CellStore>,
    progress: &Arc<SweepProgress>,
) {
    let s = &mut states[i];
    if s.retired {
        return;
    }
    if converged(&s.costs, target) {
        retire(s, spec, backend, cache, progress);
        return;
    }
    if !prune_done {
        // Held until the whole grid has pilot data: the surface fit may
        // accept this cell without spending another trial on it.
        parked.push(i);
        return;
    }
    if s.trials() >= max {
        retire(s, spec, backend, cache, progress);
        return;
    }
    heap.push((Width(ci_width(&s.costs)), Reverse(i)));
}

/// Fit both cost surfaces to the current medians and mark unconverged
/// cells whose predictions agree with their pilot medians to within
/// `ci_target`. Returns the number of cells pruned. No-ops when fewer than
/// 10 cells are measurable or either fit is below [`PRUNE_MIN_R2`].
fn prune_by_surface(states: &mut [CellState], ci_target: f64) -> usize {
    // Quarantined (or still-empty) cells carry no usable medians; fit and
    // prune over the healthy cells only.
    let eligible: Vec<usize> = states
        .iter()
        .enumerate()
        .filter(|(_, s)| !s.failed && !s.costs.train_s.is_empty())
        .map(|(i, _)| i)
        .collect();
    if eligible.len() < 10 {
        return 0;
    }
    let sample = |s: &CellState, cost: f64| Sample {
        n_signals: s.key.n,
        n_memvec: s.key.m,
        n_obs: s.key.obs,
        cost: cost.max(1e-9),
    };
    let train: Vec<Sample> = eligible
        .iter()
        .map(|&i| sample(&states[i], Summary::of(&states[i].costs.train_s).median))
        .collect();
    let surveil: Vec<Sample> = eligible
        .iter()
        .map(|&i| sample(&states[i], Summary::of(&states[i].costs.surveil_s).median))
        .collect();
    let (ts, ss) = match (ResponseSurface::fit(&train), ResponseSurface::fit(&surveil)) {
        (Ok(a), Ok(b)) => (a, b),
        _ => return 0,
    };
    if ts.r2 < PRUNE_MIN_R2 || ss.r2 < PRUNE_MIN_R2 {
        log::info!(
            "planner: surface fits too weak to prune (train r²={:.3}, surveil r²={:.3})",
            ts.r2,
            ss.r2
        );
        return 0;
    }
    let mut pruned = 0usize;
    for (j, &i) in eligible.iter().enumerate() {
        let s = &mut states[i];
        if s.retired || s.interpolated || converged(&s.costs, ci_target) {
            continue;
        }
        // `train`/`surveil` were built in `eligible` order — reuse their
        // medians instead of re-sorting both phases per cell.
        let med_t = train[j].cost;
        let med_s = surveil[j].cost;
        let pred_t = ts.predict(s.key.n, s.key.m, s.key.obs);
        let pred_s = ss.predict(s.key.n, s.key.m, s.key.obs);
        let within = |pred: f64, med: f64| med > 0.0 && ((pred - med) / med).abs() <= ci_target;
        if within(pred_t, med_t) && within(pred_s, med_s) {
            s.interpolated = true;
            pruned += 1;
        }
    }
    if pruned > 0 {
        Registry::global().add("sweep.planner.interpolated_cells", pruned as u64);
    }
    pruned
}

/// Run the sweep under the adaptive planner (entered from
/// [`super::sweep::run_sweep_executor`] when [`SweepSpec::adaptive`] is
/// set; the spec is already validated).
pub(crate) fn run_adaptive(
    spec: &SweepSpec,
    backend: Backend,
    cache: Option<&dyn CellStore>,
    ticket: &JobTicket,
    progress: &Arc<SweepProgress>,
) -> anyhow::Result<SweepResult> {
    let pilot = spec.pilot_trials;
    let max = spec.effective_max_trials();
    let target = spec.ci_target;
    let keys = grid_keys(spec);
    let cancel = ticket.cancel_token();
    progress.cells_total.store(keys.len(), Ordering::SeqCst);

    // Preload cell state from the cache; whatever is stored counts toward
    // pilot coverage and convergence for free.
    let mut states: Vec<CellState> = Vec::new();
    let mut gaps = 0usize;
    for &key in &keys {
        if spec.is_gap(key) {
            gaps += 1;
            progress.emit_cell(key, "gap");
            continue;
        }
        let mut costs = CellCosts::default();
        if let Some(c) = cache {
            if let Some(mut got) = c.fetch(key, spec, backend.tag()) {
                // Honour the per-cell bound even against oversized entries,
                // and drop any phase-length mismatch from a foreign store
                // (same defence as the exhaustive path).
                got.normalize(max);
                costs = got;
            }
        }
        let cached_trials = costs.train_s.len();
        states.push(CellState {
            key,
            costs,
            cached_trials,
            interpolated: false,
            retired: false,
            scheduled: cached_trials,
            buffer: HashMap::new(),
            in_flight: 0,
            failed: false,
        });
    }
    progress.cells_done.fetch_add(gaps, Ordering::SeqCst);

    // Scheduling state. `prune_done` starts true when pruning is disabled
    // so nothing is ever parked; the dispatch window bounds speculative
    // top-ups so fresh results keep steering the heap.
    let (tx, rx) = mpsc::channel::<(usize, usize, anyhow::Result<TrialCost>)>();
    let mut heap: BinaryHeap<(Width, Reverse<usize>)> = BinaryHeap::new();
    let mut parked: Vec<usize> = Vec::new();
    let mut prune_done = !spec.interpolate;
    let window = ticket.executor_workers().saturating_mul(2).max(4);
    let mut outstanding = 0usize;
    let mut pilot_gap = 0usize;
    let mut first_err: Option<anyhow::Error> = None;
    let mut dispatches = 0usize;
    let mut starved_rounds = 0usize;
    // Set when the planner itself cancels on a fatal invariant violation
    // (lost results); distinguishes that from an operator cancellation.
    let mut fatal = false;

    // The job driver thread runs this loop, so the job's flight recorder
    // (if any) is in the thread-local; planner phases record driver-side
    // spans while the per-trial spans come from the executor tasks.
    let recorder = crate::obs::current();

    // Pilot: bring every cell up to `pilot` trials (cache counts for free).
    let pilot_t0 = Instant::now();
    for (i, s) in states.iter_mut().enumerate() {
        if s.trials() < pilot {
            pilot_gap += 1;
            outstanding +=
                dispatch_trials(s, i, pilot, spec, &backend, ticket, &tx, progress, &cancel);
        }
    }
    if let Some(rec) = &recorder {
        rec.push(
            "planner",
            "pilot",
            pilot_t0,
            Instant::now(),
            Duration::ZERO,
            format!("cells={} scheduled={pilot_gap} outstanding={outstanding}", states.len()),
        );
    }
    log::info!(
        "planner pilot: {} cells ({} scheduled up to {pilot} trials, {} cached trials), \
         ci_target={target}, max_trials={max}, model={}, backend={}, executor={}",
        states.len(),
        pilot_gap,
        states.iter().map(|s| s.cached_trials).sum::<usize>(),
        spec.model,
        backend.tag(),
        ticket.executor_workers()
    );

    // Cells the cache already carried past the pilot are ready right away.
    let ready0: Vec<usize> = states
        .iter()
        .enumerate()
        .filter(|(_, s)| s.in_flight == 0)
        .map(|(i, _)| i)
        .collect();
    for i in ready0 {
        on_ready(
            &mut states, i, spec, target, max, prune_done, &mut heap, &mut parked, &backend,
            cache, progress,
        );
    }

    loop {
        if cancel.is_cancelled() {
            break;
        }
        if !prune_done && pilot_gap == 0 {
            // The whole grid has pilot data: fit the surfaces once, accept
            // predictable cells, then release the parked cells to the heap.
            prune_done = true;
            let prune_t0 = Instant::now();
            let pruned = prune_by_surface(&mut states, target);
            if let Some(rec) = &recorder {
                rec.push(
                    "planner",
                    "prune",
                    prune_t0,
                    Instant::now(),
                    Duration::ZERO,
                    format!("pruned={pruned} parked={}", parked.len()),
                );
            }
            if pruned > 0 {
                log::info!("planner: {pruned} cells accepted via surface interpolation");
            }
            for i in std::mem::take(&mut parked) {
                if states[i].interpolated {
                    retire(&mut states[i], spec, &backend, cache, progress);
                } else {
                    on_ready(
                        &mut states, i, spec, target, max, prune_done, &mut heap, &mut parked,
                        &backend, cache, progress,
                    );
                }
            }
        }
        // Top-ups: widest relative CI first, while the window has room.
        if prune_done {
            let round_t0 = Instant::now();
            let dispatches_before = dispatches;
            while outstanding < window {
                let Some((_, Reverse(i))) = heap.pop() else { break };
                let s = &mut states[i];
                if s.retired || s.interpolated || s.in_flight > 0 {
                    continue;
                }
                let n = s.trials();
                if n >= max || converged(&s.costs, target) {
                    retire(s, spec, &backend, cache, progress);
                    continue;
                }
                let goal = needed_trials(&s.costs.train_s, target)
                    .max(needed_trials(&s.costs.surveil_s, target))
                    .clamp(n + 1, max);
                outstanding +=
                    dispatch_trials(s, i, goal, spec, &backend, ticket, &tx, progress, &cancel);
                dispatches += 1;
            }
            if dispatches > dispatches_before {
                if let Some(rec) = &recorder {
                    rec.push(
                        "planner",
                        "round",
                        round_t0,
                        Instant::now(),
                        Duration::ZERO,
                        format!(
                            "dispatches={} outstanding={outstanding}",
                            dispatches - dispatches_before
                        ),
                    );
                }
            }
        }
        if outstanding == 0 && heap.is_empty() && parked.is_empty() && pilot_gap == 0 {
            break;
        }
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok((i, t, r)) => {
                starved_rounds = 0;
                outstanding = outstanding.saturating_sub(1);
                match r {
                    Ok(c) => {
                        let ready = {
                            let s = &mut states[i];
                            s.in_flight = s.in_flight.saturating_sub(1);
                            let before = s.trials();
                            s.absorb(t, c);
                            if before < pilot && s.trials() >= pilot {
                                pilot_gap -= 1;
                            }
                            s.in_flight == 0
                        };
                        if ready {
                            if states[i].failed {
                                // A sibling trial already poisoned this
                                // cell; quarantine it now that its last
                                // in-flight result has landed.
                                if states[i].trials() < pilot {
                                    pilot_gap -= 1;
                                }
                                retire_failed(&mut states[i], spec, &backend, cache, progress);
                            } else {
                                on_ready(
                                    &mut states, i, spec, target, max, prune_done, &mut heap,
                                    &mut parked, &backend, cache, progress,
                                );
                            }
                        }
                    }
                    Err(e) => {
                        // Retries exhausted (see `submit_trial`): quarantine
                        // the cell, keep the sweep going. The job only
                        // errors if every measurable cell ends up failed.
                        let ready = {
                            let s = &mut states[i];
                            s.in_flight = s.in_flight.saturating_sub(1);
                            s.failed = true;
                            if first_err.is_none() {
                                first_err =
                                    Some(anyhow::anyhow!("cell {:?}: {e:#}", s.key));
                            }
                            s.in_flight == 0
                        };
                        if ready {
                            if states[i].trials() < pilot {
                                pilot_gap -= 1;
                            }
                            retire_failed(&mut states[i], spec, &backend, cache, progress);
                        }
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Task panics are contained and retried inside the task,
                // so a silently-consumed result should be impossible — but
                // keep the backstop: if the executor has nothing queued or
                // running for this job across two silent timeouts (one
                // guards against a result racing the first check), the
                // outstanding count can never drain — fail the job instead
                // of spinning forever.
                if outstanding > 0 && ticket.pending() == (0, 0) {
                    starved_rounds += 1;
                    if starved_rounds >= 2 {
                        first_err = Some(anyhow::anyhow!(
                            "{outstanding} trial results lost (task reclaimed without cancel?)"
                        ));
                        fatal = true;
                        cancel.cancel();
                        break;
                    }
                } else {
                    starved_rounds = 0;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break, // unreachable: we hold `tx`
        }
    }

    if cancel.is_cancelled() {
        // Drain whatever in-flight trials still land (queued tasks were
        // reclaimed by the executor), then flush every cell's contiguous
        // finished prefix so a resubmission reuses the stranded work.
        loop {
            if ticket.pending() == (0, 0) {
                while let Ok((i, t, r)) = rx.try_recv() {
                    if let Ok(c) = r {
                        states[i].absorb(t, c);
                    }
                }
                break;
            }
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok((i, t, r)) => {
                    if let Ok(c) = r {
                        states[i].absorb(t, c);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if fatal {
            return Err(first_err
                .unwrap_or_else(|| anyhow::anyhow!("planner failed without a recorded cause")));
        }
        let mut flushed = 0usize;
        for s in states.iter_mut().filter(|s| !s.retired) {
            if s.trials() > s.cached_trials {
                if let Some(c) = cache {
                    c.store(s.key, spec, backend.tag(), s.costs.clone());
                    flushed += 1;
                }
            }
        }
        log::info!("planner cancelled: {flushed} partial cells flushed to the store");
        return Err(Cancelled.into());
    }
    Registry::global().add("sweep.planner.rounds", dispatches as u64);

    // Assemble in grid order (every measurable cell has retired).
    let by_key: HashMap<CellKey, &CellState> = states.iter().map(|s| (s.key, s)).collect();
    let mut cells = Vec::new();
    for &key in &keys {
        if spec.is_gap(key) {
            cells.push(gap_measure(key));
            continue;
        }
        let s = by_key.get(&key).expect("planner state for measurable cell");
        debug_assert!(s.retired, "unretired cell at assembly");
        if s.failed {
            // Quarantined: carries whatever contiguous prefix succeeded.
            cells.push(failed_measure(key, &s.costs));
            continue;
        }
        anyhow::ensure!(
            !s.costs.train_s.is_empty(),
            "no trials completed for {key:?}"
        );
        cells.push(CellMeasure {
            key,
            train: Some(Summary::of(&s.costs.train_s)),
            surveil: Some(Summary::of(&s.costs.surveil_s)),
            violated: false,
            interpolated: s.interpolated,
            failed: false,
        });
    }
    // Quarantine keeps partial results useful; a sweep where *every*
    // measurable cell failed is still a job error.
    let measurable = cells.iter().filter(|c| !c.violated).count();
    let failed_n = cells.iter().filter(|c| c.failed).count();
    if measurable > 0 && failed_n == measurable {
        let cause = first_err
            .take()
            .unwrap_or_else(|| anyhow::anyhow!("unknown trial failure"));
        return Err(cause.context(format!(
            "sweep failed: all {measurable} measurable cells quarantined after trial retries"
        )));
    }
    if failed_n > 0 {
        log::warn!("planner finished with {failed_n}/{measurable} cells quarantined");
    }
    Ok(SweepResult {
        spec: spec.clone(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_sweep_cached;
    use crate::service::cache::SweepCache;

    fn adaptive_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![2, 3, 4],
            memvecs: vec![8, 12, 16],
            obs: vec![16, 32],
            trials: 4,
            seed: 9,
            model: "mset2".into(),
            workers: 2,
            pilot_trials: 2,
            ci_target: 0.5,
            max_trials: 4,
            interpolate: false,
        }
    }

    #[test]
    fn rel_ci_basics() {
        assert!(rel_ci(&[]).is_infinite());
        assert!(rel_ci(&[1.0]).is_infinite());
        assert_eq!(rel_ci(&[2.0, 2.0, 2.0]), 0.0);
        // wide spread → wide interval
        assert!(rel_ci(&[1.0, 10.0]) > 1.0);
    }

    #[test]
    fn width_orders_infinity_widest() {
        let mut h = BinaryHeap::new();
        h.push((Width(0.3), Reverse(0usize)));
        h.push((Width(f64::INFINITY), Reverse(1usize)));
        h.push((Width(0.9), Reverse(2usize)));
        let order: Vec<usize> = std::iter::from_fn(|| h.pop().map(|(_, Reverse(i))| i))
            .collect();
        assert_eq!(order, vec![1, 2, 0], "widest CI must be served first");
    }

    #[test]
    fn adaptive_counts_stay_within_bounds() {
        let res = run_sweep_cached(&adaptive_spec(), Backend::Native, None).unwrap();
        assert_eq!(res.cells.len(), 18);
        assert!(res.gap_cells().is_empty()); // m ≥ 2n everywhere on this grid
        for c in &res.cells {
            let t = c.train.as_ref().unwrap();
            let s = c.surveil.as_ref().unwrap();
            assert_eq!(t.n, s.n, "phases share the trial schedule");
            assert!(
                (2..=4).contains(&t.n),
                "cell {:?} ran {} trials, outside [pilot, max]",
                c.key,
                t.n
            );
            assert!(!c.interpolated, "interpolate=false must never mark cells");
        }
    }

    #[test]
    fn interpolated_cells_keep_pilot_budget() {
        let spec = SweepSpec {
            interpolate: true,
            ..adaptive_spec()
        };
        let res = run_sweep_cached(&spec, Backend::Native, None).unwrap();
        for c in &res.cells {
            if c.interpolated {
                assert_eq!(
                    c.train.as_ref().unwrap().n,
                    spec.pilot_trials,
                    "pruned cells must stop at the pilot budget"
                );
            }
        }
        // Whether any cell prunes depends on measured noise, but the result
        // must always partition cleanly.
        assert_eq!(
            res.measured_cells() + res.interpolated_cells() + res.gap_cells().len(),
            res.cells.len()
        );
    }

    #[test]
    fn all_gap_grid_yields_no_measurements_and_no_panic() {
        let spec = SweepSpec {
            signals: vec![8],
            memvecs: vec![8], // 8 < 2·8 → gap
            obs: vec![16],
            ..adaptive_spec()
        };
        let res = run_sweep_cached(&spec, Backend::Native, None).unwrap();
        assert_eq!(res.cells.len(), 1);
        assert!(res.cells[0].violated);
        assert_eq!(res.measured_cells(), 0);
        assert_eq!(res.total_trials(), 0);
    }

    #[test]
    fn second_adaptive_run_is_served_from_cache() {
        let cache = SweepCache::in_memory();
        let spec = adaptive_spec();
        let a = run_sweep_cached(&spec, Backend::Native, Some(&cache)).unwrap();
        let stored = cache.len();
        assert_eq!(stored, 18);

        // Identical request: every cell's stored trials already satisfy
        // the planner — each terminated converged or at the cap, and with
        // interpolate=false no noise-dependent prune decision is re-made —
        // so no new trials run and the summaries are bit-identical. (With
        // interpolate=true a warm run may legitimately re-measure a cell
        // the cold run pruned, since the re-fitted surface sees newer
        // medians; that refinement is allowed, just not exercised here.)
        let b = run_sweep_cached(&spec, Backend::Native, Some(&cache)).unwrap();
        assert_eq!(cache.hits(), 18);
        assert_eq!(cache.len(), stored);
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.key, cb.key);
            assert_eq!(
                ca.train.as_ref().unwrap().n,
                cb.train.as_ref().unwrap().n,
                "cell {:?} re-measured despite warm cache",
                ca.key
            );
            assert_eq!(
                ca.train.as_ref().unwrap().median,
                cb.train.as_ref().unwrap().median
            );
        }
    }

    #[test]
    fn planner_reports_all_cells_quarantined_as_classified_error() {
        use crate::util::failpoint;
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        failpoint::arm_from_str("executor.trial.run:1:error:4").unwrap();
        let err = run_sweep_cached(&adaptive_spec(), Backend::Native, None).unwrap_err();
        failpoint::disarm_all();
        assert!(
            failpoint::is_injected(&err),
            "error must classify as injected: {err:#}"
        );
        assert!(format!("{err:#}").contains("quarantined"), "{err:#}");
    }

    #[test]
    fn warm_cache_makes_adaptive_run_immune_to_trial_faults() {
        use crate::util::failpoint;
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        let cache = SweepCache::in_memory();
        let spec = adaptive_spec();
        let a = run_sweep_cached(&spec, Backend::Native, Some(&cache)).unwrap();
        // Every trial would fail — but a warm cache schedules none, so the
        // run completes bit-identically to the fault-free one.
        failpoint::arm_from_str("executor.trial.run:1:error:4").unwrap();
        let b = run_sweep_cached(&spec, Backend::Native, Some(&cache)).unwrap();
        failpoint::disarm_all();
        assert!(b.failed_cells().is_empty());
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.key, cb.key);
            assert_eq!(
                ca.train.as_ref().unwrap().median,
                cb.train.as_ref().unwrap().median
            );
        }
    }

    #[test]
    fn exhaustive_run_tops_up_short_adaptive_entries() {
        // An adaptive sweep may store fewer trials per cell than a later
        // exhaustive request needs; the exhaustive run keeps the stored
        // prefix and measures only the missing trial indices.
        let cache = SweepCache::in_memory();
        let adaptive = adaptive_spec();
        run_sweep_cached(&adaptive, Backend::Native, Some(&cache)).unwrap();
        let exhaustive = SweepSpec {
            ci_target: 0.0,
            trials: 4,
            ..adaptive_spec()
        };
        let probe = CellKey { n: 2, m: 8, obs: 16 };
        let before = CellStore::fetch(&cache, probe, &exhaustive, "native").unwrap();
        let res = run_sweep_cached(&exhaustive, Backend::Native, Some(&cache)).unwrap();
        for c in &res.cells {
            assert_eq!(c.train.as_ref().unwrap().n, 4);
            assert!(!c.interpolated);
        }
        let after = CellStore::fetch(&cache, probe, &exhaustive, "native").unwrap();
        assert_eq!(after.train_s.len(), 4);
        assert_eq!(
            &after.train_s[..before.train_s.len()],
            &before.train_s[..],
            "the cached prefix must be reused, not re-measured"
        );
    }
}
