//! Log-bucketed bounded histogram (HDR-style).
//!
//! Fixed memory regardless of how many observations are recorded: values
//! land in geometrically spaced buckets (8 per octave) spanning
//! [`Histogram::MIN_TRACKED`] .. [`Histogram::MAX_TRACKED`], with one
//! underflow and one overflow bucket catching everything outside. Count,
//! sum, sum-of-squares, min and max are tracked exactly, so `mean`/`std`
//! are exact while quantiles are approximate: a bucket spans a 2^(1/8)
//! ratio, its geometric midpoint is within 2^(1/16) − 1 ≈ 4.4% of any
//! value inside it, so reported quantiles carry **≤ 5% relative error**
//! for in-range values (exact `min`/`max` clamp the tails).
//!
//! Histograms are mergeable (same fixed layout everywhere), which is what
//! lets per-shard registries or checkpointed snapshots be combined without
//! replaying raw samples.

use crate::util::Summary;

/// Sub-buckets per power of two (bucket width ratio = 2^(1/8) ≈ 1.09).
const SUB_PER_OCTAVE: usize = 8;
/// Octaves covered between the smallest and largest tracked value.
const OCTAVES: usize = 60;
/// Log-spaced buckets, excluding the underflow/overflow catch-alls.
const LOG_BUCKETS: usize = SUB_PER_OCTAVE * OCTAVES;
/// Total bucket slots: underflow + log region + overflow.
const TOTAL_BUCKETS: usize = LOG_BUCKETS + 2;

/// Bounded log-bucketed histogram; see the module docs for the error
/// contract.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// `counts[0]` = underflow (v < MIN_TRACKED, incl. zero/negative),
    /// `counts[1..=LOG_BUCKETS]` = log region, last slot = overflow.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// Smallest value resolved by the log region (1 ns when recording
    /// seconds). Anything below — including zero and negatives — lands in
    /// the underflow bucket but still counts toward `n`/`sum`/`min`.
    pub const MIN_TRACKED: f64 = 1e-9;

    /// Upper edge of the log region: `MIN_TRACKED · 2^60` ≈ 1.15e9.
    /// Larger values land in the overflow bucket (exact `max` is kept).
    pub const MAX_TRACKED: f64 = Self::MIN_TRACKED * (1u64 << OCTAVES) as f64;

    /// Fixed number of bucket slots — the memory bound: one `u64` each,
    /// independent of how many observations are recorded.
    pub const BUCKETS: usize = TOTAL_BUCKETS;

    /// Empty histogram (fixed allocation up front).
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; TOTAL_BUCKETS],
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket slot for a value.
    fn index_of(v: f64) -> usize {
        if v.is_nan() || v < Self::MIN_TRACKED {
            // NaN and anything below the resolved range → underflow.
            return 0;
        }
        let idx = ((v / Self::MIN_TRACKED).log2() * SUB_PER_OCTAVE as f64).floor();
        if idx >= LOG_BUCKETS as f64 {
            TOTAL_BUCKETS - 1
        } else {
            // idx ≥ 0 because v ≥ MIN_TRACKED.
            idx as usize + 1
        }
    }

    /// Upper bound of a log-region slot (1-based within the log region).
    fn upper_bound(slot: usize) -> f64 {
        Self::MIN_TRACKED * (slot as f64 / SUB_PER_OCTAVE as f64).exp2()
    }

    /// Representative value reported for a slot: the geometric midpoint of
    /// its bounds (which is what bounds quantile error at ≤ 5%).
    fn representative(&self, slot: usize) -> f64 {
        let rep = if slot == 0 {
            self.min
        } else if slot == TOTAL_BUCKETS - 1 {
            self.max
        } else {
            Self::MIN_TRACKED * ((slot as f64 - 0.5) / SUB_PER_OCTAVE as f64).exp2()
        };
        // Exact extremes clamp the tails so a quantile never leaves the
        // observed range.
        rep.clamp(self.min, self.max)
    }

    /// Record one observation. NaN is treated as an underflow observation
    /// of value 0 (it cannot perturb `min`/`max`/`sum`).
    pub fn record(&mut self, v: f64) {
        let v = if v.is_nan() { 0.0 } else { v };
        self.counts[Self::index_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded observations (exact).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Smallest recorded observation (exact); `None` when empty.
    pub fn min(&self) -> Option<f64> {
        (self.count > 0).then_some(self.min)
    }

    /// Largest recorded observation (exact); `None` when empty.
    pub fn max(&self) -> Option<f64> {
        (self.count > 0).then_some(self.max)
    }

    /// Fold another histogram into this one (same fixed layout always).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate quantile (`q` in [0, 1]); `None` when empty. Error is
    /// ≤ 5% relative for in-range values (see module docs).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (slot, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Some(self.representative(slot));
            }
        }
        Some(self.max)
    }

    /// [`Summary`]-shaped digest: `n`/`mean`/`std`/`min`/`max` exact,
    /// quantiles approximate per the module error contract. `None` when
    /// empty.
    pub fn summary(&self) -> Option<Summary> {
        if self.count == 0 {
            return None;
        }
        let n = self.count as f64;
        let mean = self.sum / n;
        let var = (self.sum_sq / n - mean * mean).max(0.0);
        Some(Summary {
            n: self.count as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            p25: self.quantile(0.25).unwrap_or(self.min),
            median: self.quantile(0.5).unwrap_or(self.min),
            p75: self.quantile(0.75).unwrap_or(self.min),
            max: self.max,
        })
    }

    /// Cumulative non-empty buckets as `(upper_bound, cumulative_count)`
    /// pairs with strictly increasing bounds — the Prometheus `le` series
    /// (the renderer appends the `+Inf` bucket). Overflow observations
    /// appear only in `+Inf`, i.e. in the final cumulative count.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (slot, &c) in self.counts.iter().enumerate().take(TOTAL_BUCKETS - 1) {
            cum += c;
            if c > 0 {
                let le = if slot == 0 {
                    Self::MIN_TRACKED
                } else {
                    Self::upper_bound(slot)
                };
                out.push((le, cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fields_and_bounded_layout() {
        let mut h = Histogram::new();
        assert!(h.summary().is_none());
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.sum() - 500.5).abs() < 1e-9);
        assert_eq!(h.min(), Some(1e-3));
        assert_eq!(h.max(), Some(1.0));
        // memory bound: the layout never grows with observations
        assert_eq!(h.counts.len(), Histogram::BUCKETS);
    }

    #[test]
    fn quantiles_within_documented_error() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64 * 1e-4); // uniform on (0, 1]
        }
        for (q, exact) in [(0.25, 0.25), (0.5, 0.5), (0.75, 0.75), (0.99, 0.99)] {
            let got = h.quantile(q).unwrap();
            let rel = (got - exact).abs() / exact;
            assert!(rel <= 0.05, "q={q}: got {got}, exact {exact}, rel {rel}");
        }
    }

    #[test]
    fn underflow_overflow_and_nan() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(1e12);
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), Some(-3.0));
        assert_eq!(h.max(), Some(1e12));
        // quantiles stay inside the observed range
        let q = h.quantile(0.99).unwrap();
        assert!((-3.0..=1e12).contains(&q));
    }

    #[test]
    fn merge_matches_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        for i in 0..500 {
            let v = 1e-3 * (1.0 + (i % 97) as f64);
            a.record(v);
            both.record(v);
        }
        for i in 0..300 {
            let v = 2e-2 * (1.0 + (i % 53) as f64);
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.counts, both.counts);
        assert_eq!(a.quantile(0.5), both.quantile(0.5));
        let (sa, sb) = (a.summary().unwrap(), both.summary().unwrap());
        assert!((sa.mean - sb.mean).abs() < 1e-12);
        assert!((sa.std - sb.std).abs() < 1e-9);
    }

    #[test]
    fn cumulative_buckets_are_monotonic() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        h.record(1e12); // overflow: only visible via +Inf
        let buckets = h.cumulative_buckets();
        assert!(!buckets.is_empty());
        for w in buckets.windows(2) {
            assert!(w[0].0 < w[1].0, "le bounds must increase");
            assert!(w[0].1 <= w[1].1, "cumulative counts must not decrease");
        }
        // the last cumulative count excludes the overflow observation
        assert_eq!(buckets.last().unwrap().1, 100);
    }
}
