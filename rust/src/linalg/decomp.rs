//! Matrix decompositions: Cholesky, QR least squares, symmetric Jacobi
//! eigendecomposition, and the regularised pseudo-inverse MSET training uses.
//!
//! The Jacobi rotations and the pseudo-inverse reconstruction sit on the
//! native MSET training hot path (`reg_pinv` runs once per trial), so both
//! have allocation-free `_into` variants fed from a
//! [`super::workspace::Workspace`], the rotations stream contiguous row
//! slices instead of per-element indexed access (same arithmetic, same
//! op order — eigenvalues are bit-identical to the index-based loop), and
//! the reconstruction `V·diag(d)·Vᵀ` runs through the blocked
//! [`super::kernel::syrk_into`] — which also makes the returned inverse
//! *exactly* symmetric.

use super::kernel;
use super::mat::Mat;
use super::workspace::Workspace;

/// Cholesky factor `L` with `L Lᵀ = A` for symmetric positive-definite `A`.
/// Returns `None` if a pivot drops below `eps` (not SPD).
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols, "cholesky: square required");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 1e-14 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    let n = a.rows;
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    // back: Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    Some(x)
}

/// Least squares `min ‖A x − b‖₂` via normal equations with ridge fallback:
/// used by the response-surface fitter where `A` is tall and well-scaled.
pub fn lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    let at = a.transpose();
    let mut ata = at.matmul(a);
    let atb = at.matvec(b);
    // Tikhonov jitter escalates until the system factors.
    let trace: f64 = (0..ata.rows).map(|i| ata[(i, i)]).sum();
    let mut jitter = 1e-12 * trace.max(1.0) / ata.rows as f64;
    for _ in 0..12 {
        if let Some(x) = solve_spd(&ata, &atb) {
            return x;
        }
        for i in 0..ata.rows {
            ata[(i, i)] += jitter;
        }
        jitter *= 10.0;
    }
    panic!("lstsq: normal equations failed to factor");
}

/// Symmetric eigendecomposition by cyclic Jacobi rotations.
/// Returns `(eigenvalues, V)` with `A = V diag(w) Vᵀ`, eigenvalues ascending.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    Workspace::with(|ws| {
        let mut w = Vec::new();
        let mut v = Mat::zeros(0, 0);
        eigh_into(a, &mut w, &mut v, ws);
        (w, v)
    })
}

/// [`eigh`] writing into caller-owned outputs, with all internal scratch
/// (the working copy, the sort permutation, the column-permuted
/// eigenvectors) checked out of `ws` — zero heap allocations once warm.
pub fn eigh_into(a: &Mat, w: &mut Vec<f64>, v: &mut Mat, ws: &mut Workspace) {
    assert_eq!(a.rows, a.cols, "eigh: square required");
    let n = a.rows;
    let mut mb = ws.take_f64(n * n);
    mb.copy_from_slice(&a.data);
    v.reshape(n, n);
    v.data.fill(0.0);
    for i in 0..n {
        v.data[i * n + i] = 1.0;
    }
    let vd = &mut v.data;
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm (upper triangle, contiguous rows)
        let mut off = 0.0;
        for i in 0..n {
            for &x in &mb[i * n + i + 1..(i + 1) * n] {
                off += x * x;
            }
        }
        let norm = mb.iter().map(|x| x * x).sum::<f64>().sqrt();
        if off.sqrt() < 1e-12 * (1.0 + norm) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = mb[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = mb[p * n + p];
                let aqq = mb[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // columns p,q rotated row-wise: contiguous chunks instead
                // of strided indexed access (identical op order)
                for row in mb.chunks_exact_mut(n) {
                    let mkp = row[p];
                    let mkq = row[q];
                    row[p] = c * mkp - s * mkq;
                    row[q] = s * mkp + c * mkq;
                }
                // rows p,q (p < q): two disjoint contiguous slices
                let (head, tail) = mb.split_at_mut(q * n);
                let rp = &mut head[p * n..p * n + n];
                let rq = &mut tail[..n];
                for (mp, mq) in rp.iter_mut().zip(rq.iter_mut()) {
                    let mpk = *mp;
                    let mqk = *mq;
                    *mp = c * mpk - s * mqk;
                    *mq = s * mpk + c * mqk;
                }
                // eigenvector columns p,q, row-wise like the columns above
                for row in vd.chunks_exact_mut(n) {
                    let vkp = row[p];
                    let vkq = row[q];
                    row[p] = c * vkp - s * vkq;
                    row[q] = s * vkp + c * vkq;
                }
            }
        }
    }
    w.clear();
    w.extend((0..n).map(|i| mb[i * n + i]));
    // sort ascending, permute V columns to match
    let mut order = ws.take_idx(n);
    for (i, o) in order.iter_mut().enumerate() {
        *o = i;
    }
    order.sort_by(|&i, &j| w[i].partial_cmp(&w[j]).unwrap());
    let mut vperm = ws.take_f64(n * n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            vperm[r * n + new_c] = vd[r * n + old_c];
        }
    }
    vd.copy_from_slice(&vperm);
    let mut wsorted = ws.take_f64(n);
    for (slot, &i) in order.iter().enumerate() {
        wsorted[slot] = w[i];
    }
    w.copy_from_slice(&wsorted);
    ws.give_f64(wsorted);
    ws.give_f64(vperm);
    ws.give_idx(order);
    ws.give_f64(mb);
}

/// Regularised symmetric pseudo-inverse: `(A + λI)⁻¹` computed through the
/// eigendecomposition with an eigenvalue floor — the same construction the
/// paper applies to the MSET similarity matrix via cuSOLVER.
pub fn reg_pinv(a: &Mat, lambda: f64) -> Mat {
    Workspace::with(|ws| {
        let mut out = Mat::zeros(0, 0);
        reg_pinv_into(&mut out, a, lambda, ws);
        out
    })
}

/// [`reg_pinv`] writing into a caller-owned matrix with workspace-backed
/// scratch. The reconstruction `V·diag(1/(w+λ))·Vᵀ` is factored as
/// `W'·W'ᵀ` with `W' = V·diag(√·)` and runs through the blocked
/// [`kernel::syrk_into`] — half the naive flops, and the result is
/// *exactly* symmetric (the surveillance path exploits this).
pub fn reg_pinv_into(out: &mut Mat, a: &Mat, lambda: f64, ws: &mut Workspace) {
    let n = a.rows;
    if n == 0 {
        out.reshape(0, 0);
        return;
    }
    let mut w = ws.take_f64(0);
    let mut v = Mat {
        rows: 0,
        cols: 0,
        data: ws.take_f64(0),
    };
    eigh_into(a, &mut w, &mut v, ws);
    let floor = 1e-12 * w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
    let mut dsq = ws.take_f64(n);
    for (d, &wk) in dsq.iter_mut().zip(w.iter()) {
        *d = (1.0 / (wk + lambda).max(floor)).sqrt();
    }
    let mut scaled = Mat {
        rows: n,
        cols: n,
        data: ws.take_f64(n * n),
    };
    for (srow, vrow) in scaled
        .data
        .chunks_exact_mut(n)
        .zip(v.data.chunks_exact(n))
    {
        for ((s, &vv), &d) in srow.iter_mut().zip(vrow).zip(dsq.iter()) {
            *s = vv * d;
        }
    }
    kernel::syrk_into(out, &scaled);
    ws.give_f64(scaled.data);
    ws.give_f64(dsq);
    ws.give_f64(v.data);
    ws.give_f64(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let mut b = Mat::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.gauss();
        }
        let bt = b.transpose();
        let mut a = bt.matmul(&b);
        for i in 0..n {
            a[(i, i)] += n as f64; // well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9, "diff={}", a.max_abs_diff(&rec));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eig −1, 3
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_roundtrip() {
        let mut rng = Rng::new(2);
        let a = random_spd(10, &mut rng);
        let x_true: Vec<f64> = (0..10).map(|i| i as f64 - 4.5).collect();
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn lstsq_recovers_line() {
        // y = 2 + 3x, overdetermined
        let xs: Vec<f64> = (0..20).map(|i| i as f64 / 5.0).collect();
        let a = Mat::from_rows(xs.iter().map(|&x| vec![1.0, x]).collect());
        let b: Vec<f64> = xs.iter().map(|&x| 2.0 + 3.0 * x).collect();
        let c = lstsq(&a, &b);
        assert!((c[0] - 2.0).abs() < 1e-9 && (c[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn eigh_reconstructs_and_orthogonal() {
        let mut rng = Rng::new(3);
        let a = random_spd(12, &mut rng);
        let (w, v) = eigh(&a);
        // ascending
        for k in 1..w.len() {
            assert!(w[k] >= w[k - 1]);
        }
        // V diag(w) Vᵀ == A
        let mut d = Mat::zeros(12, 12);
        for i in 0..12 {
            d[(i, i)] = w[i];
        }
        let rec = v.matmul(&d).matmul(&v.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-8, "diff={}", a.max_abs_diff(&rec));
        // VᵀV == I
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.max_abs_diff(&Mat::eye(12)) < 1e-9);
    }

    #[test]
    fn eigh_known_2x2() {
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (w, _) = eigh(&a);
        assert!((w[0] - 1.0).abs() < 1e-10 && (w[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn reg_pinv_inverts_well_conditioned() {
        let mut rng = Rng::new(4);
        let a = random_spd(6, &mut rng);
        let inv = reg_pinv(&a, 0.0);
        let eye = a.matmul(&inv);
        assert!(eye.max_abs_diff(&Mat::eye(6)) < 1e-7);
    }

    #[test]
    fn reg_pinv_exactly_symmetric() {
        // the syrk-based reconstruction mirrors its lower triangle, so the
        // inverse is symmetric to the bit — surveil relies on this.
        let mut rng = Rng::new(9);
        let a = random_spd(7, &mut rng);
        let p = reg_pinv(&a, 1e-3);
        for i in 0..7 {
            for j in 0..7 {
                assert_eq!(p[(i, j)].to_bits(), p[(j, i)].to_bits());
            }
        }
    }

    #[test]
    fn eigh_into_matches_eigh() {
        let mut rng = Rng::new(10);
        let a = random_spd(9, &mut rng);
        let (w1, v1) = eigh(&a);
        let mut ws = crate::linalg::Workspace::new();
        let mut w2 = Vec::new();
        let mut v2 = Mat::zeros(0, 0);
        eigh_into(&a, &mut w2, &mut v2, &mut ws);
        assert_eq!(w1, w2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn reg_pinv_handles_singular() {
        // rank-1 matrix; with λ>0 result stays finite
        let a = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let p = reg_pinv(&a, 0.1);
        assert!(p.data.iter().all(|x| x.is_finite()));
    }
}
