//! The **surface oracle**: answers per-epoch "what does workload *w* cost
//! on shape *s*?" queries without re-running Monte Carlo trials.
//!
//! Following the "build oracles, don't re-simulate" idea (PAPERS.md,
//! arXiv 2308.06815), a fitted [`ResponseSurface`] *is* an exact online
//! cost model over the measured design grid. The oracle layers three
//! answer sources, cheapest first:
//!
//! 1. **surface** — queries inside the fitted grid's bounding box are a
//!    10-coefficient polynomial evaluation (then memoised);
//! 2. **cell store** — out-of-domain queries with a [`MeasureCtx`]
//!    run a one-cell exhaustive sweep through the shared
//!    [`crate::util::threadpool::TrialExecutor`]; a warm
//!    [`CellStore`] serves the cell without executing a single trial;
//! 3. **fresh trials** — only a genuinely new out-of-domain cell costs
//!    real Monte Carlo measurements (which then land in the store for
//!    every later scenario).
//!
//! Without a `MeasureCtx`, out-of-domain queries fall back to the
//! power-law fit ([`ResponseSurface::fit_power_law`]), whose global
//! exponents extrapolate safely where the quadratic's curvature would
//! bend predictions toward zero.
//!
//! [`OracleSnapshot`] counts every source so benchmarks (and the
//! `/v1/scenarios` result payload) can prove a replay was trial-free.

use crate::coordinator::sweep::{run_sweep_executor, SweepProgress};
use crate::coordinator::{Backend, CellStore, SweepResult, SweepSpec};
use crate::recommend::LocalCalibration;
use crate::shapes;
use crate::surface::ResponseSurface;
use crate::util::json::Json;
use crate::util::threadpool::JobTicket;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Everything a backstop measurement needs: a sweep-spec template (seed,
/// model, trial budget — the axes are replaced by the queried cell), the
/// execution backend, the shared cell store, and the executor job the
/// trials are billed to.
pub struct MeasureCtx<'a> {
    /// Template spec; `seed`/`model`/`trials` define the cell's content
    /// address, so backstop cells are shared with ordinary sweeps.
    pub spec: &'a SweepSpec,
    /// Where backstop trials execute.
    pub backend: &'a Backend,
    /// Cell store consulted before any trial is scheduled.
    pub cache: Option<&'a dyn CellStore>,
    /// Executor job ticket the backstop trials run under.
    pub ticket: &'a JobTicket,
}

/// The ticket-independent backstop configuration for standalone scenario
/// runs ([`crate::scenario::fleet::run_scenario`] builds a [`MeasureCtx`]
/// from it once its private executor exists).
pub struct Backstop<'a> {
    /// Template sweep spec (see [`MeasureCtx::spec`]).
    pub spec: &'a SweepSpec,
    /// Where backstop trials execute.
    pub backend: &'a Backend,
    /// Cell store consulted before any trial is scheduled.
    pub cache: Option<&'a dyn CellStore>,
}

/// Largest cell the backstop will measure synchronously, as
/// `n_signals × max(n_memvec, n_obs)` synthesis elements — the same
/// quantity the service's per-request sweep limit bounds (~128 MB at the
/// cap). Bigger out-of-domain queries answer by power-law extrapolation.
pub const MAX_BACKSTOP_ELEMS: usize = 1 << 24;

#[derive(Debug, Default)]
struct OracleStats {
    surface_hits: AtomicUsize,
    memo_hits: AtomicUsize,
    extrapolated: AtomicUsize,
    measured_cells: AtomicUsize,
    fresh_trials: AtomicUsize,
}

/// Plain-value snapshot of the oracle's answer-source counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OracleSnapshot {
    /// In-domain queries answered by the fitted surface.
    pub surface_hits: usize,
    /// Queries served from the oracle's memo table.
    pub memo_hits: usize,
    /// Out-of-domain queries answered by power-law extrapolation.
    pub extrapolated: usize,
    /// Out-of-domain cells resolved through the sweep engine.
    pub measured_cells: usize,
    /// Fresh Monte Carlo trials those cells actually executed (0 when the
    /// cell store already held them).
    pub fresh_trials: usize,
}

impl OracleSnapshot {
    /// JSON rendering for scenario results.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("surface_hits", Json::Num(self.surface_hits as f64)),
            ("memo_hits", Json::Num(self.memo_hits as f64)),
            ("extrapolated", Json::Num(self.extrapolated as f64)),
            ("measured_cells", Json::Num(self.measured_cells as f64)),
            ("fresh_trials", Json::Num(self.fresh_trials as f64)),
        ])
    }
}

/// Fitted per-epoch cost oracle over one sweep's response surfaces.
pub struct SurfaceOracle {
    train: ResponseSurface,
    surveil: ResponseSurface,
    train_pl: ResponseSurface,
    surveil_pl: ResponseSurface,
    cal: LocalCalibration,
    lo: [usize; 3],
    hi: [usize; 3],
    /// Local-testbed seconds → core-equivalents conversion factor
    /// (`cal.eff_flops / base-shape eff_flops`).
    testbed_per_base: f64,
    memo: Mutex<HashMap<(usize, usize, usize), (f64, f64)>>,
    stats: OracleStats,
}

impl SurfaceOracle {
    /// Fit an oracle from a finished sweep: quadratic surfaces for
    /// interpolation, power-law surfaces for extrapolation, calibration
    /// against the largest measured cell, and the grid bounding box as
    /// the trusted domain.
    pub fn from_sweep(result: &SweepResult) -> anyhow::Result<SurfaceOracle> {
        let train_samples = result.samples("train");
        let surveil_samples = result.samples("surveil");
        anyhow::ensure!(
            !train_samples.is_empty(),
            "sweep has no measurable cells to fit an oracle from"
        );
        let fit_err = |e: anyhow::Error| {
            anyhow::anyhow!(
                "oracle surface fit failed ({e}); widen the sweep grid to ≥10 \
                 measurable cells"
            )
        };
        let train = ResponseSurface::fit(&train_samples).map_err(fit_err)?;
        let surveil = ResponseSurface::fit(&surveil_samples).map_err(fit_err)?;
        let train_pl = ResponseSurface::fit_power_law(&train_samples).map_err(fit_err)?;
        let surveil_pl = ResponseSurface::fit_power_law(&surveil_samples).map_err(fit_err)?;
        let spec = &result.spec;
        let axis = |v: &[usize], name: &str| -> anyhow::Result<(usize, usize)> {
            let lo = v.iter().min().copied();
            let hi = v.iter().max().copied();
            match (lo, hi) {
                (Some(lo), Some(hi)) => Ok((lo, hi)),
                _ => anyhow::bail!("sweep axis {name} is empty; cannot bound the oracle"),
            }
        };
        let (n_lo, n_hi) = axis(&spec.signals, "signals")?;
        let (m_lo, m_hi) = axis(&spec.memvecs, "memvecs")?;
        let (o_lo, o_hi) = axis(&spec.obs, "obs")?;
        let cal = LocalCalibration::from_surface(&surveil, n_hi, m_hi, o_hi);
        let testbed_per_base = cal.eff_flops / shapes::catalog()[0].cpu_eff_flops();
        Ok(SurfaceOracle {
            train,
            surveil,
            train_pl,
            surveil_pl,
            cal,
            lo: [n_lo, m_lo, o_lo],
            hi: [n_hi, m_hi, o_hi],
            testbed_per_base,
            memo: Mutex::new(HashMap::new()),
            stats: OracleStats::default(),
        })
    }

    /// The testbed calibration behind the oracle.
    pub fn calibration(&self) -> LocalCalibration {
        self.cal
    }

    /// Inclusive `(lo, hi)` bounds of the trusted design-grid box.
    pub fn domain(&self) -> ([usize; 3], [usize; 3]) {
        (self.lo, self.hi)
    }

    /// Whether a cell lies inside the fitted grid's bounding box.
    pub fn in_domain(&self, n: usize, m: usize, obs: usize) -> bool {
        (self.lo[0]..=self.hi[0]).contains(&n)
            && (self.lo[1]..=self.hi[1]).contains(&m)
            && (self.lo[2]..=self.hi[2]).contains(&obs)
    }

    /// Answer-source counters so far.
    pub fn stats(&self) -> OracleSnapshot {
        OracleSnapshot {
            surface_hits: self.stats.surface_hits.load(Ordering::SeqCst),
            memo_hits: self.stats.memo_hits.load(Ordering::SeqCst),
            extrapolated: self.stats.extrapolated.load(Ordering::SeqCst),
            measured_cells: self.stats.measured_cells.load(Ordering::SeqCst),
            fresh_trials: self.stats.fresh_trials.load(Ordering::SeqCst),
        }
    }

    /// Local-testbed cost of cell `(n, m, obs)`: `(train_s, surveil_s)`
    /// where `surveil_s` streams `obs` observations. Sources, in order:
    /// memo → fitted surface (in-domain) → cell store / fresh trials
    /// (out-of-domain with a [`MeasureCtx`]) → power-law extrapolation.
    pub fn local_costs(
        &self,
        n: usize,
        m: usize,
        obs: usize,
        ctx: Option<&MeasureCtx<'_>>,
    ) -> anyhow::Result<(f64, f64)> {
        let key = (n, m, obs);
        if let Some(&hit) = self.memo.lock().unwrap().get(&key) {
            self.stats.memo_hits.fetch_add(1, Ordering::SeqCst);
            return Ok(hit);
        }
        let costs = if self.in_domain(n, m, obs) {
            self.stats.surface_hits.fetch_add(1, Ordering::SeqCst);
            (self.train.predict(n, m, obs), self.surveil.predict(n, m, obs))
        } else {
            let measured = match ctx {
                Some(ctx) => self.measure_cell(n, m, obs, ctx)?,
                None => None,
            };
            match measured {
                Some(c) => c,
                None => {
                    self.stats.extrapolated.fetch_add(1, Ordering::SeqCst);
                    (
                        self.train_pl.predict(n, m, obs),
                        self.surveil_pl.predict(n, m, obs),
                    )
                }
            }
        };
        self.memo.lock().unwrap().insert(key, costs);
        Ok(costs)
    }

    /// One-cell exhaustive sweep through the shared executor; the cell
    /// store serves warm cells with zero fresh trials. `None` (→ the
    /// caller extrapolates instead) when the cell is a training-constraint
    /// gap (`m < 2n` under MSET) or larger than [`MAX_BACKSTOP_ELEMS`] —
    /// the backstop must not let one scenario's runaway workload drift
    /// schedule arbitrarily large Monte Carlo cells the service's
    /// per-request limits never saw.
    fn measure_cell(
        &self,
        n: usize,
        m: usize,
        obs: usize,
        ctx: &MeasureCtx<'_>,
    ) -> anyhow::Result<Option<(f64, f64)>> {
        if n.saturating_mul(m.max(obs)) > MAX_BACKSTOP_ELEMS {
            return Ok(None);
        }
        let mut spec = ctx.spec.clone();
        spec.signals = vec![n];
        spec.memvecs = vec![m];
        spec.obs = vec![obs];
        spec.ci_target = 0.0; // a single cell: the exhaustive loop is right
        if spec.is_gap(crate::coordinator::CellKey { n, m, obs }) {
            return Ok(None);
        }
        let progress = Arc::new(SweepProgress::default());
        let result =
            run_sweep_executor(&spec, ctx.backend.clone(), ctx.cache, ctx.ticket, &progress)?;
        let fresh = progress.trials_done.load(Ordering::SeqCst);
        self.stats.fresh_trials.fetch_add(fresh, Ordering::SeqCst);
        self.stats.measured_cells.fetch_add(1, Ordering::SeqCst);
        crate::metrics::Registry::global().add("scenario.oracle.fresh_trials", fresh as u64);
        let cell = &result.cells[0];
        match (&cell.train, &cell.surveil) {
            (Some(t), Some(s)) => Ok(Some((t.median, s.median))),
            _ => Ok(None),
        }
    }

    /// Local seconds to surveil **one** observation for an `(n, m)` model,
    /// evaluated at the best-measured streaming window (the domain's
    /// largest obs count).
    pub fn per_obs_s(
        &self,
        n: usize,
        m: usize,
        ctx: Option<&MeasureCtx<'_>>,
    ) -> anyhow::Result<f64> {
        let window = self.hi[2];
        let (_, surveil_s) = self.local_costs(n, m, window, ctx)?;
        Ok(surveil_s / window as f64)
    }

    /// Core-equivalent demand of an `(n, m)` model streaming
    /// `obs_per_sec` observations per second — the unit the fleet engine
    /// and the shape ladder speak.
    pub fn demand_core_eq(
        &self,
        n: usize,
        m: usize,
        obs_per_sec: f64,
        ctx: Option<&MeasureCtx<'_>>,
    ) -> anyhow::Result<f64> {
        let per_obs = self.per_obs_s(n, m, ctx)?;
        Ok(obs_per_sec * per_obs * self.testbed_per_base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_sweep_cached;
    use crate::service::cache::SweepCache;
    use crate::util::threadpool::TrialExecutor;

    fn fitted_sweep(cache: Option<&dyn CellStore>) -> SweepResult {
        let spec = SweepSpec {
            signals: vec![2, 3],
            memvecs: vec![8, 12, 16],
            obs: vec![16, 32],
            trials: 1,
            seed: 5,
            model: "mset2".into(),
            workers: 2,
            ..SweepSpec::default()
        };
        run_sweep_cached(&spec, Backend::Native, cache).unwrap()
    }

    #[test]
    fn in_domain_queries_use_the_surface_and_memoise() {
        let oracle = SurfaceOracle::from_sweep(&fitted_sweep(None)).unwrap();
        assert!(oracle.in_domain(2, 12, 16));
        assert!(!oracle.in_domain(2, 12, 4096));
        let (t, s) = oracle.local_costs(2, 12, 16, None).unwrap();
        assert!(t > 0.0 && s > 0.0);
        let again = oracle.local_costs(2, 12, 16, None).unwrap();
        assert_eq!((t, s), again, "memoised answer must be identical");
        let st = oracle.stats();
        assert_eq!(st.surface_hits, 1);
        assert_eq!(st.memo_hits, 1);
        assert_eq!(st.fresh_trials, 0);
    }

    #[test]
    fn out_of_domain_without_ctx_extrapolates() {
        let oracle = SurfaceOracle::from_sweep(&fitted_sweep(None)).unwrap();
        let (t, s) = oracle.local_costs(2, 64, 16, None).unwrap();
        assert!(t.is_finite() && t > 0.0 && s.is_finite() && s > 0.0);
        assert_eq!(oracle.stats().extrapolated, 1);
        assert_eq!(oracle.stats().measured_cells, 0);
    }

    #[test]
    fn out_of_domain_with_ctx_measures_once_then_serves_from_cache() {
        let cache = SweepCache::in_memory();
        let result = fitted_sweep(Some(&cache));
        let template = result.spec.clone();
        let exec = TrialExecutor::new(2, true);
        let ticket = exec.register(1.0);
        let backend = Backend::Native;
        {
            let oracle = SurfaceOracle::from_sweep(&result).unwrap();
            let ctx = MeasureCtx {
                spec: &template,
                backend: &backend,
                cache: Some(&cache),
                ticket: &ticket,
            };
            let (t, s) = oracle.local_costs(2, 64, 16, Some(&ctx)).unwrap();
            assert!(t > 0.0 && s > 0.0);
            let st = oracle.stats();
            assert_eq!(st.measured_cells, 1);
            assert!(st.fresh_trials > 0, "cold cell must execute real trials");
        }
        // A second oracle over the now-warm store: same query, zero trials.
        let oracle = SurfaceOracle::from_sweep(&result).unwrap();
        let ctx = MeasureCtx {
            spec: &template,
            backend: &backend,
            cache: Some(&cache),
            ticket: &ticket,
        };
        oracle.local_costs(2, 64, 16, Some(&ctx)).unwrap();
        let st = oracle.stats();
        assert_eq!(st.measured_cells, 1);
        assert_eq!(st.fresh_trials, 0, "warm store must serve without trials");
    }

    #[test]
    fn gap_cells_fall_back_to_extrapolation() {
        let result = fitted_sweep(None);
        let template = result.spec.clone();
        let exec = TrialExecutor::new(1, true);
        let ticket = exec.register(1.0);
        let backend = Backend::Native;
        let oracle = SurfaceOracle::from_sweep(&result).unwrap();
        let ctx = MeasureCtx {
            spec: &template,
            backend: &backend,
            cache: None,
            ticket: &ticket,
        };
        // m < 2n and outside the grid: unmeasurable, must extrapolate
        let (t, s) = oracle.local_costs(64, 8, 16, Some(&ctx)).unwrap();
        assert!(t > 0.0 && s > 0.0);
        assert_eq!(oracle.stats().measured_cells, 0);
        assert_eq!(oracle.stats().extrapolated, 1);
        // an oversized cell must also extrapolate, never schedule trials
        // (one scenario must not defeat the service's resource caps)
        let (t, s) = oracle
            .local_costs(4096, 1 << 23, 16, Some(&ctx))
            .unwrap();
        assert!(t.is_finite() && t > 0.0 && s.is_finite() && s > 0.0);
        assert_eq!(oracle.stats().measured_cells, 0);
        assert_eq!(oracle.stats().extrapolated, 2);
        assert_eq!(oracle.stats().fresh_trials, 0);
    }

    #[test]
    fn demand_scales_with_rate_and_model_size() {
        let oracle = SurfaceOracle::from_sweep(&fitted_sweep(None)).unwrap();
        let d1 = oracle.demand_core_eq(2, 8, 1.0, None).unwrap();
        let d10 = oracle.demand_core_eq(2, 8, 10.0, None).unwrap();
        assert!(d1 > 0.0);
        assert!((d10 / d1 - 10.0).abs() < 1e-9, "demand linear in rate");
        let big = oracle.demand_core_eq(3, 16, 1.0, None).unwrap();
        assert!(big > d1, "bigger model must demand more compute");
    }
}
